"""Paper Fig. 13/14: batch-query optimization cost & benefit.

Sweeps batch size and #candidate models per query; reports Alg. 4
search time (cost) and training-time saving (benefit, Def. 3), plus the
oracle gap on the small instances where the oracle is feasible.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_CFG, bench_world
from repro.core.batch_opt import batch_optimize, batch_oracle
from repro.core.cost import CostModel
from repro.core.plans import Interval
from repro.core.store import ModelStore


def _store(index, n_models, span, seed):
    rng = np.random.default_rng(seed)
    store = ModelStore()
    for _ in range(n_models):
        lo = rng.uniform(span[0], span[1] * 0.85)
        hi = lo + rng.uniform((span[1] - span[0]) * 0.03,
                              (span[1] - span[0]) * 0.2)
        nd, nt = index.count(lo, hi)
        store.add(Interval(lo, hi), nd, nt, "vb",
                  {"lam": np.ones((4, 8), np.float32)})
    return store


def _queries(rng, n, span):
    out = []
    for _ in range(n):
        lo = rng.uniform(span[0], span[1] * 0.6)
        hi = lo + rng.uniform((span[1] - span[0]) * 0.2,
                              (span[1] - span[0]) * 0.4)
        out.append(Interval(lo, min(hi, span[1])))
    return out


def run(batch_sizes=(2, 3, 4, 6), models_per=(8, 16, 24), seed=0):
    _, _, index, _ = bench_world(n_docs=1200, seed=seed)
    span = (0.0, 1200.0)
    cost = CostModel(max_iters=BENCH_CFG.max_iters,
                     n_topics=BENCH_CFG.n_topics)
    rng = np.random.default_rng(seed)
    rows = []
    for n_models in models_per:
        store = _store(index, n_models, span, seed + n_models)
        for b in batch_sizes:
            qs = _queries(rng, b, span)
            h = batch_optimize(store.models(), qs, index, cost)
            oracle_t = float("nan")
            if b <= 3 and n_models <= 8:
                try:
                    o = batch_oracle(store.models(), qs, index, cost)
                    oracle_t = o.total_time
                except ValueError:
                    pass
            rows.append((b, n_models, h.elapsed_s, h.n_scored, h.benefit,
                         h.total_time, h.naive_time, oracle_t))
    return rows


def main():
    print("batch,models,search_s,n_scored,benefit,total_time,naive_time,"
          "oracle_time")
    for r in run():
        print(",".join(f"{x:.6f}" if isinstance(x, float) else str(x)
                       for x in r))


if __name__ == "__main__":
    main()
