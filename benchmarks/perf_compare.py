"""Before/after roofline comparison between two dry-run artifact dirs.

    PYTHONPATH=src python -m benchmarks.perf_compare \
        --before experiments/baseline --after experiments/dryrun \
        [--cells qwen3-moe-235b-a22b__train_4k__single,...]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_dir(d):
    out = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        with open(p) as f:
            rec = json.load(f)
        out[f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"] = rec
    return out


def fmt_delta(b, a):
    if b == 0:
        return "--"
    return f"{(a - b) / b * 100:+.1f}%"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--before", default="experiments/baseline")
    ap.add_argument("--after", default="experiments/dryrun")
    ap.add_argument("--cells", default=None)
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    before = load_dir(args.before)
    after = load_dir(args.after)
    cells = (args.cells.split(",") if args.cells
             else sorted(set(before) & set(after)))
    hdr = ("cell,term,before_s,after_s,delta,"
           "temp_GB_before,temp_GB_after")
    if args.md:
        cols = hdr.split(",")
        print("| " + " | ".join(cols) + " |")
        print("|" + "---|" * len(cols))
    else:
        print(hdr)
    for c in cells:
        if c not in before or c not in after:
            continue
        b, a = before[c], after[c]
        for term in ("compute_s", "memory_s", "collective_s"):
            bv, av = b["roofline"][term], a["roofline"][term]
            row = [c, term.replace("_s", ""), f"{bv:.4f}", f"{av:.4f}",
                   fmt_delta(bv, av),
                   f"{b['bytes_per_device']['temp']/1e9:.2f}",
                   f"{a['bytes_per_device']['temp']/1e9:.2f}"]
            if args.md:
                print("| " + " | ".join(row) + " |")
            else:
                print(",".join(row))


if __name__ == "__main__":
    main()
