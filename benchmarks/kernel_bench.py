"""Kernel parity micro-benchmarks.

On this CPU host the Pallas kernels execute in interpret mode (a Python
emulation — wall time is meaningless for TPU), so we report the
reference-path timing (the jnp math the kernel replaces, which IS the
CPU execution path) plus a parity check, and derive the kernel's TPU
byte/flop budget analytically from its BlockSpec tiling.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _t(fn, *args, repeat=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat * 1e6   # us


def run(quick=False):
    rng = np.random.default_rng(0)
    rows = []

    # vb_estep
    from repro.kernels.vb_estep.ops import vb_estep
    from repro.kernels.vb_estep.ref import vb_estep_ref
    d, v, k = (64, 256, 64) if quick else (256, 1024, 128)
    x = jnp.asarray(rng.poisson(0.4, (d, v)), jnp.float32)
    eeb = jnp.asarray(rng.gamma(1.0, 1.0, (k, v)), jnp.float32)
    g0 = jnp.ones((d, k), jnp.float32)
    ref = jax.jit(lambda *a: vb_estep_ref(*a, 0.5, 10))
    us = _t(ref, x, eeb, g0)
    g1, s1 = vb_estep(x, eeb, g0, 0.5, 10, interpret=True)
    g2, s2 = vb_estep_ref(x, eeb, g0, 0.5, 10)
    err = float(jnp.abs(s1 - s2).max() / jnp.abs(s2).max())
    # TPU budget: n_iters x 2 matmuls (D,K)x(K,V), one HBM pass over x
    flops = 10 * 2 * 2 * d * k * v
    rows.append(("vb_estep", us, err,
                 f"tpu_us~{flops / 197e12 * 1e6:.1f}(mxu-bound)"))

    # merge_topics
    from repro.kernels.merge_topics.ops import merge_topics
    from repro.kernels.merge_topics.ref import merge_topics_ref
    n, mk, mv = (4, 64, 256) if quick else (16, 128, 1024)
    st = jnp.asarray(rng.normal(size=(n, mk, mv)), jnp.float32)
    w = jnp.ones((n,), jnp.float32)
    ref = jax.jit(lambda s, w: merge_topics_ref(s, w, 0.01, 0.01))
    us = _t(ref, st, w)
    a = merge_topics(st, w, bias=0.01, base=0.01, interpret=True)
    err = float(jnp.abs(a - ref(st, w)).max())
    bts = (n + 1) * mk * mv * 4
    rows.append(("merge_topics", us, err,
                 f"tpu_us~{bts / 819e9 * 1e6:.2f}(hbm-bound)"))

    # merge_topics_batch (the submit_many one-launch path)
    from repro.kernels.merge_topics.ops import merge_topics_batch
    from repro.kernels.merge_topics.ref import merge_topics_batched_ref
    nb = 2 if quick else 4
    stb = jnp.asarray(rng.normal(size=(nb, n, mk, mv)), jnp.float32)
    wb = jnp.ones((nb, n), jnp.float32)
    ref = jax.jit(lambda s, w: merge_topics_batched_ref(s, w, 0.01, 0.01))
    us = _t(ref, stb, wb)
    a = merge_topics_batch(stb, wb, bias=0.01, base=0.01, interpret=True)
    err = float(jnp.abs(a - ref(stb, wb)).max())
    bts = nb * (n + 1) * mk * mv * 4
    rows.append(("merge_topics_batch", us, err,
                 f"tpu_us~{bts / 819e9 * 1e6:.2f}(hbm-bound)"))

    # flash attention
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    b, s, h, kvh, hd = (1, 128, 4, 2, 32) if quick else (2, 256, 8, 2, 64)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, kvh, hd)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(b, s, kvh, hd)), jnp.float32)
    ref = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us = _t(ref, q, kk, vv)
    a = flash_attention(q, kk, vv, block_q=64, block_k=64, interpret=True)
    err = float(jnp.abs(a - ref(q, kk, vv)).max())
    flops = 4 * b * s * s * h * hd
    rows.append(("flash_attention", us, err,
                 f"tpu_us~{flops / 197e12 * 1e6:.2f}(mxu-bound)"))

    # decode attention
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    s = 1024 if quick else 4096
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, s, kvh, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, s, kvh, hd)), jnp.float32)
    ref = jax.jit(lambda q, k, v: decode_attention_ref(q, k, v, s - 1))
    us = _t(ref, q, kc, vc)
    a = decode_attention(q, kc, vc, s - 1, interpret=True)
    err = float(jnp.abs(a - ref(q, kc, vc)).max())
    bts = 2 * b * s * kvh * hd * 4
    rows.append(("decode_attention", us, err,
                 f"tpu_us~{bts / 819e9 * 1e6:.2f}(hbm-bound)"))

    print("kernel,ref_us_per_call,max_err_vs_ref,derived")
    for name, us, err, derived in rows:
        print(f"{name},{us:.1f},{err:.2e},{derived}")


if __name__ == "__main__":
    run()
