"""Roofline table from the dry-run artifacts (§Roofline deliverable).

Reads experiments/dryrun/*.json (written by launch/dryrun.py) and
renders, per (arch × shape × mesh):

    compute/memory/collective terms (s), the dominant term,
    MODEL_FLOPS = 6·N·D (train) / 2·N_active·tokens (serve),
    MODEL_FLOPS / HLO_FLOPs (useful-compute fraction — catches
    remat/redundancy waste), and bytes-per-device.

Markdown output with --md (used verbatim in EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from repro.configs import ARCHS, SHAPES_BY_NAME
from repro.models.model import build_model

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops_global(arch: str, shape_name: str) -> float:
    cfg = ARCHS[arch]
    shape = SHAPES_BY_NAME[shape_name]
    model = build_model(cfg)
    n_act = model.active_param_count()
    n_tot = model.param_count()
    if shape.kind == "train":
        return 6.0 * n_act * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.tokens
    return 2.0 * n_act * shape.global_batch    # decode: 1 token/seq


def load(dryrun_dir: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def render(rows, md=False, mesh_filter=None):
    out = []
    hdr = ["arch", "shape", "mesh", "compute_s", "memory_s", "mem_kern_s",
           "coll_s", "dominant", "model_gflops/dev", "useful_frac",
           "temp_GB/dev"]
    if md:
        out.append("| " + " | ".join(hdr) + " |")
        out.append("|" + "---|" * len(hdr))
    else:
        out.append(",".join(hdr))
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        mf = model_flops_global(r["arch"], r["shape"]) / r["n_devices"]
        hlo_f = max(r["hlo_analysis"]["flops"], 1e-9)
        rl = r["roofline"]
        cells = [
            r["arch"], r["shape"], r["mesh"],
            f"{rl['compute_s']:.4f}", f"{rl['memory_s']:.4f}",
            f"{rl.get('memory_kernelized_s', rl['memory_s']):.4f}",
            f"{rl['collective_s']:.4f}", rl["dominant"].replace("_s", ""),
            f"{mf / 1e9:.1f}", f"{mf / hlo_f:.3f}",
            f"{r['bytes_per_device']['temp'] / 1e9:.2f}",
        ]
        if md:
            out.append("| " + " | ".join(cells) + " |")
        else:
            out.append(",".join(cells))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = load(args.dir)
    if not rows:
        print(f"no dry-run artifacts under {args.dir}; "
              "run python -m repro.launch.dryrun first", file=sys.stderr)
        raise SystemExit(1)
    print(render(rows, md=args.md, mesh_filter=args.mesh))


if __name__ == "__main__":
    main()
