"""Shared benchmark substrate: corpus/store construction + timing."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Tuple

import jax
import numpy as np

from repro.api import get_trainer, resolve_kind
from repro.configs.lda_default import LDAConfig
from repro.core.cost import CostModel
from repro.core.lda import log_predictive_probability, topics_from_vb
from repro.core.plans import Interval
from repro.core.store import ModelStore
from repro.core.vb import vb_fit
from repro.data.corpus import (
    Corpus,
    DataIndex,
    doc_term_matrix,
    make_corpus,
    train_test_split,
)

BENCH_CFG = LDAConfig(n_topics=16, vocab_size=512, alpha=0.5, eta=0.05,
                      max_iters=20, e_step_iters=10, gibbs_sweeps=10)

# Quick mode: small enough that the full bench harness finishes in
# under ~2 min on a CPU runner (the CI smoke job and local spot checks
# share this config via ``bench_cfg(quick=True)``).
QUICK_CFG = LDAConfig(n_topics=8, vocab_size=256, alpha=0.5, eta=0.05,
                      max_iters=8, e_step_iters=5, gibbs_sweeps=5)


def bench_cfg(quick: bool = False) -> LDAConfig:
    return QUICK_CFG if quick else BENCH_CFG


def timed(fn: Callable, *args, repeat: int = 1, **kw) -> Tuple[float, object]:
    out = None
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / repeat, out


def bench_world(n_docs=1500, cfg: LDAConfig = BENCH_CFG, seed=0):
    corpus, beta = make_corpus(n_docs, cfg.vocab_size, cfg.n_topics,
                               mean_doc_len=40, seed=seed)
    train, test = train_test_split(corpus, test_frac=0.1, seed=seed)
    return train, test, DataIndex(train), beta


def train_vb_range(corpus: Corpus, cfg: LDAConfig, lo, hi, seed=0):
    sub = corpus.subset(lo, hi)
    x = doc_term_matrix(sub)
    lam = np.asarray(vb_fit(x, jax.random.PRNGKey(seed), cfg))
    return lam, sub


def materialize_partitions(corpus: Corpus, cfg: LDAConfig, store: ModelStore,
                           edges: List[float], kind: str = "vb") -> None:
    kind = resolve_kind(kind)     # store tags must be canonical ("gibbs"->"gs")
    trainer = get_trainer(kind)
    for lo, hi in zip(edges, edges[1:]):
        sub = corpus.subset(lo, hi)
        if sub.n_docs == 0:
            continue
        theta = trainer(sub, cfg, jax.random.PRNGKey(0))
        store.add(Interval(lo, hi), sub.n_docs, sub.n_tokens, kind, theta)


def lpp_of(beta: np.ndarray, test: Corpus) -> float:
    return log_predictive_probability(beta, doc_term_matrix(test))
