"""Vocab-sharded merge bench: ragged segmented launch, 8-way V slices.

Forks one subprocess with ``--xla_force_host_platform_device_count=8``
(the parent keeps the single real CPU device for the other sections)
and ``MLEGO_KERNEL_INTERPRET=1``, merges one ragged batch through the
single-device ``DeviceBackend`` and the vocab-sharded
``ShardedDeviceBackend``, and reports launches, pad rows, per-device
resident bytes and wall time for each.  On CPU the walls measure the
interpret-mode overhead, not TPU speed — the structural columns
(launches == 1, ``pad_rows == 0``, per-device bytes == global/ndev)
are the regression surface CI watches.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BODY = """
import json, time
import numpy as np
from repro.api.backend import DeviceBackend, ShardedDeviceBackend
from repro.configs.lda_default import LDAConfig
from repro.core.lda import MaterializedModel
from repro.core.plans import Interval

K, V, COUNTS = {k}, {v}, {counts}
CFG = LDAConfig(n_topics=K, vocab_size=V, eta=0.05)
rng = np.random.default_rng(0)
ms, mid = [], 0
batches = []
for n in COUNTS:
    parts = []
    for _ in range(n):
        parts.append(MaterializedModel(
            mid, Interval(float(mid), float(mid) + 1.0), 10, 100, "vb",
            {{"lam": rng.gamma(1.0, 1.0, (K, V)).astype(np.float32)}}))
        mid += 1
    batches.append(parts)

def bench(backend):
    backend.merge_many(batches, "vb", CFG)      # warm: uploads + compile
    s0 = backend.stats
    t0 = time.perf_counter()
    out = backend.merge_many(batches, "vb", CFG)
    wall = time.perf_counter() - t0
    s = backend.stats.delta(s0)
    return out, dict(wall_s=wall, launches=s.device_launches,
                     pad_rows=s.pad_rows,
                     per_device_bytes=backend.cache.resident_bytes,
                     shards=backend.shards)

single, single_m = bench(DeviceBackend())
sharded, sharded_m = bench(ShardedDeviceBackend())
err = max(float(np.abs(a - b).max()) for a, b in zip(single, sharded))
print(json.dumps(dict(k=K, v=V, counts=COUNTS, rows=sum(COUNTS),
                      single=single_m, sharded=sharded_m,
                      max_abs_err=err)))
"""


def run(quick: bool = False) -> dict:
    k, v = (8, 512) if quick else (16, 2048)
    counts = [1, 1, 4, 1] if quick else [1, 3, 1, 8, 2, 1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["MLEGO_KERNEL_INTERPRET"] = "1"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    body = textwrap.dedent(_BODY).format(k=k, v=v, counts=counts)
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(f"merge_shard subprocess failed:\n"
                           f"{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])
