"""Benchmark harness — one section per paper table/figure.

  merging_effect      Fig. 3/6   perf loss vs #merges (+ rho refit)
  merging_efficiency  Fig. 7     SR vs ORIG / LDA* / OGS
  scalability         Fig. 8     SR vs corpus size
  coverage            Fig. 9     SR vs coverage ratio
  plan_search         Fig. 10-12 NAI/GRA/PSOA/PSOA++ times, alpha sweep
  batch_opt           Fig. 13/14 Alg. 4 cost & benefit
  session             (ours)     unified submit/submit_many API latency
                                 + device-backend cache hit rates
  serve               (ours)     multi-tenant service: coalesced vs
                                 serial throughput/p50/p95 under
                                 concurrent traffic + cross-session
                                 cache reuse
  gibbs_gap           (ours)     host exact CGS scan vs doc-blocked
                                 device sweep (latency + quality delta)
  merge_shard         (ours)     vocab-sharded ragged merge vs single
                                 device (launches, pad rows, per-device
                                 bytes, wall) over 8 forced host devices
  ingest              (ours)     streaming ingestion: freshness lag,
                                 speculative pre-training A/B (p50 +
                                 hit rate), compaction budget/quality
  chaos               (ours)     serve trace under injected faults:
                                 goodput, retry counts, breaker opens/
                                 reroutes, device-loss recovery time
  obs                 (ours)     tracing/metrics overhead (asserted
                                 < 5%) + Chrome trace artifact and
                                 span/metric cardinality
  kernels             (ours)     Pallas kernel parity timings
  roofline            (ours)     table from dry-run artifacts, if present

All sections drive MLego through the ``repro.api`` session surface
(QuerySpec -> MLegoSession.submit); none construct the deprecated
``QueryEngine`` directly.

``--quick`` shrinks every section so the whole harness finishes in
under ~2 min on CPU (the CI smoke job runs this).  ``--json PATH``
additionally dumps every section's rows as one JSON document — CI
uploads these as ``BENCH_*.json`` artifacts so the perf trajectory
accumulates across commits.

Usage: PYTHONPATH=src python -m benchmarks.run
           [--quick] [--only SECTION[,SECTION...]] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time


def _section(name):
    print(f"\n### {name}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section names (default: all)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write section rows as JSON to PATH")
    args = ap.parse_args()

    only = None if args.only is None else {
        s.strip() for s in args.only.split(",") if s.strip()}
    out = {}

    def want(name):
        return only is None or name in only

    t_start = time.perf_counter()

    if want("merging_effect"):
        _section("merging_effect (Fig. 3/6)")
        from benchmarks import merging_effect
        rows, ploss = merging_effect.run(
            n_docs=600 if args.quick else 1200,
            parts=(1, 2, 4, 8) if args.quick else (1, 2, 4, 8, 16))
        print("n_parts,lpp_scratch,lpp_mvb,lpp_mgs,dp_mvb,dp_mgs")
        for r in rows:
            print(",".join(f"{v:.4f}" if isinstance(v, float) else str(v)
                           for v in r))
        print(f"# fitted PerformanceLoss rho = {ploss.rho:.5f}")
        out["merging_effect"] = {"rows": [list(r) for r in rows],
                                 "rho": ploss.rho}

    if want("merging_efficiency"):
        _section("merging_efficiency (Fig. 7)")
        from benchmarks import merging_efficiency
        rows, t_mat = merging_efficiency.run(
            n_docs=600 if args.quick else 1500)
        print("method,time_s,lpp,SR")
        for name, t, lpp, sr in rows:
            print(f"{name},{t:.4f},{lpp:.4f},{sr:.2f}")
        print(f"# materialization {t_mat:.2f}s (offline)")
        out["merging_efficiency"] = {"rows": [list(r) for r in rows],
                                     "t_materialize_s": t_mat}

    if want("scalability"):
        _section("scalability (Fig. 8)")
        from benchmarks import merging_efficiency
        print("n_docs,method,time_s,SR")
        scal = []
        for n in ((400, 1000) if args.quick else (500, 1500, 4000)):
            rows, _ = merging_efficiency.run(n_docs=n)
            for name, t, _, sr in rows:
                print(f"{n},{name},{t:.4f},{sr:.2f}")
                scal.append([n, name, t, sr])
        out["scalability"] = {"rows": scal}

    if want("coverage"):
        _section("coverage (Fig. 9)")
        from benchmarks import coverage
        print("coverage,t_orig_s,t_mlego_s,SR,t_search_s,lpp")
        rows = list(coverage.run(n_docs=600 if args.quick else 1500))
        for r in rows:
            print(",".join(f"{v:.4f}" for v in r))
        out["coverage"] = {"rows": [list(r) for r in rows]}

    if want("plan_search"):
        _section("plan_search (Fig. 10/11/12)")
        from benchmarks import plan_search
        print("n_models,alpha,nai_s,nai_scored,gra_s,gra_scored,"
              "psoa_s,psoa_scored,psoa++_s,psoa++_scored")
        sizes = (6, 10, 14) if args.quick else (6, 10, 14, 18, 22)
        size_rows = list(plan_search.run_sizes(sizes=sizes))
        for r in size_rows:
            print(",".join(f"{x:.6f}" if isinstance(x, float) else str(x)
                           for x in r))
        print("alpha,psoa_s,n_scored,n_layers,method")
        alpha_rows = list(plan_search.run_alpha())
        for r in alpha_rows:
            print(",".join(f"{x:.6f}" if isinstance(x, float) else str(x)
                           for x in r))
        out["plan_search"] = {"sizes": [list(r) for r in size_rows],
                              "alpha": [list(r) for r in alpha_rows]}

    if want("batch_opt"):
        _section("batch_opt (Fig. 13/14)")
        from benchmarks import batch_opt_bench
        print("batch,models,search_s,n_scored,benefit,total_time,"
              "naive_time,oracle_time")
        bs = (2, 3) if args.quick else (2, 3, 4, 6)
        mp = (8, 16) if args.quick else (8, 16, 24)
        rows = list(batch_opt_bench.run(batch_sizes=bs, models_per=mp))
        for r in rows:
            print(",".join(f"{x:.6f}" if isinstance(x, float) else str(x)
                           for x in r))
        out["batch_opt"] = {"rows": [list(r) for r in rows]}

    if want("session"):
        _section("session (unified API latency)")
        from benchmarks import session_bench
        n_docs = 600 if args.quick else 1200
        rows, batch_row = session_bench.run(n_docs=n_docs, quick=args.quick)
        print("label,search_s,train_s,merge_s,n_reused,n_trained_tokens,"
              "plan_cached")
        for label, s, t, m, nr, nt, pc in rows:
            print(f"{label},{s:.4f},{t:.4f},{m:.4f},{nr},{nt},{pc}")
        print("# batch: shared_search_s,shared_train_s,merge_s,benefit,n")
        print("batch," + ",".join(
            f"{v:.4f}" if isinstance(v, float) else str(v)
            for v in batch_row))
        dev_rows, hit_rate = session_bench.run_device_cache(
            n_docs=n_docs, quick=args.quick)
        print("label,cache_hits,cache_misses,merge_device_ms,merge_s,"
              "plan_cached")
        for label, h, mi, dms, ms, pc in dev_rows:
            print(f"{label},{h},{mi},{dms:.3f},{ms:.4f},{pc}")
        print(f"# device cache hit-rate {hit_rate:.3f}")
        prov_rows = session_bench.run_providers(
            n_docs=n_docs, quick=args.quick)
        print("provider,mean_submit_s,total_s,plan_cache_hits,"
              "device_hit_rate")
        for provider, mean_s, total, hits, rate in prov_rows:
            print(f"{provider},{mean_s:.4f},{total:.4f},{hits},{rate:.3f}")
        pad = session_bench.run_padding(n_docs=n_docs, quick=args.quick)
        print(f"# padding: ragged {pad['pad_rows_ragged']} rows vs "
              f"bucketed {pad['pad_rows_bucketed']} vs widest "
              f"{pad['pad_rows_widest']} (parts {pad['part_counts']})")
        out["session"] = {"rows": [list(r) for r in rows],
                          "batch": list(batch_row),
                          "device_cache": [list(r) for r in dev_rows],
                          "device_cache_hit_rate": hit_rate,
                          "providers": [list(r) for r in prov_rows],
                          "padding": pad}

    if want("serve"):
        _section("serve (coalesced service vs serial session)")
        from benchmarks import serve_bench
        sv = serve_bench.run(n_docs=600 if args.quick else 1200,
                             quick=args.quick)
        s, c = sv["serial"], sv["coalesced"]
        print("mode,queries,wall_s,qps,p50_s,p95_s")
        for label, m in (("serial", s), ("coalesced", c)):
            print(f"{label},{m['queries']},{m['wall_s']:.3f},"
                  f"{m['qps']:.2f},{m['p50_s']:.4f},{m['p95_s']:.4f}")
        print(f"# speedup {sv['speedup']:.2f}x, mean coalesce width "
              f"{sv['mean_coalesce_width']:.2f} (max "
              f"{sv['max_coalesce_width']}), coalesce rate "
              f"{sv['coalesce_rate']:.2f}")
        cross = serve_bench.run_cross_session(
            n_docs=600 if args.quick else 1200, quick=args.quick)
        print(f"# cross-session: plan_cached={cross['second_plan_cached']} "
              f"device hits={cross['second_cache_hits']} "
              f"misses={cross['second_cache_misses']}")
        ol = serve_bench.run_open_loop(
            n_docs=600 if args.quick else 1200, quick=args.quick)
        print(f"# open-loop ({ol['n_tenants']} tenants, {ol['arrivals']} "
              f"arrivals, {ol['overload']:.1f}x overload): "
              f"p50 {ol['p50_ms']:.1f}ms p95 {ol['p95_ms']:.1f}ms "
              f"p99 {ol['p99_ms']:.1f}ms, shed_rate {ol['shed_rate']:.3f}, "
              f"degraded_frac {ol['degraded_frac']:.3f}, "
              f"p95_within_slo={ol['p95_within_slo']} "
              f"(slo {ol['slo_ms']:.1f}ms)")
        pc = serve_bench.run_pool_comparison(
            n_docs=600 if args.quick else 1200, quick=args.quick)
        print(f"# worker pools: single-loop "
              f"{pc['single_loop']['wall_s']:.2f}s vs pooled "
              f"{pc['pooled']['wall_s']:.2f}s "
              f"({pc['pool_speedup']:.2f}x)")
        out["serve"] = {**sv, "cross_session": cross,
                        "open_loop": ol, "pools": pc,
                        # hardening headline numbers, hoisted for the
                        # artifact trajectory
                        "p50_ms": ol["p50_ms"], "p95_ms": ol["p95_ms"],
                        "p99_ms": ol["p99_ms"],
                        "shed_rate": ol["shed_rate"],
                        "degraded_frac": ol["degraded_frac"]}

    if want("gibbs_gap"):
        _section("gibbs_gap (host exact scan vs blocked device sweep)")
        from benchmarks import gibbs_gap
        print("block_docs,n_blocks,host_scan_s,blocked_s,speedup,"
              "lpp_host,lpp_blocked,lpp_delta,top_word_overlap")
        gg_rows = gibbs_gap.rows(quick=args.quick)
        for r in gg_rows:
            print(f"{r['block_docs']},{r['n_blocks']},"
                  f"{r['host_scan_s']:.4f},{r['blocked_s']:.4f},"
                  f"{r['speedup']:.2f},{r['lpp_host']:.4f},"
                  f"{r['lpp_blocked']:.4f},{r['lpp_delta']:.4f},"
                  f"{r['top_word_overlap']:.3f}")
        out["gibbs_gap"] = {"rows": gg_rows}

    if want("merge_shard"):
        _section("merge_shard (vocab-sharded ragged merge, 8 devices)")
        from benchmarks import merge_shard_bench
        msd = merge_shard_bench.run(quick=args.quick)
        print("mode,shards,launches,pad_rows,per_device_bytes,wall_s")
        for label in ("single", "sharded"):
            m = msd[label]
            print(f"{label},{m['shards']},{m['launches']},{m['pad_rows']},"
                  f"{m['per_device_bytes']},{m['wall_s']:.4f}")
        print(f"# batch {msd['counts']} ({msd['rows']} rows, K={msd['k']}, "
              f"V={msd['v']}), sharded-vs-single max|err| "
              f"{msd['max_abs_err']:.2e}")
        out["merge_shard"] = msd

    if want("ingest"):
        _section("ingest (streaming freshness / speculation / compaction)")
        from benchmarks import ingest_bench
        ib = ingest_bench.run(n_docs=400 if args.quick else 800,
                              quick=args.quick)
        fr = ib["freshness"]
        print("batch,slice_lo,slice_hi,ingest_to_built_s,query_s,fresh,"
              "n_reused")
        for r in fr["rows"]:
            print(f"{r['batch']},{r['slice_lo']:.1f},{r['slice_hi']:.1f},"
                  f"{r['ingest_to_built_s']:.4f},{r['query_s']:.4f},"
                  f"{r['fresh']},{r['n_reused']}")
        print(f"# fresh-answered {fr['fresh_answered']}/{fr['queries']}, "
              f"builder lag mean {fr['freshness_lag_s_mean']:.3f}s "
              f"max {fr['freshness_lag_s_max']:.3f}s")
        sp = ib["speculation"]
        print("speculation,steady_p50_s,p95_s,hit_rate,segments")
        for label in ("off", "on"):
            m = sp[label]
            print(f"{label},{m['steady_p50_s']:.4f},{m['p95_s']:.4f},"
                  f"{m['hit_rate']:.2f},{m['speculated_segments']}")
        print(f"# steady-state hot-sigma speedup "
              f"{sp['steady_speedup']:.2f}x")
        cp = ib["compaction"]
        print(f"# compaction: {cp['bytes_before']} -> {cp['bytes_after']} "
              f"bytes (budget {cp['budget_bytes']}, under="
              f"{cp['under_budget']}), parts {cp['parts_before']} -> "
              f"{cp['parts_after']}, beta max|delta| "
              f"{cp['beta_max_abs_delta']:.2e}, topic overlap "
              f"{cp['topic_overlap']:.3f}")
        out["ingest"] = ib

    if want("chaos"):
        _section("chaos (serve goodput under injected faults)")
        from benchmarks import serve_bench
        cz = serve_bench.run_chaos(n_docs=600 if args.quick else 1200,
                                   quick=args.quick)
        rec = (f"{cz['recovery_s']:.3f}s" if cz["recovery_s"] is not None
               else "n/a")
        print(f"# chaos ({cz['fault_rate']:.0%} transient): goodput "
              f"{cz['goodput']:.3f} ({cz['answered']}/{cz['queries']}), "
              f"{cz['injected_failures']} faults, {cz['retries']} "
              f"retries, {cz['fallback_answers']} fallback answers")
        print(f"# breaker: opens {cz['breaker_opens']} (final "
              f"{cz['breaker_final_state']}), reroutes "
              f"{cz['breaker_reroutes']}, device-loss recovery {rec}, "
              f"workers_alive {cz['workers_alive']}")
        out["chaos"] = cz

    if want("obs"):
        _section("obs (tracing/metrics overhead)")
        from benchmarks import serve_bench
        ob = serve_bench.run_obs(n_docs=600 if args.quick else 1200,
                                 quick=args.quick,
                                 trace_path="BENCH_obs_trace.json")
        print(f"# overhead: untraced {ob['untraced_wall_s']:.3f}s vs "
              f"traced {ob['traced_wall_s']:.3f}s "
              f"({ob['overhead_frac']:+.2%}, budget <5%)")
        print(f"# spans: {ob['span_count']} across {ob['span_kinds']} "
              f"kinds; metrics: {ob['metric_lines']} exposition lines; "
              f"trace -> {ob['trace_path']}")
        out["obs"] = ob

    if want("kernels"):
        _section("kernels (interpret-mode parity timings)")
        from benchmarks import kernel_bench
        kernel_bench.run(quick=args.quick)

    if want("roofline"):
        _section("roofline (from dry-run artifacts)")
        import os
        from benchmarks import roofline
        if os.path.isdir("experiments/dryrun") and \
                os.listdir("experiments/dryrun"):
            rows = roofline.load("experiments/dryrun")
            print(roofline.render(rows, md=False))
        else:
            print("# no artifacts; run: PYTHONPATH=src python -m "
                  "repro.launch.dryrun")

    elapsed = time.perf_counter() - t_start
    print(f"\n# total bench time {elapsed:.1f}s")

    if args.json:
        doc = {"quick": args.quick, "sections": out, "elapsed_s": elapsed}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
