"""Benchmark harness — one section per paper table/figure.

  merging_effect      Fig. 3/6   perf loss vs #merges (+ rho refit)
  merging_efficiency  Fig. 7     SR vs ORIG / LDA* / OGS
  scalability         Fig. 8     SR vs corpus size
  coverage            Fig. 9     SR vs coverage ratio
  plan_search         Fig. 10-12 NAI/GRA/PSOA/PSOA++ times, alpha sweep
  batch_opt           Fig. 13/14 Alg. 4 cost & benefit
  session             (ours)     unified submit/submit_many API latency
  kernels             (ours)     Pallas kernel parity timings
  roofline            (ours)     table from dry-run artifacts, if present

All sections drive MLego through the ``repro.api`` session surface
(QuerySpec -> MLegoSession.submit); none construct the deprecated
``QueryEngine`` directly.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _section(name):
    print(f"\n### {name}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    sections = []

    def want(name):
        return args.only is None or args.only == name

    t_start = time.perf_counter()

    if want("merging_effect"):
        _section("merging_effect (Fig. 3/6)")
        from benchmarks import merging_effect
        rows, ploss = merging_effect.run(
            n_docs=600 if args.quick else 1200,
            parts=(1, 2, 4, 8) if args.quick else (1, 2, 4, 8, 16))
        print("n_parts,lpp_scratch,lpp_mvb,lpp_mgs,dp_mvb,dp_mgs")
        for r in rows:
            print(",".join(f"{v:.4f}" if isinstance(v, float) else str(v)
                           for v in r))
        print(f"# fitted PerformanceLoss rho = {ploss.rho:.5f}")

    if want("merging_efficiency"):
        _section("merging_efficiency (Fig. 7)")
        from benchmarks import merging_efficiency
        rows, t_mat = merging_efficiency.run(
            n_docs=600 if args.quick else 1500)
        print("method,time_s,lpp,SR")
        for name, t, lpp, sr in rows:
            print(f"{name},{t:.4f},{lpp:.4f},{sr:.2f}")
        print(f"# materialization {t_mat:.2f}s (offline)")

    if want("scalability"):
        _section("scalability (Fig. 8)")
        from benchmarks import merging_efficiency
        print("n_docs,method,time_s,SR")
        for n in ((400, 1000) if args.quick else (500, 1500, 4000)):
            rows, _ = merging_efficiency.run(n_docs=n)
            for name, t, _, sr in rows:
                print(f"{n},{name},{t:.4f},{sr:.2f}")

    if want("coverage"):
        _section("coverage (Fig. 9)")
        from benchmarks import coverage
        print("coverage,t_orig_s,t_mlego_s,SR,t_search_s,lpp")
        for r in coverage.run(n_docs=600 if args.quick else 1500):
            print(",".join(f"{v:.4f}" for v in r))

    if want("plan_search"):
        _section("plan_search (Fig. 10/11/12)")
        from benchmarks import plan_search
        print("n_models,alpha,nai_s,nai_scored,gra_s,gra_scored,"
              "psoa_s,psoa_scored,psoa++_s,psoa++_scored")
        sizes = (6, 10, 14) if args.quick else (6, 10, 14, 18, 22)
        for r in plan_search.run_sizes(sizes=sizes):
            print(",".join(f"{x:.6f}" if isinstance(x, float) else str(x)
                           for x in r))
        print("alpha,psoa_s,n_scored,n_layers,method")
        for r in plan_search.run_alpha():
            print(",".join(f"{x:.6f}" if isinstance(x, float) else str(x)
                           for x in r))

    if want("batch_opt"):
        _section("batch_opt (Fig. 13/14)")
        from benchmarks import batch_opt_bench
        print("batch,models,search_s,benefit,total_time,naive_time,"
              "oracle_time")
        bs = (2, 3) if args.quick else (2, 3, 4, 6)
        mp = (8, 16) if args.quick else (8, 16, 24)
        for r in batch_opt_bench.run(batch_sizes=bs, models_per=mp):
            print(",".join(f"{x:.6f}" if isinstance(x, float) else str(x)
                           for x in r))

    if want("session"):
        _section("session (unified API latency)")
        from benchmarks import session_bench
        rows, batch_row = session_bench.run(
            n_docs=600 if args.quick else 1200)
        print("label,search_s,train_s,merge_s,n_reused,n_trained_tokens")
        for label, s, t, m, nr, nt in rows:
            print(f"{label},{s:.4f},{t:.4f},{m:.4f},{nr},{nt}")
        print("# batch: shared_search_s,shared_train_s,merge_s,benefit,n")
        print("batch," + ",".join(
            f"{v:.4f}" if isinstance(v, float) else str(v)
            for v in batch_row))

    if want("kernels"):
        _section("kernels (interpret-mode parity timings)")
        from benchmarks import kernel_bench
        kernel_bench.run(quick=args.quick)

    if want("roofline"):
        _section("roofline (from dry-run artifacts)")
        import os
        from benchmarks import roofline
        if os.path.isdir("experiments/dryrun") and \
                os.listdir("experiments/dryrun"):
            rows = roofline.load("experiments/dryrun")
            print(roofline.render(rows, md=False))
        else:
            print("# no artifacts; run: PYTHONPATH=src python -m "
                  "repro.launch.dryrun")

    print(f"\n# total bench time {time.perf_counter() - t_start:.1f}s")


if __name__ == "__main__":
    main()
