"""Gibbs gap training: host exact scan vs doc-blocked device sweep.

The ROADMAP's last sequential host stage in the query hot path: when a
``gs``-kind query's interval is uncovered, ``submit()`` latency is
dominated by ``cgs_fit``'s per-token ``lax.scan``.  This section
measures the blocked replacement (``cgs_fit_blocked``; the
DeviceBackend gap-training route) against the exact scan on the same
partition — wall time (warm, compile excluded), speedup, and the
quality deltas (held-out lpp + greedy-matched top-word overlap) the
blocked approximation costs.  The host baseline is timed once and
shared across block widths (it does not depend on them).  Rows
accumulate in the CI bench JSON so ``BENCH_*.json`` tracks the
speedup trajectory across commits.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import jax

from benchmarks.common import bench_cfg, bench_world, lpp_of
from repro.core.gibbs import cgs_fit, cgs_fit_blocked
from repro.core.lda import greedy_topic_overlap, topics_from_gs


def _timed_fit(fn, repeat: int = 2) -> tuple:
    """(warm seconds, result): first call pays compile, last is timed."""
    out = fn()
    t = None
    for _ in range(max(repeat - 1, 1)):
        t0 = time.perf_counter()
        out = fn()
        t = time.perf_counter() - t0
    return t, out


def rows(quick: bool = False, n_docs: int = None,
         block_widths: Sequence[int] = None) -> List[Dict]:
    """One row per doc-block width (the parallelism/staleness knob)."""
    n_docs = (500 if quick else 1200) if n_docs is None else n_docs
    if block_widths is None:
        block_widths = (64, 32) if quick else (128, 64, 32)
    cfg = bench_cfg(quick)
    train, test, _, _ = bench_world(n_docs=n_docs, cfg=cfg)
    key = jax.random.PRNGKey(0)
    tokens, doc_ids = train.tokens, train.doc_ids

    t_host, nkv_host = _timed_fit(
        lambda: cgs_fit(tokens, doc_ids, cfg, key))
    beta_host = topics_from_gs(nkv_host, cfg.eta)
    lpp_host = lpp_of(beta_host, test)

    out = []
    for block_docs in block_widths:
        t_blocked, nkv_blocked = _timed_fit(
            lambda: cgs_fit_blocked(tokens, doc_ids, cfg, key,
                                    block_docs=block_docs))
        beta_blocked = topics_from_gs(nkv_blocked, cfg.eta)
        lpp_blocked = lpp_of(beta_blocked, test)
        out.append({
            "n_docs": train.n_docs,
            "n_tokens": train.n_tokens,
            "sweeps": cfg.gibbs_sweeps,
            "block_docs": block_docs,
            "n_blocks": -(-train.n_docs // block_docs),
            "host_scan_s": t_host,
            "blocked_s": t_blocked,
            "speedup": (t_host / t_blocked if t_blocked > 0
                        else float("inf")),
            "lpp_host": lpp_host,
            "lpp_blocked": lpp_blocked,
            "lpp_delta": lpp_blocked - lpp_host,
            "top_word_overlap": greedy_topic_overlap(beta_host,
                                                     beta_blocked),
        })
    return out


def run(n_docs: int = 1200, quick: bool = False,
        block_docs: int = 64) -> Dict:
    """Single-width convenience form of :func:`rows`."""
    return rows(quick=quick, n_docs=n_docs, block_widths=(block_docs,))[0]
