"""Paper Fig. 9: speedup ratio vs coverage ratio.

Materialize models covering X% of the query range; the query trains the
rest.  SR = t_from_scratch / t_mlego per coverage level.  At 100% the
model is merged in milliseconds and plan-search cost becomes visible
(the paper's motivation for PSOA).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import BENCH_CFG, bench_world, lpp_of, timed
from repro.api import Interval, MLegoSession, QuerySpec
from repro.core.store import ModelStore
from repro.core.vb import vb_fit
from repro.data.corpus import doc_term_matrix


def run(n_docs=1500, coverages=(0.0, 0.25, 0.5, 0.75, 1.0), seed=0):
    cfg = BENCH_CFG
    train, test, index, _ = bench_world(n_docs=n_docs, seed=seed)
    lo, hi = 0.0, float(train.attr[-1]) + 1.0

    x_all = doc_term_matrix(train)
    t_orig, _ = timed(
        lambda: np.asarray(vb_fit(x_all, jax.random.PRNGKey(seed), cfg)))

    rows = []
    for cov in coverages:
        store = ModelStore()
        # cover [lo, lo + cov*(hi-lo)) with 4 materialized pieces
        edge = lo + cov * (hi - lo)
        if cov > 0:
            warm = MLegoSession(train, cfg, store=store, kind="vb")
            for a, b in zip(np.linspace(lo, edge, 5),
                            np.linspace(lo, edge, 5)[1:]):
                warm.train_range(float(a), float(b))
        session = MLegoSession(train, cfg, store=store, kind="vb")
        t_mlego, rep = timed(session.submit,
                             QuerySpec(sigma=Interval(lo, hi), alpha=0.0))
        rows.append((cov, t_orig, t_mlego, t_orig / t_mlego,
                     rep.search_s, lpp_of(rep.beta, test)))
    return rows


def main():
    print("coverage,t_orig_s,t_mlego_s,SR,t_search_s,lpp")
    for r in run():
        print(",".join(f"{v:.4f}" for v in r))


if __name__ == "__main__":
    main()
