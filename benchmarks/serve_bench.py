"""Concurrent-traffic serving benchmark: coalesced service vs serial
session on one synthetic trace.

The trace models n interactive analysts over one shared store whose
capital covers half the corpus: every client repeatedly asks volatile
queries whose plans reuse the covered half and train the uncovered
half.  The serial baseline answers the whole trace through one
blocking ``MLegoSession.submit`` loop — every query pays its own gap
training.  The service answers the same trace submitted concurrently:
queries landing inside the coalescing window fuse into ``submit_many``
batches, so each round's shared gap segment trains ~once instead of
once per client — which is exactly the §V.C sharing the paper builds
Alg. 4 for, harvested at serve time.

``run`` reports wall-clock throughput and client-observed p50/p95
latency for both modes plus the realized coalesce width;
``run_cross_session`` demonstrates end-to-end cross-session reuse (the
acceptance check): a repeated query from a *second* session over the
shared store reports ``plan_cached=True`` and reads the first
session's device-resident parameters as cache hits.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

from benchmarks.common import bench_cfg, bench_world
from repro.api import (
    DeviceBackend,
    Interval,
    MLegoSession,
    PlanCache,
    QuerySpec,
)
from repro.core.store import ModelStore
from repro.serve import MLegoService


def _percentile(xs: List[float], p: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(int(round(p / 100.0 * (len(xs) - 1))), len(xs) - 1)
    return xs[i]


def _trace(hi: float, per_client: int) -> List[QuerySpec]:
    """One client's query sequence: volatile full-range explorations
    (reuse the covered half, train the uncovered half) with a narrower
    pan every other round."""
    specs = []
    for r in range(per_client):
        if r % 2 == 0:
            specs.append(QuerySpec(sigma=Interval(0.0, hi),
                                   materialize="volatile"))
        else:
            specs.append(QuerySpec(sigma=Interval(0.25 * hi, hi),
                                   materialize="volatile"))
    return specs


def _summary(lat: List[float], wall: float) -> Dict[str, float]:
    return {
        "queries": len(lat),
        "wall_s": wall,
        "qps": len(lat) / wall if wall > 0 else 0.0,
        "p50_s": _percentile(lat, 50.0),
        "p95_s": _percentile(lat, 95.0),
    }


def run(n_docs=600, seed=0, quick=False, n_clients=4, per_client=4,
        window_s=0.1) -> Dict:
    cfg = bench_cfg(quick)
    train, _, _, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0
    capital = [(i * hi / 4, (i + 1) * hi / 4) for i in range(2)]

    # --- serial baseline: one blocking session, whole trace in order ---
    sess = MLegoSession(train, cfg, kind="vb", seed=seed)
    for lo, hi_e in capital:
        sess.train_range(lo, hi_e)
    serial_lat: List[float] = []
    t0 = time.perf_counter()
    for _ in range(n_clients):
        for spec in _trace(hi, per_client):
            t = time.perf_counter()
            sess.submit(spec)
            serial_lat.append(time.perf_counter() - t)
    serial_wall = time.perf_counter() - t0

    # --- coalesced service: same trace, n concurrent clients -----------
    svc = MLegoService(train, cfg, kind="vb", seed=seed,
                       window_s=window_s, max_width=2 * n_clients)
    for lo, hi_e in capital:
        svc.train_range(lo, hi_e)
    svc_lat: List[float] = []
    lat_lock = threading.Lock()

    def client(name: str) -> None:
        for spec in _trace(hi, per_client):
            t = time.perf_counter()
            svc.submit(spec, tenant=name).result()
            with lat_lock:
                svc_lat.append(time.perf_counter() - t)

    threads = [threading.Thread(target=client, args=(f"client{i}",))
               for i in range(n_clients)]
    t1 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc_wall = time.perf_counter() - t1
    report = svc.report()
    svc.close()

    serial = _summary(serial_lat, serial_wall)
    coalesced = _summary(svc_lat, svc_wall)
    return {
        "n_clients": n_clients,
        "per_client": per_client,
        "window_s": window_s,
        "serial": serial,
        "coalesced": coalesced,
        "speedup": serial["wall_s"] / coalesced["wall_s"]
        if coalesced["wall_s"] > 0 else 0.0,
        "mean_coalesce_width": report.mean_coalesce_width,
        "max_coalesce_width": report.max_coalesce_width,
        "coalesce_rate": report.coalesce_rate,
        "plan_cache_hits": report.plan_cache_hits,
        "plan_cache_misses": report.plan_cache_misses,
    }


def run_cross_session(n_docs=600, seed=0, quick=False) -> Dict:
    """The acceptance demonstration: session B repeats session A's
    query over the shared store/plan-cache/device-LRU and must report
    ``plan_cached=True`` with device-cache hits > 0."""
    cfg = bench_cfg(quick)
    train, _, _, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0

    store, backend, cache = ModelStore(), DeviceBackend(), PlanCache()
    a = MLegoSession(train, cfg, store=store, backend=backend,
                     plan_cache=cache, kind="vb", seed=0)
    b = MLegoSession(train, cfg, store=store, backend=backend,
                     plan_cache=cache, kind="vb", seed=1)
    for i in range(4):
        a.train_range(i * hi / 4, (i + 1) * hi / 4)
    spec = QuerySpec(sigma=Interval(0.0, hi), alpha=1.0)
    ra = a.submit(spec)
    rb = b.submit(spec)
    return {
        "first_plan_cached": ra.plan_cached,
        "second_plan_cached": rb.plan_cached,
        "second_cache_hits": rb.cache_hits,
        "second_cache_misses": rb.cache_misses,
        "second_merge_device_ms": rb.merge_device_ms,
    }


def main() -> None:
    out = run()
    s, c = out["serial"], out["coalesced"]
    print("mode,queries,wall_s,qps,p50_s,p95_s")
    print(f"serial,{s['queries']},{s['wall_s']:.3f},{s['qps']:.2f},"
          f"{s['p50_s']:.4f},{s['p95_s']:.4f}")
    print(f"coalesced,{c['queries']},{c['wall_s']:.3f},{c['qps']:.2f},"
          f"{c['p50_s']:.4f},{c['p95_s']:.4f}")
    print(f"# speedup {out['speedup']:.2f}x, mean width "
          f"{out['mean_coalesce_width']:.2f}, max {out['max_coalesce_width']}")
    cross = run_cross_session()
    print(f"# cross-session: plan_cached={cross['second_plan_cached']} "
          f"hits={cross['second_cache_hits']} "
          f"misses={cross['second_cache_misses']}")


if __name__ == "__main__":
    main()
