"""Concurrent-traffic serving benchmark: coalesced service vs serial
session on one synthetic trace.

The trace models n interactive analysts over one shared store whose
capital covers half the corpus: every client repeatedly asks volatile
queries whose plans reuse the covered half and train the uncovered
half.  The serial baseline answers the whole trace through one
blocking ``MLegoSession.submit`` loop — every query pays its own gap
training.  The service answers the same trace submitted concurrently:
queries landing inside the coalescing window fuse into ``submit_many``
batches, so each round's shared gap segment trains ~once instead of
once per client — which is exactly the §V.C sharing the paper builds
Alg. 4 for, harvested at serve time.

``run`` reports wall-clock throughput and client-observed p50/p95
latency for both modes plus the realized coalesce width;
``run_cross_session`` demonstrates end-to-end cross-session reuse (the
acceptance check): a repeated query from a *second* session over the
shared store reports ``plan_cached=True`` and reads the first
session's device-resident parameters as cache hits.

Production-hardening benches:

``run_open_loop`` drives a thousand-tenant *open-loop* trace (arrivals
at a fixed rate, independent of completions — the regime where an
unprotected queue grows without bound) against the admission-
controlled service: a bounded queue plus per-query ``max_queue_wait_s``
sheds the excess, the SLO loop degrades α under load, and the idle-TTL
sweep recycles tenant sessions.  It reports answered-query p50/p95/p99
(ms), the shed rate, and the degraded fraction — the acceptance check
is shed rate > 0 *with* answered p95 still inside the SLO.

``run_pool_comparison`` replays one mixed host/device trace through
the per-backend worker pools and through the pre-hardening single-loop
topology (``pool_per_backend=False``, one worker): pools let host and
device groups execute concurrently instead of serializing.

``run_obs`` replays the trace with the tracer disabled vs enabled and
asserts the tracing overhead stays under 5 % of wall; it also exports
the traced run's Chrome trace (Perfetto-loadable) and reports span /
metric cardinality — the observability layer must stay free enough to
leave on in production.

``run_chaos`` replays the trace under the deterministic fault
injector: a configurable transient rate on the merge/fetch/store
sites plus one injected device loss mid-trace.  It reports goodput
(answered fraction), the retry ledger, breaker transition counts, the
reroute count while the device backend sat quarantined, and the
recovery time from device loss to the first post-probe device-served
answer — the acceptance check is goodput ≈ 1 with zero worker deaths.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

from benchmarks.common import bench_cfg, bench_world
from repro.api import (
    DeviceBackend,
    Interval,
    MLegoSession,
    PlanCache,
    QuerySpec,
    Tracer,
)
from repro.core.store import ModelStore
from repro.serve import BreakerPolicy, MLegoService, ShedError, SLOPolicy
from repro.testing.faults import FaultInjector, FaultRule, injected


def _percentile(xs: List[float], p: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(int(round(p / 100.0 * (len(xs) - 1))), len(xs) - 1)
    return xs[i]


def _trace(hi: float, per_client: int) -> List[QuerySpec]:
    """One client's query sequence: volatile full-range explorations
    (reuse the covered half, train the uncovered half) with a narrower
    pan every other round."""
    specs = []
    for r in range(per_client):
        if r % 2 == 0:
            specs.append(QuerySpec(sigma=Interval(0.0, hi),
                                   materialize="volatile"))
        else:
            specs.append(QuerySpec(sigma=Interval(0.25 * hi, hi),
                                   materialize="volatile"))
    return specs


def _summary(lat: List[float], wall: float) -> Dict[str, float]:
    return {
        "queries": len(lat),
        "wall_s": wall,
        "qps": len(lat) / wall if wall > 0 else 0.0,
        "p50_s": _percentile(lat, 50.0),
        "p95_s": _percentile(lat, 95.0),
    }


def run(n_docs=600, seed=0, quick=False, n_clients=4, per_client=4,
        window_s=0.1) -> Dict:
    cfg = bench_cfg(quick)
    train, _, _, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0
    capital = [(i * hi / 4, (i + 1) * hi / 4) for i in range(2)]

    # --- serial baseline: one blocking session, whole trace in order ---
    sess = MLegoSession(train, cfg, kind="vb", seed=seed)
    for lo, hi_e in capital:
        sess.train_range(lo, hi_e)
    serial_lat: List[float] = []
    t0 = time.perf_counter()
    for _ in range(n_clients):
        for spec in _trace(hi, per_client):
            t = time.perf_counter()
            sess.submit(spec)
            serial_lat.append(time.perf_counter() - t)
    serial_wall = time.perf_counter() - t0

    # --- coalesced service: same trace, n concurrent clients -----------
    svc = MLegoService(train, cfg, kind="vb", seed=seed,
                       window_s=window_s, max_width=2 * n_clients)
    for lo, hi_e in capital:
        svc.train_range(lo, hi_e)
    svc_lat: List[float] = []
    lat_lock = threading.Lock()

    def client(name: str) -> None:
        for spec in _trace(hi, per_client):
            t = time.perf_counter()
            svc.submit(spec, tenant=name).result()
            with lat_lock:
                svc_lat.append(time.perf_counter() - t)

    threads = [threading.Thread(target=client, args=(f"client{i}",))
               for i in range(n_clients)]
    t1 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc_wall = time.perf_counter() - t1
    report = svc.report()
    svc.close()

    serial = _summary(serial_lat, serial_wall)
    coalesced = _summary(svc_lat, svc_wall)
    return {
        "n_clients": n_clients,
        "per_client": per_client,
        "window_s": window_s,
        "serial": serial,
        "coalesced": coalesced,
        "speedup": serial["wall_s"] / coalesced["wall_s"]
        if coalesced["wall_s"] > 0 else 0.0,
        "mean_coalesce_width": report.mean_coalesce_width,
        "max_coalesce_width": report.max_coalesce_width,
        "coalesce_rate": report.coalesce_rate,
        "plan_cache_hits": report.plan_cache_hits,
        "plan_cache_misses": report.plan_cache_misses,
    }


def _drive_trace(svc, hi: float, n_clients: int,
                 per_client: int) -> float:
    """Replay the standard concurrent trace; returns wall seconds."""
    def client(name: str) -> None:
        for spec in _trace(hi, per_client):
            svc.submit(spec, tenant=name).result()

    threads = [threading.Thread(target=client, args=(f"client{i}",))
               for i in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0


def run_obs(n_docs=600, seed=0, quick=False, n_clients=4, per_client=4,
            window_s=0.05, repeats=2, trace_path=None) -> Dict:
    """Observability overhead: the same concurrent trace through a
    service with the tracer disabled vs enabled (metrics run in both
    cases — they are always on).  Each mode gets its own service over
    its own store; one untraced warm-up run absorbs jit compilation.
    Walls are min-of-``repeats``; the acceptance check (asserted) is
    traced wall within 5 % of untraced.  When ``trace_path`` is given
    the last traced run's Chrome trace is exported there, and the
    result reports its span count / kind cardinality plus the metrics
    exposition size."""
    cfg = bench_cfg(quick)
    train, _, _, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0
    capital = [(i * hi / 4, (i + 1) * hi / 4) for i in range(2)]

    def one_run(enabled: bool):
        svc = MLegoService(train, cfg, kind="vb", seed=seed,
                           window_s=window_s, max_width=2 * n_clients,
                           tracer=Tracer(capacity=1 << 16,
                                         enabled=enabled))
        for lo, hi_e in capital:
            svc.train_range(lo, hi_e)
        wall = _drive_trace(svc, hi, n_clients, per_client)
        spans = svc.tracer.spans()
        metric_lines = sum(1 for line in svc.metrics_text().splitlines()
                           if line and not line.startswith("#"))
        rep = svc.report()
        if enabled and trace_path:
            svc.export_trace(trace_path)
        svc.close()
        return wall, spans, metric_lines, rep

    one_run(False)                               # warm-up: compile jits
    untraced = min(one_run(False)[0] for _ in range(repeats))
    traced_runs = [one_run(True) for _ in range(repeats)]
    traced = min(w for w, _, _, _ in traced_runs)
    wall, spans, metric_lines, rep = traced_runs[-1]
    overhead = traced / untraced - 1.0
    assert overhead < 0.05, (
        f"tracing overhead {overhead:.1%} exceeds the 5% budget "
        f"(untraced {untraced:.3f}s, traced {traced:.3f}s)")
    return {
        "queries": n_clients * per_client,
        "untraced_wall_s": untraced,
        "traced_wall_s": traced,
        "overhead_frac": overhead,
        "span_count": len(spans),
        "span_kinds": len({s.name for s in spans}),
        "metric_lines": metric_lines,
        "mean_coalesce_width": rep.mean_coalesce_width,
        "trace_path": trace_path,
    }


def run_cross_session(n_docs=600, seed=0, quick=False) -> Dict:
    """The acceptance demonstration: session B repeats session A's
    query over the shared store/plan-cache/device-LRU and must report
    ``plan_cached=True`` with device-cache hits > 0."""
    cfg = bench_cfg(quick)
    train, _, _, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0

    store, backend, cache = ModelStore(), DeviceBackend(), PlanCache()
    a = MLegoSession(train, cfg, store=store, backend=backend,
                     plan_cache=cache, kind="vb", seed=0)
    b = MLegoSession(train, cfg, store=store, backend=backend,
                     plan_cache=cache, kind="vb", seed=1)
    for i in range(4):
        a.train_range(i * hi / 4, (i + 1) * hi / 4)
    spec = QuerySpec(sigma=Interval(0.0, hi), alpha=1.0)
    ra = a.submit(spec)
    rb = b.submit(spec)
    return {
        "first_plan_cached": ra.plan_cached,
        "second_plan_cached": rb.plan_cached,
        "second_cache_hits": rb.cache_hits,
        "second_cache_misses": rb.cache_misses,
        "second_merge_device_ms": rb.merge_device_ms,
    }


def run_open_loop(n_docs=600, seed=0, quick=False, n_tenants=1000,
                  n_arrivals=None, overload=2.0, max_queue=64) -> Dict:
    """Open-loop thousand-tenant trace against the hardened front door.

    Arrivals are paced at ``overload``× the service's measured serve
    rate, round-robin over ``n_tenants`` distinct tenants, each query a
    *distinct* sliding predicate (every plan search is cold — the
    realistic overload source).  Admission control keeps answered
    latency bounded: the queue is capped at ``max_queue`` and every
    query carries ``max_queue_wait_s`` at half the SLO, so under
    sustained overload the excess sheds instead of queueing; the SLO
    loop additionally degrades α once the latency window heats up.
    """
    cfg = bench_cfg(quick)
    train, _, _, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0
    if quick:
        n_tenants = min(n_tenants, 100)
    if n_arrivals is None:
        n_arrivals = 80 if quick else 2 * n_tenants

    def spec_for(i: int) -> QuerySpec:
        lo = (i * 0.37 * hi) % (hi / 2)          # sliding pan: cold plans
        return QuerySpec(sigma=Interval(lo, lo + hi / 2), alpha=1.0,
                         materialize="volatile")

    # calibrate the serve rate on a throwaway service (same capital)
    probe = MLegoService(train, cfg, kind="vb", seed=seed, window_s=0.0)
    probe.train_range(0.0, hi / 2)
    t0 = time.perf_counter()
    n_probe = 5
    for i in range(1, n_probe + 1):          # i=0 has no gap: too cheap
        probe.submit(spec_for(i)).result()
    t_q = (time.perf_counter() - t0) / n_probe
    probe.close()

    # answered latency = queue wait (≤ wait_s) + the query's fused
    # group's execution (≤ max_width × t_q): budget both inside the
    # SLO, with one worker so executions never contend for the core
    slo_s = 8.0 * t_q
    wait_s = slo_s / 4.0
    max_width = 2
    gap_s = t_q / overload
    policy = SLOPolicy(p95_slo_s=slo_s, min_samples=16,
                       degrade_at=0.25, heavy_at=0.5, severe_at=1.0)

    svc = MLegoService(train, cfg, kind="vb", seed=seed, window_s=0.0,
                       max_width=max_width, workers_per_pool=1,
                       max_queue=max_queue, slo=policy,
                       slo_window=max(n_arrivals, 256),
                       tenant_ttl_s=max(20.0 * t_q, 1.0))
    svc.train_range(0.0, hi / 2)

    lats: List[float] = []
    lock = threading.Lock()
    futures = []
    door_shed = 0
    t_open = time.perf_counter()
    for i in range(n_arrivals):
        tenant = f"t{i % n_tenants}"
        t_sub = time.perf_counter()
        try:
            fut = svc.submit(spec_for(i), tenant=tenant,
                             max_queue_wait_s=wait_s, deadline_s=slo_s)
        except ShedError:
            door_shed += 1
        else:
            def _done(f, t=t_sub):
                try:
                    f.result()
                except Exception:
                    pass                         # shed/expired: counted below
                else:
                    with lock:
                        lats.append(time.perf_counter() - t)
            fut.add_done_callback(_done)
            futures.append(fut)
        sleep = gap_s - (time.perf_counter() - t_sub)
        if sleep > 0:
            time.sleep(sleep)
    for f in futures:
        try:
            f.result(timeout=600)
        except Exception:
            pass
    wall = time.perf_counter() - t_open
    report = svc.report()
    svc.close()

    with lock:
        answered = sorted(lats)
    p = lambda q: (_percentile(answered, q) * 1e3)  # noqa: E731
    p95_ms = p(95.0)
    return {
        "n_tenants": n_tenants,
        "arrivals": n_arrivals,
        "overload": overload,
        "gap_ms": gap_s * 1e3,
        "slo_ms": slo_s * 1e3,
        "answered": len(answered),
        "p50_ms": p(50.0),
        "p95_ms": p95_ms,
        "p99_ms": p(99.0),
        "shed": report.shed,
        "deadline_rejected": report.deadline_rejected,
        "shed_rate": report.shed_rate,
        "degraded_frac": report.degraded_frac,
        "tenant_evictions": report.tenant_evictions,
        "active_sessions": report.active_sessions,
        "p95_within_slo": p95_ms <= slo_s * 1e3,
        "wall_s": wall,
    }


def run_pool_comparison(n_docs=600, seed=0, quick=False, n_clients=4,
                        per_client=3) -> Dict:
    """Mixed host/device closed-loop trace: per-backend worker pools vs
    the single-loop baseline topology.  Each client alternates host and
    device merge-heavy queries; pools execute the two backends'
    groups concurrently, the single loop serializes them."""
    cfg = bench_cfg(quick)
    train, _, _, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0
    if quick:
        per_client = 2

    def trace(i: int) -> List[QuerySpec]:
        out = []
        for r in range(per_client):
            backend = "device" if (i + r) % 2 else "host"
            out.append(QuerySpec(sigma=Interval(0.0, hi), alpha=1.0,
                                 materialize="volatile", backend=backend))
        return out

    def drive(pool_per_backend: bool, workers: int) -> Dict[str, float]:
        svc = MLegoService(train, cfg, kind="vb", seed=seed,
                           window_s=0.01, max_width=2 * n_clients,
                           pool_per_backend=pool_per_backend,
                           workers_per_pool=workers)
        for i in range(4):
            svc.train_range(i * hi / 4, (i + 1) * hi / 4)
        lats: List[float] = []
        lock = threading.Lock()

        def client(i: int) -> None:
            for spec in trace(i):
                t = time.perf_counter()
                svc.submit(spec, tenant=f"c{i}").result()
                with lock:
                    lats.append(time.perf_counter() - t)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        svc.close()
        return _summary(lats, wall)

    single = drive(pool_per_backend=False, workers=1)
    pooled = drive(pool_per_backend=True, workers=2)
    return {
        "n_clients": n_clients,
        "per_client": per_client,
        "single_loop": single,
        "pooled": pooled,
        "pool_speedup": single["wall_s"] / pooled["wall_s"]
        if pooled["wall_s"] > 0 else 0.0,
    }


def run_chaos(n_docs=600, seed=0, quick=False, fault_rate=0.1,
              n_queries=None) -> Dict:
    """Closed-loop trace under deterministic chaos.

    ``fault_rate`` transient injection on the merge, fetch and store
    sites, plus exactly one device loss a quarter of the way in.  The
    retry layer must absorb the transients, the session fallback chain
    must answer through the loss, the breaker must open/reroute/probe/
    close, and no worker thread may die — goodput stays ≈ 1.
    """
    cfg = bench_cfg(quick)
    train, _, _, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0
    if n_queries is None:
        n_queries = 32 if quick else 120
    cooldown = 0.2 if quick else 1.0

    svc = MLegoService(train, cfg, kind="vb", seed=seed, window_s=0.0,
                       backend="device",
                       breaker=BreakerPolicy(cooldown_s=cooldown))
    svc.train_range(0.0, hi / 2)

    def spec_for(i: int) -> QuerySpec:
        lo = (i * 0.31 * hi) % (hi / 2)
        return QuerySpec(sigma=Interval(lo, lo + hi / 2), alpha=1.0,
                         materialize="volatile")

    inj = FaultInjector([
        FaultRule("backend.merge", rate=fault_rate),
        FaultRule("backend.fetch", rate=fault_rate),
        FaultRule("store.get", rate=fault_rate),
        FaultRule("backend.merge.device", rate=1.0, kind="device_lost",
                  after=max(2, n_queries // 4), max_failures=1),
    ], seed=seed)

    answered = failed = fallback_answers = 0
    t_loss = t_recovered = None
    t0 = time.perf_counter()
    with injected(inj):
        for i in range(n_queries):
            try:
                rep = svc.submit(spec_for(i)).result(timeout=600)
            except Exception:
                failed += 1
                continue
            answered += 1
            now = time.perf_counter()
            if rep.fallback_from is not None:
                fallback_answers += 1
                if t_loss is None:
                    t_loss = now
            elif t_loss is not None and t_recovered is None \
                    and rep.backend == "device":
                t_recovered = now
    wall = time.perf_counter() - t0
    report = svc.report()
    workers_alive = all(t.is_alive() for p in svc._pools_snapshot()
                        for t in p.threads)
    svc.close()

    dev = report.breaker.get("device")
    return {
        "fault_rate": fault_rate,
        "queries": n_queries,
        "answered": answered,
        "failed": failed,
        "goodput": answered / n_queries if n_queries else 0.0,
        "injected_failures": inj.total_failures,
        "retries": sum(report.retries.values()),
        "retries_by_site": dict(report.retries),
        "fallback_answers": fallback_answers,
        "breaker_opens": dev.opens if dev is not None else 0,
        "breaker_final_state": dev.state if dev is not None else "n/a",
        "breaker_reroutes": report.breaker_reroutes,
        "recovery_s": (t_recovered - t_loss)
        if t_loss is not None and t_recovered is not None else None,
        "workers_alive": workers_alive,
        "wall_s": wall,
    }


def main() -> None:
    out = run()
    s, c = out["serial"], out["coalesced"]
    print("mode,queries,wall_s,qps,p50_s,p95_s")
    print(f"serial,{s['queries']},{s['wall_s']:.3f},{s['qps']:.2f},"
          f"{s['p50_s']:.4f},{s['p95_s']:.4f}")
    print(f"coalesced,{c['queries']},{c['wall_s']:.3f},{c['qps']:.2f},"
          f"{c['p50_s']:.4f},{c['p95_s']:.4f}")
    print(f"# speedup {out['speedup']:.2f}x, mean width "
          f"{out['mean_coalesce_width']:.2f}, max {out['max_coalesce_width']}")
    cross = run_cross_session()
    print(f"# cross-session: plan_cached={cross['second_plan_cached']} "
          f"hits={cross['second_cache_hits']} "
          f"misses={cross['second_cache_misses']}")
    ol = run_open_loop(quick=True)
    print(f"# open-loop: {ol['arrivals']} arrivals over "
          f"{ol['n_tenants']} tenants, p50 {ol['p50_ms']:.1f}ms "
          f"p95 {ol['p95_ms']:.1f}ms p99 {ol['p99_ms']:.1f}ms, "
          f"shed_rate {ol['shed_rate']:.3f}, degraded_frac "
          f"{ol['degraded_frac']:.3f}, p95_within_slo "
          f"{ol['p95_within_slo']} (slo {ol['slo_ms']:.1f}ms)")
    pc = run_pool_comparison(quick=True)
    print(f"# pools: single-loop {pc['single_loop']['wall_s']:.2f}s vs "
          f"pooled {pc['pooled']['wall_s']:.2f}s "
          f"({pc['pool_speedup']:.2f}x)")
    ob = run_obs(quick=True)
    print(f"# obs: untraced {ob['untraced_wall_s']:.3f}s vs traced "
          f"{ob['traced_wall_s']:.3f}s ({ob['overhead_frac']:+.2%}), "
          f"{ob['span_count']} spans / {ob['span_kinds']} kinds, "
          f"{ob['metric_lines']} metric lines")
    ch = run_chaos(quick=True)
    rec = f"{ch['recovery_s']:.3f}s" if ch['recovery_s'] is not None \
        else "n/a"
    print(f"# chaos ({ch['fault_rate']:.0%} transient): goodput "
          f"{ch['goodput']:.3f} ({ch['answered']}/{ch['queries']}), "
          f"{ch['injected_failures']} faults, {ch['retries']} retries, "
          f"{ch['fallback_answers']} fallback answers, breaker opens "
          f"{ch['breaker_opens']} (final {ch['breaker_final_state']}), "
          f"reroutes {ch['breaker_reroutes']}, recovery {rec}, "
          f"workers_alive {ch['workers_alive']}")


if __name__ == "__main__":
    main()
