"""Session-API latency: the unified submit/submit_many path.

Measures what an interactive client sees through ``repro.api``:
per-query cost breakdown (search / train / merge) over a warming
store, a union-of-intervals query, and a batch with Alg. 4 shared
training — shared costs read from the ``BatchReport`` (batch-level),
per-query latencies from the individual reports.

The device-backend pass replays a repeated-query workload against the
Pallas execution backend and reports the device cache hit rate plus
the fused-launch wall time (``merge_device_ms``) — the counters the
tentpole acceptance criteria track.
"""
from __future__ import annotations

from benchmarks.common import bench_cfg, bench_world
from repro.api import Interval, MLegoSession, QuerySpec


def run(n_docs=1200, seed=0, quick=False, backend="host"):
    cfg = bench_cfg(quick)
    train, test, index, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0
    session = MLegoSession(train, cfg, kind="vb", backend=backend)

    rows = []
    sequence = [
        ("cold_full", QuerySpec(sigma=Interval(0.0, hi), alpha=0.0)),
        ("warm_full", QuerySpec(sigma=Interval(0.0, hi), alpha=0.0)),
        ("warm_half", QuerySpec(sigma=Interval(0.0, hi / 2), alpha=0.5)),
        ("union", QuerySpec(sigma=[Interval(0.0, hi / 4),
                                   Interval(hi / 2, 0.75 * hi)], alpha=0.5)),
    ]
    for label, spec in sequence:
        rep = session.submit(spec)
        rows.append((label, rep.search_s, rep.train_s, rep.merge_s,
                     rep.n_reused, rep.n_trained_tokens))

    batch = session.submit_many([
        QuerySpec(sigma=Interval(0.0, 0.6 * hi)),
        QuerySpec(sigma=Interval(0.3 * hi, 0.9 * hi)),
        QuerySpec(sigma=Interval(0.1 * hi, hi)),
    ])
    batch_row = (batch.shared_search_s, batch.shared_train_s,
                 batch.merge_s, batch.benefit, len(batch))
    return rows, batch_row


def run_device_cache(n_docs=1200, seed=0, quick=False, repeats=3):
    """Repeated-query workload on the device backend.

    Warms the store once, then replays the same full-range query
    ``repeats`` times: the first replay uploads every plan model into
    the device cache, the rest must hit.  Returns per-replay rows
    (hits, misses, merge_device_ms) plus the backend's cumulative
    hit rate.
    """
    cfg = bench_cfg(quick)
    train, _, _, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0
    session = MLegoSession(train, cfg, kind="vb", backend="device")

    # build capital so replays are pure merges
    edges = [i * hi / 4 for i in range(5)]
    for lo, hi_e in zip(edges, edges[1:]):
        session.train_range(lo, hi_e)

    spec = QuerySpec(sigma=Interval(0.0, hi), alpha=1.0)
    rows = []
    for i in range(repeats):
        rep = session.submit(spec)
        rows.append((f"replay_{i}", rep.cache_hits, rep.cache_misses,
                     rep.merge_device_ms, rep.merge_s))
    return rows, session.backend.stats.hit_rate


def main():
    rows, batch_row = run()
    print("label,search_s,train_s,merge_s,n_reused,n_trained_tokens")
    for label, s, t, m, nr, nt in rows:
        print(f"{label},{s:.4f},{t:.4f},{m:.4f},{nr},{nt}")
    print("# batch: shared_search_s,shared_train_s,merge_s,benefit,n")
    print("batch," + ",".join(f"{v:.4f}" if isinstance(v, float) else str(v)
                              for v in batch_row))
    dev_rows, hit_rate = run_device_cache()
    print("label,cache_hits,cache_misses,merge_device_ms,merge_s")
    for label, h, mi, dms, ms in dev_rows:
        print(f"{label},{h},{mi},{dms:.3f},{ms:.4f}")
    print(f"# device cache hit-rate {hit_rate:.3f}")


if __name__ == "__main__":
    main()
