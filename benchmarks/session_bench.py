"""Session-API latency: the unified submit/submit_many path.

Measures what an interactive client sees through ``repro.api``:
per-query cost breakdown (search / train / merge) over a warming
store, a union-of-intervals query, and a batch with Alg. 4 shared
training — shared costs read from the ``BatchReport`` (batch-level),
per-query latencies from the individual reports.
"""
from __future__ import annotations

from benchmarks.common import BENCH_CFG, bench_world
from repro.api import Interval, MLegoSession, QuerySpec


def run(n_docs=1200, seed=0):
    cfg = BENCH_CFG
    train, test, index, _ = bench_world(n_docs=n_docs, seed=seed)
    hi = float(train.attr[-1]) + 1.0
    session = MLegoSession(train, cfg, kind="vb")

    rows = []
    sequence = [
        ("cold_full", QuerySpec(sigma=Interval(0.0, hi), alpha=0.0)),
        ("warm_full", QuerySpec(sigma=Interval(0.0, hi), alpha=0.0)),
        ("warm_half", QuerySpec(sigma=Interval(0.0, hi / 2), alpha=0.5)),
        ("union", QuerySpec(sigma=[Interval(0.0, hi / 4),
                                   Interval(hi / 2, 0.75 * hi)], alpha=0.5)),
    ]
    for label, spec in sequence:
        rep = session.submit(spec)
        rows.append((label, rep.search_s, rep.train_s, rep.merge_s,
                     rep.n_reused, rep.n_trained_tokens))

    batch = session.submit_many([
        QuerySpec(sigma=Interval(0.0, 0.6 * hi)),
        QuerySpec(sigma=Interval(0.3 * hi, 0.9 * hi)),
        QuerySpec(sigma=Interval(0.1 * hi, hi)),
    ])
    batch_row = (batch.shared_search_s, batch.shared_train_s,
                 batch.merge_s, batch.benefit, len(batch))
    return rows, batch_row


def main():
    rows, batch_row = run()
    print("label,search_s,train_s,merge_s,n_reused,n_trained_tokens")
    for label, s, t, m, nr, nt in rows:
        print(f"{label},{s:.4f},{t:.4f},{m:.4f},{nr},{nt}")
    print("# batch: shared_search_s,shared_train_s,merge_s,benefit,n")
    print("batch," + ",".join(f"{v:.4f}" if isinstance(v, float) else str(v)
                              for v in batch_row))


if __name__ == "__main__":
    main()
