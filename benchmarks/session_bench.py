"""Session-API latency: the unified submit/submit_many path.

Measures what an interactive client sees through ``repro.api``:
per-query cost breakdown (search / train / merge) over a warming
store, a union-of-intervals query, and a batch with Alg. 4 shared
training — shared costs read from the ``BatchReport`` (batch-level),
per-query latencies from the individual reports.

The device-backend pass replays a repeated-query workload against the
Pallas execution backend and reports the device cache hit rate plus
the fused-launch wall time (``merge_device_ms``).

``run_providers`` replays one repeated interactive workload twice on
the device backend — once under the analytic cost provider, once under
the calibrated provider — and reports measured per-submit latency and
plan-cache hits for each (the tentpole acceptance comparison).

``run_padding`` submits a deliberately ragged batch and compares the
zero-weight padding rows of the size-bucketed launches against what
the old pad-to-global-widest single launch would have carried.
"""
from __future__ import annotations

import time

from benchmarks.common import bench_cfg, bench_world
from repro.api import Interval, MLegoSession, QuerySpec
from repro.core.plan_ir import pad_rows_bucketed, pad_rows_widest


def run(n_docs=1200, seed=0, quick=False, backend="host"):
    cfg = bench_cfg(quick)
    train, test, index, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0
    session = MLegoSession(train, cfg, kind="vb", backend=backend)

    rows = []
    sequence = [
        ("cold_full", QuerySpec(sigma=Interval(0.0, hi), alpha=0.0)),
        ("warm_full", QuerySpec(sigma=Interval(0.0, hi), alpha=0.0)),
        ("warm_full_again", QuerySpec(sigma=Interval(0.0, hi), alpha=0.0)),
        ("warm_half", QuerySpec(sigma=Interval(0.0, hi / 2), alpha=0.5)),
        ("union", QuerySpec(sigma=[Interval(0.0, hi / 4),
                                   Interval(hi / 2, 0.75 * hi)], alpha=0.5)),
    ]
    for label, spec in sequence:
        rep = session.submit(spec)
        rows.append((label, rep.search_s, rep.train_s, rep.merge_s,
                     rep.n_reused, rep.n_trained_tokens,
                     int(rep.plan_cached)))

    batch = session.submit_many([
        QuerySpec(sigma=Interval(0.0, 0.6 * hi)),
        QuerySpec(sigma=Interval(0.3 * hi, 0.9 * hi)),
        QuerySpec(sigma=Interval(0.1 * hi, hi)),
    ])
    batch_row = (batch.shared_search_s, batch.shared_train_s,
                 batch.merge_s, batch.benefit, len(batch))
    return rows, batch_row


def run_device_cache(n_docs=1200, seed=0, quick=False, repeats=3):
    """Repeated-query workload on the device backend.

    Warms the store once, then replays the same full-range query
    ``repeats`` times: the first replay uploads every plan model into
    the device cache, the rest must hit (and, from the second replay
    on, skip plan search via the session plan cache).  Returns
    per-replay rows (hits, misses, merge_device_ms, plan_cached) plus
    the backend's cumulative hit rate.
    """
    cfg = bench_cfg(quick)
    train, _, _, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0
    session = MLegoSession(train, cfg, kind="vb", backend="device")

    # build capital so replays are pure merges
    edges = [i * hi / 4 for i in range(5)]
    for lo, hi_e in zip(edges, edges[1:]):
        session.train_range(lo, hi_e)

    spec = QuerySpec(sigma=Interval(0.0, hi), alpha=1.0)
    rows = []
    for i in range(repeats):
        rep = session.submit(spec)
        rows.append((f"replay_{i}", rep.cache_hits, rep.cache_misses,
                     rep.merge_device_ms, rep.merge_s,
                     int(rep.plan_cached)))
    return rows, session.backend.stats.hit_rate


def run_providers(n_docs=1200, seed=0, quick=False, repeats=4):
    """Analytic vs calibrated cost provider on the device backend.

    Identical warmed stores and workloads; the calibrated session
    learns κ/t_m/cache prices from its own replays.  Rows:
    (provider, mean_submit_s, total_submit_s, plan_cache_hits,
    device_hit_rate).
    """
    cfg = bench_cfg(quick)
    rows = []
    for provider in ("analytic", "calibrated"):
        train, _, _, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
        hi = float(train.attr[-1]) + 1.0
        session = MLegoSession(train, cfg, kind="vb", backend="device",
                               cost=provider)
        edges = [i * hi / 4 for i in range(5)]
        for lo, hi_e in zip(edges, edges[1:]):
            session.train_range(lo, hi_e)
        specs = [QuerySpec(sigma=Interval(0.0, hi), alpha=1.0),
                 QuerySpec(sigma=Interval(0.0, hi / 2), alpha=1.0)]
        t0 = time.perf_counter()
        n = 0
        for _ in range(repeats):
            for spec in specs:
                session.submit(spec)
                n += 1
        total = time.perf_counter() - t0
        rows.append((provider, total / n, total,
                     session.plan_cache.hits,
                     session.backend.stats.hit_rate))
    return rows


def run_padding(n_docs=1200, seed=0, quick=False):
    """Ragged submit_many: the segmented launch's actual pad rows (zero
    by construction) vs what the two retired schemes would have padded
    on the same batch shape."""
    cfg = bench_cfg(quick)
    train, _, _, _ = bench_world(n_docs=n_docs, cfg=cfg, seed=seed)
    hi = float(train.attr[-1]) + 1.0
    session = MLegoSession(train, cfg, kind="vb", backend="device")
    # 8 narrow tiles: the full-range query merges 8 parts, the narrow
    # ones 1 each — maximally ragged
    edges = [i * hi / 8 for i in range(9)]
    for lo, hi_e in zip(edges, edges[1:]):
        session.train_range(lo, hi_e)
    specs = [QuerySpec(sigma=Interval(0.0, hi))] + [
        QuerySpec(sigma=Interval(edges[i], edges[i + 1]))
        for i in range(4)]
    batch = session.submit_many(specs)
    counts = [r.n_merged for r in batch]
    return {
        "part_counts": counts,
        "pad_rows_ragged": batch.pad_rows,
        "pad_rows_bucketed": pad_rows_bucketed(counts),
        "pad_rows_widest": pad_rows_widest(counts),
        "merge_device_ms": batch.merge_device_ms,
    }


def main():
    rows, batch_row = run()
    print("label,search_s,train_s,merge_s,n_reused,n_trained_tokens,"
          "plan_cached")
    for label, s, t, m, nr, nt, pc in rows:
        print(f"{label},{s:.4f},{t:.4f},{m:.4f},{nr},{nt},{pc}")
    print("# batch: shared_search_s,shared_train_s,merge_s,benefit,n")
    print("batch," + ",".join(f"{v:.4f}" if isinstance(v, float) else str(v)
                              for v in batch_row))
    dev_rows, hit_rate = run_device_cache()
    print("label,cache_hits,cache_misses,merge_device_ms,merge_s,plan_cached")
    for label, h, mi, dms, ms, pc in dev_rows:
        print(f"{label},{h},{mi},{dms:.3f},{ms:.4f},{pc}")
    print(f"# device cache hit-rate {hit_rate:.3f}")
    print("provider,mean_submit_s,total_s,plan_cache_hits,device_hit_rate")
    for provider, mean_s, total, hits, rate in run_providers():
        print(f"{provider},{mean_s:.4f},{total:.4f},{hits},{rate:.3f}")
    pad = run_padding()
    print(f"# padding: ragged {pad['pad_rows_ragged']} rows vs bucketed "
          f"{pad['pad_rows_bucketed']} vs widest {pad['pad_rows_widest']} "
          f"(parts {pad['part_counts']})")


if __name__ == "__main__":
    main()
