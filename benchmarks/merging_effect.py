"""Paper Fig. 3/6: performance loss vs #merged models (monotonicity).

Split a query range into 1..N partitions, train per partition, merge
(MVB + MGS), and measure held-out lpp against the from-scratch model.
Emits: n_parts, lpp_scratch, lpp_mvb, lpp_mgs, dp_mvb, dp_mgs — and a
refit of the PerformanceLoss rho from the measurements (feeding the
planner's cost model, §V.B.2).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import BENCH_CFG, bench_world, lpp_of, timed
from repro.core.cost import PerformanceLoss
from repro.core.gibbs import cgs_fit
from repro.core.lda import topics_from_gs, topics_from_vb
from repro.core.merge import merge_gs, merge_vb
from repro.core.lda import MaterializedModel
from repro.core.plans import Interval
from repro.core.vb import vb_fit
from repro.data.corpus import doc_term_matrix


def run(n_docs=1200, parts=(1, 2, 4, 8, 16), seed=0, out_rows=None):
    cfg = BENCH_CFG
    train, test, index, _ = bench_world(n_docs=n_docs, seed=seed)
    lo, hi = 0.0, float(train.attr[-1]) + 1.0

    x_all = doc_term_matrix(train)
    lam = np.asarray(vb_fit(x_all, jax.random.PRNGKey(seed), cfg))
    lpp_scratch = lpp_of(topics_from_vb(lam), test)

    rows = []
    xs, losses = [], []
    for n in parts:
        edges = np.linspace(lo, hi, n + 1)
        vb_models, gs_models = [], []
        for i, (a, b) in enumerate(zip(edges, edges[1:])):
            sub = train.subset(a, b)
            if sub.n_docs == 0:
                continue
            x = doc_term_matrix(sub)
            l = np.asarray(vb_fit(x, jax.random.PRNGKey(seed + i), cfg))
            vb_models.append(MaterializedModel(
                i, Interval(a, b), sub.n_docs, sub.n_tokens, "vb",
                {"lam": l}))
            nkv = cgs_fit(sub.tokens, sub.doc_ids, cfg,
                          jax.random.PRNGKey(seed + i))
            gs_models.append(MaterializedModel(
                i, Interval(a, b), sub.n_docs, sub.n_tokens, "gs",
                {"delta_nkv": nkv}))
        lpp_mvb = lpp_of(topics_from_vb(merge_vb(vb_models, cfg)), test)
        lpp_mgs = lpp_of(topics_from_gs(merge_gs(gs_models, cfg), cfg.eta),
                         test)
        dp_mvb = abs(lpp_scratch - lpp_mvb)
        dp_mgs = abs(lpp_scratch - lpp_mgs)
        rows.append((n, lpp_scratch, lpp_mvb, lpp_mgs, dp_mvb, dp_mgs))
        if n > 1:
            xs.append(n - 1)
            losses.append(min(max(dp_mvb / max(abs(lpp_scratch), 1e-9), 0.0),
                              0.99))
    ploss = PerformanceLoss.fit(xs, losses) if xs else PerformanceLoss()
    if out_rows is not None:
        out_rows.extend(rows)
    return rows, ploss


def main():
    rows, ploss = run()
    print("n_parts,lpp_scratch,lpp_mvb,lpp_mgs,dp_mvb,dp_mgs")
    for r in rows:
        print(",".join(f"{v:.4f}" if isinstance(v, float) else str(v)
                       for v in r))
    print(f"# fitted PerformanceLoss rho = {ploss.rho:.5f}")
    return rows


if __name__ == "__main__":
    main()
