"""Paper Fig. 7/8: model-construction time — MLego merge vs baselines.

Baselines (paper §VI.A.4, adapted to this host per DESIGN.md §7):
  ORIG : batch VB / CGS from scratch on the query range.
  LDA* : the distributed-training baseline class — partitioned training
         without reuse; on one host we execute the partition trainings
         and charge the *max* partition time (perfect 8-way scaling,
         an upper bound on LDA*'s advantage).
  OGS  : online single-pass VB (one E/M sweep per minibatch).

MLego answers from materialized models: plan search + Alg. 1 merge.
SR (speedup ratio) = t_baseline / t_mlego.  --scale sweeps corpus size
(Fig. 8).
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import (
    BENCH_CFG,
    bench_world,
    lpp_of,
    materialize_partitions,
    timed,
)
from repro.api import Interval, MLegoSession, QuerySpec
from repro.core.cost import CostModel
from repro.core.lda import topics_from_vb
from repro.core.merge import merge_vb
from repro.core.store import ModelStore
from repro.core.vb import vb_fit, vb_estep, _exp_dirichlet_expectation
from repro.data.corpus import doc_term_matrix
import jax.numpy as jnp


def ogs_fit(x, cfg, key, batch_docs=64):
    """Online VB: single pass, minibatch natural-gradient updates."""
    d, v = x.shape
    lam = jax.random.gamma(key, 100.0, (cfg.n_topics, v), jnp.float32) * 0.01
    tau0, kappa = 1.0, 0.7
    for t, s in enumerate(range(0, d, batch_docs)):
        xb = jnp.asarray(x[s:s + batch_docs])
        eeb = _exp_dirichlet_expectation(lam)
        g0 = jnp.ones((xb.shape[0], cfg.n_topics), jnp.float32)
        _, sstats = vb_estep(xb, eeb, g0, cfg.alpha, cfg.e_step_iters)
        rho = (tau0 + t) ** (-kappa)
        lam_hat = cfg.eta + (d / xb.shape[0]) * sstats
        lam = (1 - rho) * lam + rho * lam_hat
    return np.asarray(lam)


def run(n_docs=1500, n_partitions=8, seed=0):
    cfg = BENCH_CFG
    train, test, index, _ = bench_world(n_docs=n_docs, seed=seed)
    lo, hi = 0.0, float(train.attr[-1]) + 1.0
    store = ModelStore()
    edges = list(np.linspace(lo, hi, n_partitions + 1))

    # materialization (offline capital; timed for reference)
    t_mat, _ = timed(materialize_partitions, train, cfg, store, edges)

    # ORIG
    x_all = doc_term_matrix(train)
    t_orig, lam = timed(
        lambda: np.asarray(vb_fit(x_all, jax.random.PRNGKey(seed), cfg)))
    lpp_orig = lpp_of(topics_from_vb(lam), test)

    # LDA* proxy: partitioned training, charged max partition time
    part_times = []
    for a, b in zip(edges, edges[1:]):
        sub = train.subset(a, b)
        if sub.n_docs == 0:
            continue
        x = doc_term_matrix(sub)
        t, _ = timed(lambda x=x: np.asarray(
            vb_fit(x, jax.random.PRNGKey(seed), cfg)))
        part_times.append(t)
    t_ldastar = max(part_times)

    # OGS
    t_ogs, lam_ogs = timed(ogs_fit, x_all, cfg, jax.random.PRNGKey(seed))
    lpp_ogs = lpp_of(topics_from_vb(lam_ogs), test)

    # MLego: full-coverage query -> plan search + merge only
    session = MLegoSession(train, cfg, store=store, kind="vb")
    t_mlego, rep = timed(session.submit,
                         QuerySpec(sigma=Interval(lo, hi), alpha=0.0))
    lpp_mlego = lpp_of(rep.beta, test)

    rows = [
        ("ORIG", t_orig, lpp_orig, t_orig / t_mlego),
        ("LDA*", t_ldastar, lpp_orig, t_ldastar / t_mlego),
        ("OGS", t_ogs, lpp_ogs, t_ogs / t_mlego),
        ("MLego", t_mlego, lpp_mlego, 1.0),
    ]
    return rows, t_mat


def main():
    scale = "--scale" in sys.argv
    print("method,time_s,lpp,SR,n_docs")
    sizes = (500, 1500, 4000) if scale else (1500,)
    for n in sizes:
        rows, t_mat = run(n_docs=n)
        for name, t, lpp, sr in rows:
            print(f"{name},{t:.4f},{lpp:.4f},{sr:.2f},{n}")
        print(f"# materialization time {t_mat:.2f}s (offline, n={n})")


if __name__ == "__main__":
    main()
