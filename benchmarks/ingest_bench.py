"""Streaming-ingestion benchmark: freshness, speculation, compaction.

Drives the ``repro.ingest`` subsystem through the serving surface on
one synthetic drift trace (documents arriving in attr order past the
base corpus) and reports the three numbers the subsystem exists for:

  freshness     how stale is capital over just-arrived data?  A drift
                trace streams batches through ``MLegoService.ingest``
                while a client queries each newly closed slice; rows
                report the builder's close->materialize lag and
                whether the query was answered from ingested capital
                (zero gap-trained tokens) — no manual store mutation
                anywhere.
  speculation   does workload-driven gap pre-training pay?  One hot
                volatile sigma is replayed at a fixed cadence twice —
                once with the speculator attached, once without — and
                the client-observed p50 submit latency plus the
                speculative hit rate are compared.  With speculation
                the hot gap trains once off the query path; without,
                every replay pays it.
  compaction    what does staying under a byte budget cost?  Fine
                slices are compacted into coarse segments mid-stream;
                rows compare store bytes against the budget and the
                post-compaction beta over the compacted range against
                the pre-compaction one (the merge families are exact
                natural-parameter additions, so the delta is float
                noise — the merge-quality tolerance).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import bench_cfg
from repro.api import Interval, QuerySpec
from repro.core.lda import greedy_topic_overlap
from repro.data.corpus import make_corpus
from repro.ingest import CompactionPolicy, Compactor
from repro.serve import MLegoService


def _percentile(xs: List[float], p: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(int(round(p / 100.0 * (len(xs) - 1))), len(xs) - 1)
    return xs[i]


def _world(n_docs: int, cfg, *, base_hi: float, seed: int = 0):
    corpus, _ = make_corpus(n_docs, cfg.vocab_size, cfg.n_topics,
                            mean_doc_len=40, attr_max=base_hi, seed=seed)
    return corpus


def _batch(n_docs: int, cfg, *, lo: float, width: float, seed: int):
    c = _world(n_docs, cfg, base_hi=width, seed=seed)
    return dataclasses.replace(c, attr=c.attr + lo)


# ---------------------------------------------------------------------------
# freshness under concurrent ingest
# ---------------------------------------------------------------------------

def run_freshness(n_docs: int = 800, *, quick: bool = False,
                  n_batches: int = 4, seed: int = 0) -> Dict:
    """Stream ``n_batches`` drift batches; query each closed slice as
    soon as it is built.  ``fresh_answered`` counts queries answered
    purely from ingested capital (acceptance: every one, with zero
    manual store mutation)."""
    cfg = bench_cfg(quick)
    base_hi, width = 100.0, 25.0
    svc = MLegoService(_world(n_docs, cfg, base_hi=base_hi, seed=seed),
                       cfg, window_s=0.0, seed=seed)
    try:
        pipe = svc.attach_ingest(slice_width=width,
                                 compaction=CompactionPolicy(
                                     max_bytes=64 * cfg.n_topics
                                     * cfg.vocab_size * 4))
        rows = []
        per_batch = max(n_docs // (2 * n_batches), 40)

        def probe(b: int, lo: float, built_s: float) -> None:
            t1 = time.perf_counter()
            rep = svc.submit(QuerySpec(sigma=Interval(lo, lo + width),
                                       materialize="volatile")
                             ).result(timeout=300)
            rows.append({
                "batch": b, "slice_lo": lo, "slice_hi": lo + width,
                "ingest_to_built_s": built_s,
                "query_s": time.perf_counter() - t1,
                "fresh": rep.n_trained_tokens == 0,
                "n_reused": rep.n_reused,
            })

        # batch b's arrival closes slice b-1 (append-only: a slice only
        # closes once the frontier passes its upper bound), so each
        # round queries the slice the newest batch just sealed
        for b in range(n_batches):
            t0 = time.perf_counter()
            svc.ingest(_batch(per_batch, cfg, lo=base_hi + b * width,
                              width=width, seed=seed + 1 + b))
            pipe.flush(timeout=120.0)
            if b > 0:
                probe(b - 1, base_hi + (b - 1) * width,
                      time.perf_counter() - t0)
        # closing builds the final (partial) slice
        t0 = time.perf_counter()
        pipe.close()
        probe(n_batches - 1, base_hi + (n_batches - 1) * width,
              time.perf_counter() - t0)
        ir = svc.report().ingest
        return {
            "rows": rows,
            "fresh_answered": sum(r["fresh"] for r in rows),
            "queries": len(rows),
            "slices_built": ir.slices_built,
            "freshness_lag_s_mean": ir.freshness_lag_s_mean,
            "freshness_lag_s_max": ir.freshness_lag_s_max,
            "compactions": ir.compactions,
            "store_bytes": ir.store_bytes,
        }
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# speculation A/B
# ---------------------------------------------------------------------------

def _hot_trace(svc: MLegoService, sigma: Interval, *, rounds: int,
               cadence_s: float) -> List[float]:
    """Replay one hot volatile sigma at a fixed cadence; returns
    client-observed submit latencies."""
    spec = QuerySpec(sigma=sigma, materialize="volatile")
    lats = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        svc.submit(spec).result(timeout=300)
        lats.append(time.perf_counter() - t0)
        dt = cadence_s - (time.perf_counter() - t0)
        if dt > 0:
            time.sleep(dt)
    return lats


def run_speculation(n_docs: int = 800, *, quick: bool = False,
                    rounds: int = 6, cadence_s: float = 0.25,
                    seed: int = 0) -> Dict:
    """The same hot-sigma trace with and without the speculator.

    ``margin=0`` keeps the payoff gate open (the gate itself is
    calibration-dependent; its unit semantics are tested in tier-1),
    so the A/B isolates what pre-training is worth when it fires."""
    cfg = bench_cfg(quick)
    base_hi = 100.0
    sigma = Interval(0.0, base_hi / 2)
    out = {}
    for label, speculate in (("off", False), ("on", True)):
        svc = MLegoService(_world(n_docs, cfg, base_hi=base_hi, seed=seed),
                           cfg, window_s=0.0, seed=seed)
        try:
            if speculate:
                svc.attach_speculator(window_s=60.0, min_count=2,
                                      margin=0.0, poll_s=0.02)
            lats = _hot_trace(svc, sigma, rounds=rounds,
                              cadence_s=cadence_s)
            rep = svc.report()
            out[label] = {
                "rounds": rounds,
                "p50_s": _percentile(lats, 50),
                "p95_s": _percentile(lats, 95),
                # warm-up pays the first gap train in both modes; the
                # steady state is where speculation shows
                "steady_p50_s": _percentile(lats[1:], 50),
                "hit_rate": rep.speculation.hit_rate
                if rep.speculation else 0.0,
                "speculated_segments": rep.speculation.trained
                if rep.speculation else 0,
            }
        finally:
            svc.close()
    out["steady_speedup"] = (out["off"]["steady_p50_s"]
                             / max(out["on"]["steady_p50_s"], 1e-9))
    return out


# ---------------------------------------------------------------------------
# compaction quality/budget
# ---------------------------------------------------------------------------

def run_compaction(n_docs: int = 800, *, quick: bool = False,
                   seed: int = 0) -> Dict:
    """Stream fine slices past a tight budget; compare beta over the
    compacted range before vs after the store swapped fines for a
    coarse segment."""
    cfg = bench_cfg(quick)
    base_hi, width = 100.0, 12.5
    per_model = cfg.n_topics * cfg.vocab_size * 4
    budget = 2 * per_model
    svc = MLegoService(_world(n_docs, cfg, base_hi=base_hi, seed=seed),
                       cfg, window_s=0.0, seed=seed)
    try:
        probe = QuerySpec(sigma=Interval(base_hi, base_hi + 4 * width),
                          materialize="volatile")
        pipe = svc.attach_ingest(slice_width=width)
        # fines first, no compactor: the pre-compaction reference.
        # close() seals the trailing slice so all four materialize.
        svc.ingest(_batch(n_docs // 2, cfg, lo=base_hi, width=4 * width,
                          seed=seed + 1))
        pipe.close()
        before = svc.submit(probe).result(timeout=300)
        bytes_before = svc.store.nbytes()

        comp = Compactor(svc.store, cfg,
                         CompactionPolicy(max_bytes=budget, merge_width=4,
                                          min_retained=0))
        rep = comp.run()
        after = svc.submit(probe).result(timeout=300)
        delta = float(np.max(np.abs(after.beta - before.beta)))
        return {
            "budget_bytes": budget,
            "bytes_before": bytes_before,
            "bytes_after": svc.store.nbytes(),
            "under_budget": svc.store.nbytes() <= budget,
            "compacted_groups": len(rep.compacted),
            "evicted": len(rep.evicted),
            "parts_before": before.n_reused,
            "parts_after": after.n_reused,
            "beta_max_abs_delta": delta,
            "topic_overlap": float(greedy_topic_overlap(before.beta,
                                                        after.beta)),
        }
    finally:
        svc.close()


def run(n_docs: int = 800, *, quick: bool = False) -> Dict:
    return {
        "freshness": run_freshness(n_docs, quick=quick),
        "speculation": run_speculation(n_docs, quick=quick),
        "compaction": run_compaction(n_docs, quick=quick),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True), indent=1))
