"""Paper Fig. 10/11/12: plan-search efficiency.

Fig. 10: NAI vs GRA vs PSOA vs PSOA++ wall time on growing model sets.
Fig. 11: impact of #candidate models per query.
Fig. 12: impact of the weight parameter alpha on PSOA.
All searchers return identical optima (asserted for alpha < 1); the
benchmark reports time and #plans scored.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_CFG, bench_world
from repro.core.cost import CostModel
from repro.core.plans import Interval
from repro.core.search import gra_search, nai_search, psoa_search
from repro.core.store import ModelStore


def _store(index, n_models, span, seed=0):
    rng = np.random.default_rng(seed)
    store = ModelStore()
    k, v = 4, 8   # stats are stand-ins; search reads only ranges/counts
    for _ in range(n_models):
        lo = rng.uniform(span[0], span[1] * 0.85)
        hi = lo + rng.uniform((span[1] - span[0]) * 0.02,
                              (span[1] - span[0]) * 0.25)
        nd, nt = index.count(lo, hi)
        store.add(Interval(lo, hi), nd, nt, "vb",
                  {"lam": np.ones((k, v), np.float32)})
    return store


def run_sizes(sizes=(6, 10, 14, 18, 22), alpha=0.3, seed=0, nai_cap=18):
    _, _, index, _ = bench_world(n_docs=1200, seed=seed)
    span = (0.0, 1200.0)
    q = Interval(20.0, 1150.0)
    cost = CostModel(max_iters=BENCH_CFG.max_iters,
                     n_topics=BENCH_CFG.n_topics)
    rows = []
    for n in sizes:
        store = _store(index, n, span, seed=seed + n)
        ms = store.models()
        r_psoa = psoa_search(ms, q, index, cost, alpha, use_plus=False)
        r_plus = psoa_search(ms, q, index, cost, alpha, use_plus=True)
        r_gra = gra_search(ms, q, index, cost)
        if n <= nai_cap:
            r_nai = nai_search(ms, q, index, cost, alpha)
            assert abs(r_nai.score - r_psoa.score) < 1e-9
            nai_t, nai_scored = r_nai.elapsed_s, r_nai.n_scored
        else:
            nai_t, nai_scored = float("nan"), -1
        rows.append((n, alpha, nai_t, nai_scored,
                     r_gra.elapsed_s, r_gra.n_scored,
                     r_psoa.elapsed_s, r_psoa.n_scored,
                     r_plus.elapsed_s, r_plus.n_scored))
    return rows


def run_alpha(alphas=(0.0, 0.2, 0.4, 0.6, 0.8, 1.0), n_models=14, seed=0):
    _, _, index, _ = bench_world(n_docs=1200, seed=seed)
    q = Interval(20.0, 1150.0)
    cost = CostModel(max_iters=BENCH_CFG.max_iters,
                     n_topics=BENCH_CFG.n_topics)
    store = _store(index, n_models, (0.0, 1200.0), seed=seed)
    rows = []
    for a in alphas:
        r = psoa_search(store.models(), q, index, cost, a)
        rows.append((a, r.elapsed_s, r.n_scored, r.n_layers, r.method))
    return rows


def main():
    print("n_models,alpha,nai_s,nai_scored,gra_s,gra_scored,"
          "psoa_s,psoa_scored,psoa++_s,psoa++_scored")
    for r in run_sizes():
        print(",".join(str(x) if not isinstance(x, float)
                       else f"{x:.6f}" for x in r))
    print("alpha,psoa_s,n_scored,n_layers,method")
    for r in run_alpha():
        print(",".join(f"{x:.6f}" if isinstance(x, float) else str(x)
                       for x in r))


if __name__ == "__main__":
    main()
