"""Quickstart: materialize, query, reuse — MLego in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Builds a small synthetic review corpus with an ordered attribute
(think: timestamp), materializes LDA models for two time windows, then
answers an analytic query spanning both windows *without retraining* —
the paper's Fig. 1 scenario end to end.
"""
import numpy as np

from repro.configs.lda_default import LDAConfig
from repro.core.lda import log_predictive_probability
from repro.core.plans import Interval
from repro.core.query import QueryEngine
from repro.core.store import ModelStore
from repro.data.corpus import doc_term_matrix, make_corpus, train_test_split


def main():
    cfg = LDAConfig(n_topics=12, vocab_size=400, max_iters=25,
                    e_step_iters=10)
    corpus, _ = make_corpus(1000, cfg.vocab_size, cfg.n_topics,
                            mean_doc_len=40, seed=0)
    train, test = train_test_split(corpus, test_frac=0.1)
    x_test = doc_term_matrix(test)

    engine = QueryEngine(train, ModelStore(), cfg, kind="vb")

    print("== materializing models for two time windows ==")
    m1 = engine.train_range(0.0, 500.0)
    m2 = engine.train_range(500.0, 1000.0)
    print(f"  m1: {m1.o} ({m1.n_docs} docs)   m2: {m2.o} ({m2.n_docs} docs)")

    print("\n== analytic query over the union (alpha=0.5) ==")
    res = engine.execute(Interval(0.0, 1000.0), alpha=0.5)
    print(f"  plan: models {res.plan.model_ids}, "
          f"trained {res.n_trained_tokens} tokens, "
          f"search {res.search_s*1e3:.1f}ms, merge {res.merge_s*1e3:.1f}ms")
    print(f"  held-out lpp: {log_predictive_probability(res.beta, x_test):.4f}")

    print("\n== top words per topic (first 3 topics) ==")
    for k in range(3):
        top = np.argsort(-res.beta[k])[:8]
        print(f"  topic {k}: words {top.tolist()}")

    print("\n== a narrower ad-hoc query (partial coverage) ==")
    res2 = engine.execute(Interval(250.0, 750.0), alpha=0.2)
    print(f"  plan: {res2.plan.model_ids} + {res2.n_trained_tokens} "
          f"fresh tokens -> lpp "
          f"{log_predictive_probability(res2.beta, x_test):.4f}")
    print(f"  store now holds {len(engine.store)} models "
          f"({engine.store.nbytes()/1e6:.1f} MB) — reuse capital grows")


if __name__ == "__main__":
    main()
