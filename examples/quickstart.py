"""Quickstart: one session, typed queries, growing reuse capital.

    PYTHONPATH=src python examples/quickstart.py

MLego in 60 seconds, through the unified session API (``repro.api``):

  1. Open an ``MLegoSession`` over a corpus — the session owns the
     dataset D, the model store, the cost model, and the RNG stream
     from the paper's Def. 1 query tuple q = {F, alpha, D, sigma, M}.
  2. Materialize LDA models for two time windows (offline capital).
  3. Submit a typed ``QuerySpec`` — predicate sigma, accuracy
     preference alpha, backend kind, plan-search method, and
     materialization policy — and get a ``QueryReport`` back: the
     query spanning both windows is answered *without retraining*
     (the paper's Fig. 1 scenario end to end).
  4. Submit a narrower query that is only partially covered: the
     planner reuses what it can, trains just the gap, and (policy
     ``persist``) materializes the fresh model so the *next* query is
     faster — the interactivity flywheel.
  5. Bonus over the legacy API: a union-of-intervals predicate is a
     single query.

The old ``QueryEngine.execute(interval, alpha)`` path still exists as
a deprecated shim; see src/repro/api/README.md for the migration
table.
"""
import numpy as np

from repro.api import Interval, MLegoSession, QuerySpec
from repro.configs.lda_default import LDAConfig
from repro.core.lda import log_predictive_probability
from repro.data.corpus import doc_term_matrix, make_corpus, train_test_split


def main():
    cfg = LDAConfig(n_topics=12, vocab_size=400, max_iters=25,
                    e_step_iters=10)
    corpus, _ = make_corpus(1000, cfg.vocab_size, cfg.n_topics,
                            mean_doc_len=40, seed=0)
    train, test = train_test_split(corpus, test_frac=0.1)
    x_test = doc_term_matrix(test)

    session = MLegoSession(train, cfg, kind="vb")

    print("== materializing models for two time windows ==")
    m1 = session.train_range(0.0, 500.0)
    m2 = session.train_range(500.0, 1000.0)
    print(f"  m1: {m1.o} ({m1.n_docs} docs)   m2: {m2.o} ({m2.n_docs} docs)")

    print("\n== analytic query over the union (alpha=0.5) ==")
    rep = session.submit(QuerySpec(sigma=Interval(0.0, 1000.0), alpha=0.5))
    print(f"  plan: models {rep.model_ids}, "
          f"trained {rep.n_trained_tokens} tokens, "
          f"search {rep.search_s*1e3:.1f}ms, merge {rep.merge_s*1e3:.1f}ms")
    print(f"  held-out lpp: {log_predictive_probability(rep.beta, x_test):.4f}")

    print("\n== top words per topic (first 3 topics) ==")
    for k in range(3):
        top = np.argsort(-rep.beta[k])[:8]
        print(f"  topic {k}: words {top.tolist()}")

    print("\n== a narrower ad-hoc query (partial coverage) ==")
    rep2 = session.submit(QuerySpec(sigma=Interval(250.0, 750.0), alpha=0.2))
    print(f"  plan: {rep2.model_ids} + {rep2.n_trained_tokens} "
          f"fresh tokens -> lpp "
          f"{log_predictive_probability(rep2.beta, x_test):.4f}")
    print(f"  store now holds {len(session.store)} models "
          f"({session.store.nbytes()/1e6:.1f} MB) — reuse capital grows")

    print("\n== union predicate: two disjoint windows, one query ==")
    rep3 = session.submit(QuerySpec(
        sigma=[Interval(0.0, 250.0), Interval(750.0, 1000.0)], alpha=0.5))
    print(f"  components: {len(rep3.plans)}, merged {rep3.n_merged} parts, "
          f"lpp {log_predictive_probability(rep3.beta, x_test):.4f}")


if __name__ == "__main__":
    main()
