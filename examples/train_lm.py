"""Train a reduced assigned-architecture LM end to end on this host.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-1.7b --steps 100

Demonstrates the non-LDA half of the framework: config resolution,
model construction, the jitted train step (loss+grad+AdamW), the
deterministic data pipeline, periodic checkpointing and restart.
Full-scale cells run the same code path on the production mesh
(see launch/train.py and launch/dryrun.py).
"""
import argparse
import tempfile

import jax

from repro.configs import ARCHS
from repro.data.lm import batch_stream
from repro.distributed.sharding import single_device_env
from repro.models.model import build_model
from repro.train.optim import OptimizerConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    model = build_model(cfg)
    env = single_device_env()
    print(f"{cfg.name}: {model.param_count():,} params "
          f"({cfg.family}, {cfg.n_layers}L d={cfg.d_model})")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(model, OptimizerConfig(lr=3e-3, warmup_steps=10),
                          env, ckpt_dir=ckpt_dir, save_every=25,
                          remat=False)
        state = trainer.restore_or_init()
        stream = batch_stream(cfg, args.batch, args.seq, seed=0)
        state = trainer.fit(state, stream, args.steps, log_every=10)

        # simulate preemption: restore from the checkpoint and continue
        trainer2 = Trainer(model, OptimizerConfig(lr=3e-3, warmup_steps=10),
                           env, ckpt_dir=ckpt_dir, remat=False)
        state2 = trainer2.restore_or_init()
        print(f"restart: resumed at step {int(state2.step)} "
              f"(cursor {state2.data_cursor}) — continuing 10 more")
        stream2 = batch_stream(cfg, args.batch, args.seq, seed=0,
                               start_cursor=state2.data_cursor)
        trainer2.fit(state2, stream2, 10, log_every=5)


if __name__ == "__main__":
    main()
