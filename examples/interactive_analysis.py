"""End-to-end interactive topic-exploration session (the paper's §VI.C
usage scenario, driver form) — on the unified session API.

Simulates an analyst (Oliver) exploring a geo-tagged review corpus:
a sequence of ad-hoc range queries with different latency/accuracy
preferences (alpha), a union-of-intervals query over two disjoint
districts, a batch of queries optimized together (Alg. 4, with
shared costs reported at the batch level), a node failure recovered
by local retraining, and an elastic repartition — all against one
growing model store, with every query answered at interactive speed
once coverage builds up.

    PYTHONPATH=src python examples/interactive_analysis.py
"""
import time

import numpy as np

from repro.api import Interval, MLegoSession, QuerySpec
from repro.configs.lda_default import LDAConfig
from repro.core.lda import log_predictive_probability
from repro.data.corpus import doc_term_matrix, make_corpus, train_test_split
from repro.distributed.elastic import (
    apply_repartition,
    plan_repartition,
    recover_failed,
)


def main():
    cfg = LDAConfig(n_topics=16, vocab_size=600, max_iters=20,
                    e_step_iters=10)
    corpus, _ = make_corpus(2000, cfg.vocab_size, cfg.n_topics,
                            mean_doc_len=40, seed=42)
    train, test = train_test_split(corpus, test_frac=0.1)
    x_test = doc_term_matrix(test)
    session = MLegoSession(train, cfg, kind="vb")
    lpp = lambda beta: log_predictive_probability(beta, x_test)

    print("== session: exploratory range queries ==")
    script = [
        (Interval(0.0, 400.0), 0.0, "first look at district A (speed)"),
        (Interval(300.0, 900.0), 0.0, "pan east"),
        (Interval(0.0, 900.0), 0.5, "zoom out, balanced"),
        (Interval(100.0, 800.0), 0.8, "re-check, accuracy-leaning"),
        (Interval(0.0, 2000.0), 0.0, "whole city, fast"),
    ]
    for q, alpha, label in script:
        t0 = time.perf_counter()
        rep = session.submit(QuerySpec(sigma=q, alpha=alpha))
        dt = time.perf_counter() - t0
        print(f"  [{label:34s}] q={q.lo:6.0f}..{q.hi:6.0f} a={alpha}: "
              f"{dt*1e3:7.1f}ms  plan={rep.n_reused} models "
              f"+{rep.n_trained_tokens:6d} tok  lpp={lpp(rep.beta):.3f}")
    print(f"  store: {len(session.store)} models")

    print("\n== union predicate: districts A and C, one query ==")
    rep = session.submit(QuerySpec(
        sigma=[Interval(0.0, 400.0), Interval(1400.0, 1800.0)], alpha=0.5))
    print(f"  components={len(rep.plans)} merged={rep.n_merged} parts "
          f"+{rep.n_trained_tokens} tok  lpp={lpp(rep.beta):.3f}")

    print("\n== batch of three queries (Alg. 4 shared training) ==")
    batch = [Interval(900.0, 1500.0), Interval(1200.0, 1900.0),
             Interval(1000.0, 1700.0)]
    t0 = time.perf_counter()
    br = session.submit_many([QuerySpec(sigma=q) for q in batch])
    dt = time.perf_counter() - t0
    print(f"  {len(br)} queries in {dt*1e3:.1f}ms; "
          f"benefit={br.benefit:.4f} (saved training), "
          f"naive={br.opt.naive_time:.4f} shared={br.opt.total_time:.4f}")
    print(f"  batch costs: search {br.shared_search_s*1e3:.1f}ms + train "
          f"{br.shared_train_s*1e3:.1f}ms shared; per-query merges "
          + " ".join(f"{r.merge_s*1e3:.1f}ms" for r in br))

    print("\n== node failure: range [400, 800) models lost ==")
    lost = [m for m in session.store.models()
            if Interval(400.0, 800.0).contains(m.o)]
    for m in lost:
        session.store.remove(m.model_id)
    t0 = time.perf_counter()
    fresh = recover_failed(session.store, [Interval(400.0, 800.0)],
                           session.train_range)
    print(f"  retrained {len(fresh)} gap models in "
          f"{time.perf_counter()-t0:.2f}s (only the lost ranges)")

    print("\n== elastic scale-out: repartition store to 4 workers ==")
    parts = plan_repartition(session.store, Interval(0.0, 2000.0), 4)
    worker_models = apply_repartition(parts, session.store, cfg,
                                      session.train_range)
    for w, m in sorted(worker_models.items()):
        print(f"  worker {w}: span {m.o.lo:6.0f}..{m.o.hi:6.0f} "
              f"({m.n_docs} docs merged, lpp covered)")

    print("\nsession complete — every repeat query was answered from the "
          "store at millisecond scale.")


if __name__ == "__main__":
    main()
