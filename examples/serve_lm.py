"""Batched serving example: prefill a batch of prompts, then greedy
decode — the inference path the decode_32k / long_500k dry-run cells
lower at production scale.

    PYTHONPATH=src python examples/serve_lm.py --arch xlstm-1.3b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.data.lm import make_batch
from repro.distributed.sharding import single_device_env, set_env
from repro.launch.serve import generate
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-1.3b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    model = build_model(cfg)
    env = single_device_env(profile="serve")
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, args.batch, args.prompt_len, 0, 0)
    batch.pop("labels", None)

    t0 = time.perf_counter()
    toks = generate(model, params, batch, env, steps=args.gen_len,
                    cache_len=args.prompt_len + args.gen_len)
    dt = time.perf_counter() - t0
    print(f"{cfg.name} ({cfg.family}): {toks.shape[0]}x{toks.shape[1]} "
          f"tokens in {dt:.2f}s "
          f"({args.batch*args.gen_len/dt:.1f} tok/s incl. compile)")
    for row in range(min(2, toks.shape[0])):
        print(f"  seq {row}:", toks[row, :16].tolist())


if __name__ == "__main__":
    main()
