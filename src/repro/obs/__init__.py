"""MLego observability layer: tracing, metrics, kernel profiling.

Three pieces, one instrumentation story (see api/README.md
"Observability" for the user-facing tour):

* ``repro.obs.trace`` — `Span`/`Tracer` with a thread-safe ring
  buffer and Chrome-trace-event export (loads in Perfetto).  Span
  owners (session, service) hold a `Tracer`; everything else emits
  through the ambient thread-local context, so un-traced code paths
  cost one dict lookup.
* ``repro.obs.metrics`` — `MetricsRegistry` of labelled counters/
  gauges/histograms with Prometheus text exposition and a JSON
  snapshot; the single read surface for every counter the serve
  stack used to scatter across ad-hoc structures.
* ``repro.obs.profile`` — opt-in kernel profiling hooks:
  ``jax.profiler`` trace annotations around device launches plus
  HLO-derived flops/bytes features (via ``launch/hlo_analyzer``)
  landed as span attributes.

``trace`` and ``metrics`` are stdlib-only by design — importable from
``repro.core`` without cycles; only ``profile`` touches jax.
"""
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    HistogramView,
    MetricsRegistry,
)
from repro.obs.trace import (
    Span,
    Tracer,
    current_span,
    current_tracer,
    instant,
    set_attrs,
    span,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "HistogramView",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "current_span",
    "current_tracer",
    "instant",
    "set_attrs",
    "span",
]
