"""Zero-dependency tracing core: spans, tracers, Chrome-trace export.

One query through the serve stack crosses four subsystems (queue,
session, executor, backend) and at least two threads (the submitting
caller and the pool worker that drains it).  The ad-hoc counters that
grew up around those layers can say *how much* time the system spent
merging, but not *where this one query's 40 ms went*.  This module is
the answer: a `Span` is one timed region with an explicit parent, a
`Tracer` is a thread-safe ring buffer of finished spans, and
`Tracer.to_chrome()` serializes the buffer as Chrome trace-event JSON
that loads directly in Perfetto (or ``chrome://tracing``).

Design rules, in priority order:

* **Zero dependencies.**  Stdlib only.  The tracer must be importable
  from `core/errors.py` without creating a cycle, so this module
  imports nothing from ``repro``.
* **Cheap when idle.**  Code that *might* run under a trace (backends,
  the retry driver, kernel wrappers) calls the module-level `span()` /
  `instant()` / `set_attrs()` helpers, which consult a thread-local
  context stack: when no enclosing span is active they are a dict
  lookup and a ``None`` check.  Only span *owners* (session, service)
  hold a `Tracer` reference.
* **Monotonic clocks.**  All timestamps are ``time.perf_counter()``
  seconds.  Chrome export rebases them onto the tracer's own epoch so
  traces from one process line up; never mix wall-clock in.
* **Explicit parents, implicit nesting.**  Entering ``tracer.span()``
  pushes the span onto the calling thread's context stack, so nested
  spans pick up their parent automatically.  Crossing a thread (a
  pool worker finishing a query enqueued elsewhere) passes
  ``trace_id=`` / ``parent_id=`` explicitly — the queue item carries
  them.

A ``trace_id`` groups every span recorded on behalf of one logical
query; it is minted by `Tracer.new_trace_id()` at the outermost entry
point (service front door or a direct ``session.submit``) and rides
``QueryReport.trace`` back to the caller, so a slow report can be
looked up in the exported trace by id.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "Span",
    "Tracer",
    "current_span",
    "current_tracer",
    "instant",
    "set_attrs",
    "span",
]


@dataclass
class Span:
    """One timed region.  ``t0``/``t1`` are ``perf_counter`` seconds."""

    name: str
    kind: str
    trace_id: str
    span_id: str
    parent_id: Optional[str]
    t0: float
    t1: float = 0.0
    thread: int = 0
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return max(0.0, self.t1 - self.t0)


class _NullCtx:
    """Reusable no-op context manager for the disabled/ambient-miss path."""

    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc: object) -> bool:
        return False


_NULL_CTX = _NullCtx()

# Per-thread stack of (tracer, span) for implicit parent inheritance.
_tls = threading.local()


def _stack() -> List[Tuple["Tracer", Span]]:
    s = getattr(_tls, "stack", None)
    if s is None:
        s = []
        _tls.stack = s
    return s


def current_tracer() -> Optional["Tracer"]:
    """The tracer owning the innermost active span on this thread."""
    s = _stack()
    return s[-1][0] if s else None


def current_span() -> Optional[Span]:
    """The innermost active span on this thread (not yet recorded)."""
    s = _stack()
    return s[-1][1] if s else None


def set_attrs(**attrs: Any) -> None:
    """Annotate the innermost active span; no-op without one."""
    sp = current_span()
    if sp is not None:
        sp.attrs.update(attrs)


def span(name: str, cat: str = "internal", **attrs: Any):
    """Open a child span under the ambient context, or no-op without one.

    This is the hook for code that does not own a tracer (backends,
    executor, retry driver, kernel wrappers): if the calling thread is
    inside a ``Tracer.span()`` region the child lands in that tracer;
    otherwise nothing is recorded and the overhead is one ``getattr``.
    ``cat`` becomes the span's ``kind``; remaining keywords become
    attributes (so an attribute may itself be named ``kind``).
    """
    tr = current_tracer()
    if tr is None:
        return _NULL_CTX
    return tr.span(name, cat, attrs=attrs or None)


def instant(name: str, cat: str = "event", **attrs: Any) -> None:
    """Record a zero-duration event under the ambient span, if any."""
    s = _stack()
    if not s:
        return
    tr, parent = s[-1]
    now = tr._clock()
    tr.record(name, cat, now, now, trace_id=parent.trace_id,
              parent_id=parent.span_id, attrs=attrs or None)


class Tracer:
    """Thread-safe span sink with a bounded ring buffer.

    ``capacity`` bounds memory: once full, the oldest spans are
    overwritten and ``dropped`` counts how many were lost (exported
    traces say so).  ``enabled=False`` turns every entry point into a
    no-op that still yields ``None`` — callers guard attribute access
    with ``if sp is not None`` or use `set_attrs()`.
    """

    def __init__(self, capacity: int = 16384, enabled: bool = True,
                 clock=time.perf_counter):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.enabled = enabled
        self.dropped = 0
        self._clock = clock
        self._epoch = clock()
        self._buf: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._ids = itertools.count(1)

    # -- ids -------------------------------------------------------------

    def new_trace_id(self) -> str:
        return "t%06x" % next(self._ids)

    def new_span_id(self) -> str:
        return "s%06x" % next(self._ids)

    # -- recording -------------------------------------------------------

    @contextmanager
    def span(self, name: str, kind: str = "internal", *,
             trace_id: Optional[str] = None,
             parent_id: Optional[str] = None,
             attrs: Optional[Dict[str, Any]] = None) -> Iterator[Optional[Span]]:
        """Open a span; records on exit (including on exception).

        Parentage: explicit ``trace_id``/``parent_id`` win; otherwise
        both are inherited from the innermost active span on this
        thread; otherwise a fresh trace is minted.
        """
        if not self.enabled:
            yield None
            return
        stack = _stack()
        if trace_id is None:
            if parent_id is None and stack:
                _, top = stack[-1]
                trace_id, parent_id = top.trace_id, top.span_id
            elif parent_id is None:
                trace_id = self.new_trace_id()
            else:
                # explicit parent without a trace: inherit the ambient
                # trace if there is one, else mint.
                trace_id = (stack[-1][1].trace_id if stack
                            else self.new_trace_id())
        sp = Span(name=name, kind=kind, trace_id=trace_id,
                  span_id=self.new_span_id(), parent_id=parent_id,
                  t0=self._clock(), thread=threading.get_ident(),
                  attrs=dict(attrs) if attrs else {})
        stack.append((self, sp))
        try:
            yield sp
        except BaseException as exc:
            sp.attrs.setdefault("error", type(exc).__name__)
            raise
        finally:
            stack.pop()
            sp.t1 = self._clock()
            self._append(sp)

    def record(self, name: str, kind: str, t0: float, t1: float, *,
               trace_id: str, span_id: Optional[str] = None,
               parent_id: Optional[str] = None,
               attrs: Optional[Dict[str, Any]] = None) -> Optional[Span]:
        """Record a span whose lifetime was measured externally.

        Used for regions that start on one thread and end on another
        (queue wait, per-query serve roots): the owner pre-allocates
        ``span_id`` so children recorded in between can parent onto it.
        """
        if not self.enabled:
            return None
        sp = Span(name=name, kind=kind, trace_id=trace_id,
                  span_id=span_id or self.new_span_id(),
                  parent_id=parent_id, t0=t0, t1=t1,
                  thread=threading.get_ident(),
                  attrs=dict(attrs) if attrs else {})
        self._append(sp)
        return sp

    def _append(self, sp: Span) -> None:
        with self._lock:
            if len(self._buf) == self._buf.maxlen:
                self.dropped += 1
            self._buf.append(sp)

    # -- reading ---------------------------------------------------------

    def spans(self, trace_id: Optional[str] = None,
              name: Optional[str] = None) -> List[Span]:
        """Snapshot of recorded spans, optionally filtered, in t0 order."""
        with self._lock:
            out = list(self._buf)
        if trace_id is not None:
            out = [s for s in out if s.trace_id == trace_id]
        if name is not None:
            out = [s for s in out if s.name == name]
        out.sort(key=lambda s: s.t0)
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.dropped = 0

    # -- export ----------------------------------------------------------

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (dict); loads in Perfetto as-is.

        Durations become ``ph: "X"`` complete events, zero-duration
        spans become ``ph: "i"`` instants.  Timestamps are microseconds
        rebased on the tracer's epoch.  Span/trace/parent ids ride in
        ``args`` so the tree can be reconstructed from the file.
        """
        events: List[Dict[str, Any]] = []
        for sp in self.spans():
            us0 = (sp.t0 - self._epoch) * 1e6
            args = {"trace_id": sp.trace_id, "span_id": sp.span_id}
            if sp.parent_id:
                args["parent_id"] = sp.parent_id
            for k, v in sp.attrs.items():
                args[k] = v if isinstance(v, (int, float, bool)) else str(v)
            ev: Dict[str, Any] = {
                "name": sp.name, "cat": sp.kind, "pid": 1,
                "tid": sp.thread, "ts": round(us0, 3), "args": args,
            }
            if sp.t1 > sp.t0:
                ev["ph"] = "X"
                ev["dur"] = round((sp.t1 - sp.t0) * 1e6, 3)
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            events.append(ev)
        meta: Dict[str, Any] = {"spans": len(events), "dropped": self.dropped}
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": meta}

    def export_chrome(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.to_chrome(), fh, separators=(",", ":"))
