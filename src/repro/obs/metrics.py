"""Metrics registry: counters/gauges/histograms with Prometheus export.

The serve stack accumulated three generations of one-off telemetry —
``BackendStats`` monotonic counters, scalar ints on ``MLegoService``
guarded by its stats lock, and the ``LatencyTracker`` percentile ring.
This module replaces the scalar generation outright and gives the
other two a single read surface: a `MetricsRegistry` of typed,
labelled metrics that renders both Prometheus text exposition
(`MetricsRegistry.exposition()`) and a JSON-able snapshot
(`MetricsRegistry.snapshot()`).

Two integration styles, chosen per counter:

* **Native** — the metric object *is* the counter.  Everything that
  used to be a bare int on the service (queries, sheds, degradations,
  evictions) increments a registry `Counter` and the service report
  reads the same object back, so exposition and report cannot drift.
* **Mirrored** — structures with their own locking discipline
  (``BackendStats``, breaker snapshots, the retry ledger) stay the
  writers; a collection callback registered via
  `MetricsRegistry.add_callback()` copies them into gauges/counters at
  scrape time.  Both the report and the scrape read the same live
  source, so they agree whenever no traffic lands in between.

`Histogram` doubles as the SLO feed: with ``window > 0`` each label
set also keeps a bounded deque of recent raw samples, and
`HistogramView` exposes the sliding-window ``p50/p95/p99`` /
``len()`` surface ``SLOPolicy.level()`` expects — the cumulative
buckets serve exposition, the window serves control decisions, one
``observe()`` feeds both.

Naming convention (see api/README.md): ``mlego_<subsystem>_<what>``
with Prometheus unit/suffix rules — ``_total`` for counters,
``_seconds`` / ``_bytes`` base units, label keys for the axis that
varies (``backend``, ``site``, ``level``).

Stdlib only; safe to import from anywhere in ``repro``.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "HistogramView",
    "MetricsRegistry",
]

DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_LabelKey = Tuple[str, ...]


def _fmt(v: float) -> str:
    """Prometheus sample formatting: integral floats drop the mantissa."""
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _label_str(names: Tuple[str, ...], values: _LabelKey) -> str:
    if not names:
        return ""
    pairs = ",".join('%s="%s"' % (n, str(v).replace("\\", "\\\\")
                                  .replace('"', '\\"').replace("\n", "\\n"))
                     for n, v in zip(names, values))
    return "{%s}" % pairs


class _Metric:
    """Base: a named family of samples keyed by label values."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Tuple[str, ...] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()

    def _key(self, labels: Dict[str, Any]) -> _LabelKey:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                "metric %r takes labels %r, got %r"
                % (self.name, self.labelnames, tuple(labels)))
        return tuple(str(labels[n]) for n in self.labelnames)


class Counter(_Metric):
    """Monotonically increasing value per label set."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labelnames: Tuple[str, ...] = ()):
        super().__init__(name, help, labelnames)
        self._vals: Dict[_LabelKey, float] = {}

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        k = self._key(labels)
        with self._lock:
            self._vals[k] = self._vals.get(k, 0.0) + amount

    def set_floor(self, value: float, **labels: Any) -> None:
        """Raise the counter to ``value`` if below (mirror-sync helper).

        Used by scrape callbacks that copy an external monotonic
        counter in; never lowers, so the series stays monotone even if
        two mirrors race.
        """
        k = self._key(labels)
        with self._lock:
            if value > self._vals.get(k, 0.0):
                self._vals[k] = float(value)

    def value(self, **labels: Any) -> float:
        k = self._key(labels)
        with self._lock:
            return self._vals.get(k, 0.0)

    def total(self) -> float:
        with self._lock:
            return sum(self._vals.values())

    def series(self) -> Dict[_LabelKey, float]:
        with self._lock:
            return dict(self._vals)


class Gauge(_Metric):
    """Point-in-time value per label set."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labelnames: Tuple[str, ...] = ()):
        super().__init__(name, help, labelnames)
        self._vals: Dict[_LabelKey, float] = {}

    def set(self, value: float, **labels: Any) -> None:
        k = self._key(labels)
        with self._lock:
            self._vals[k] = float(value)

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        k = self._key(labels)
        with self._lock:
            self._vals[k] = self._vals.get(k, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels: Any) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels: Any) -> float:
        k = self._key(labels)
        with self._lock:
            return self._vals.get(k, 0.0)

    def series(self) -> Dict[_LabelKey, float]:
        with self._lock:
            return dict(self._vals)


class _HistSeries:
    __slots__ = ("counts", "total", "count", "window")

    def __init__(self, n_buckets: int, window: int):
        self.counts = [0] * n_buckets
        self.total = 0.0
        self.count = 0
        self.window: Optional[deque] = deque(maxlen=window) if window else None


class Histogram(_Metric):
    """Cumulative-bucket histogram, optionally with a sample window.

    ``buckets`` are upper bounds (``+Inf`` appended implicitly).  With
    ``window > 0`` every label set also keeps the last ``window`` raw
    observations for exact sliding percentiles — that is what the SLO
    loop reads, while exposition always renders the cumulative buckets.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Tuple[str, ...] = (),
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                 window: int = 0):
        super().__init__(name, help, labelnames)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError("need at least one bucket bound")
        self.buckets = bs
        self.window = int(window)
        self._series: Dict[_LabelKey, _HistSeries] = {}

    def _at(self, k: _LabelKey) -> _HistSeries:
        s = self._series.get(k)
        if s is None:
            s = self._series[k] = _HistSeries(len(self.buckets), self.window)
        return s

    def observe(self, value: float, **labels: Any) -> None:
        k = self._key(labels)
        v = float(value)
        with self._lock:
            s = self._at(k)
            for i, ub in enumerate(self.buckets):
                if v <= ub:
                    s.counts[i] += 1
                    break
            s.total += v
            s.count += 1
            if s.window is not None:
                s.window.append(v)

    def count(self, **labels: Any) -> int:
        k = self._key(labels)
        with self._lock:
            s = self._series.get(k)
            return s.count if s else 0

    def sum(self, **labels: Any) -> float:
        k = self._key(labels)
        with self._lock:
            s = self._series.get(k)
            return s.total if s else 0.0

    def window_samples(self, **labels: Any) -> List[float]:
        k = self._key(labels)
        with self._lock:
            s = self._series.get(k)
            return list(s.window) if s and s.window is not None else []

    def percentile(self, p: float, **labels: Any) -> float:
        """Sliding-window nearest-rank percentile (0 with no samples).

        Matches ``LatencyTracker.percentile`` semantics so the SLO
        policy sees identical numbers after the migration.  Requires
        ``window > 0``; cumulative buckets are not interpolated — a
        control loop should not act on bucket-resolution estimates.
        """
        xs = sorted(self.window_samples(**labels))
        if not xs:
            return 0.0
        rank = max(1, math.ceil(p / 100.0 * len(xs)))
        return xs[min(rank, len(xs)) - 1]

    def view(self, **labels: Any) -> "HistogramView":
        return HistogramView(self, dict(labels))

    def series(self) -> Dict[_LabelKey, Tuple[List[int], float, int]]:
        with self._lock:
            return {k: (list(s.counts), s.total, s.count)
                    for k, s in self._series.items()}


class HistogramView:
    """One label set of a `Histogram`, shaped like ``LatencyTracker``.

    Implements ``observe`` / ``percentile`` / ``p50``/``p95``/``p99`` /
    ``len()`` over the histogram's sliding window so it can be handed
    to ``SLOPolicy.level()`` (which duck-types on ``len`` and ``p95``)
    and to ``BackendSLO`` unchanged.
    """

    __slots__ = ("_hist", "_labels")

    def __init__(self, hist: Histogram, labels: Dict[str, Any]):
        self._hist = hist
        self._labels = labels

    def observe(self, value: float) -> None:
        self._hist.observe(value, **self._labels)

    def percentile(self, p: float) -> float:
        return self._hist.percentile(p, **self._labels)

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p95(self) -> float:
        return self.percentile(95.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    def __len__(self) -> int:
        return len(self._hist.window_samples(**self._labels))


class MetricsRegistry:
    """Get-or-create factory plus exposition for a set of metrics.

    ``counter()``/``gauge()``/``histogram()`` are idempotent: a second
    call with the same name returns the existing object (and raises if
    the type or label names disagree — one name, one meaning).
    Callbacks registered with `add_callback()` run before every
    `exposition()`/`snapshot()` so mirrored sources are fresh at
    scrape time.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, _Metric] = {}
        self._callbacks: List[Callable[[], None]] = []
        self._lock = threading.Lock()

    # -- factories -------------------------------------------------------

    def _get_or_make(self, cls, name: str, help: str,
                     labelnames: Iterable[str], **kw: Any) -> Any:
        names = tuple(labelnames)
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls) or m.labelnames != names:
                    raise ValueError(
                        "metric %r re-registered as %s%r (was %s%r)"
                        % (name, cls.kind, names, m.kind, m.labelnames))
                return m
            m = cls(name, help, names, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> Counter:
        return self._get_or_make(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Iterable[str] = (),
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                  window: int = 0) -> Histogram:
        return self._get_or_make(Histogram, name, help, labelnames,
                                 buckets=buckets, window=window)

    def add_callback(self, fn: Callable[[], None]) -> None:
        """Register a pre-scrape sync hook (mirroring external counters)."""
        with self._lock:
            self._callbacks.append(fn)

    def collect(self) -> List[_Metric]:
        """Run callbacks, then return metrics sorted by name."""
        with self._lock:
            cbs = list(self._callbacks)
        for cb in cbs:
            cb()
        with self._lock:
            return [self._metrics[n] for n in sorted(self._metrics)]

    # -- output ----------------------------------------------------------

    def exposition(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        for m in self.collect():
            lines.append("# HELP %s %s" % (m.name, m.help or m.name))
            lines.append("# TYPE %s %s" % (m.name, m.kind))
            if isinstance(m, (Counter, Gauge)):
                series = m.series()
                for key in sorted(series):
                    lines.append("%s%s %s" % (m.name,
                                              _label_str(m.labelnames, key),
                                              _fmt(series[key])))
            elif isinstance(m, Histogram):
                for key, (counts, total, count) in sorted(m.series().items()):
                    cum = 0
                    for ub, c in zip(m.buckets, counts):
                        cum += c
                        ls = _label_str(m.labelnames + ("le",),
                                        key + (_fmt(ub),))
                        lines.append("%s_bucket%s %d" % (m.name, ls, cum))
                    ls = _label_str(m.labelnames + ("le",), key + ("+Inf",))
                    lines.append("%s_bucket%s %d" % (m.name, ls, count))
                    ls = _label_str(m.labelnames, key)
                    lines.append("%s_sum%s %s" % (m.name, ls, _fmt(total)))
                    lines.append("%s_count%s %d" % (m.name, ls, count))
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able dump: {name: {type, labels, series}}."""
        out: Dict[str, Any] = {}
        for m in self.collect():
            if isinstance(m, (Counter, Gauge)):
                series = {"|".join(k) if k else "": v
                          for k, v in m.series().items()}
            else:
                assert isinstance(m, Histogram)
                series = {"|".join(k) if k else "": {
                    "buckets": counts, "sum": total, "count": count,
                } for k, (counts, total, count) in m.series().items()}
            out[m.name] = {"type": m.kind,
                           "labels": list(m.labelnames),
                           "series": series}
        return out
