"""Kernel profiling hooks: profiler annotations + HLO-derived features.

The ``profile=`` mode on the device backends does two things per
merge/E-step/Gibbs launch:

* wraps the launch in a ``jax.profiler.TraceAnnotation`` so a real
  ``jax.profiler.trace()`` capture (TensorBoard / XProf) attributes
  device time to the MLego op that caused it, and
* extracts static flops/bytes features from the launch's *optimized*
  HLO via the in-repo analyzer (``launch/hlo_analyzer.analyze_hlo``)
  and lands them as attributes on the ambient span — the same span
  whose measured milliseconds the calibration log consumes, so one
  trace row carries both the prediction features and the label.

HLO extraction costs a compile, so features are memoized by
``(tag, arg shapes/dtypes, static kwargs)`` — the same key space XLA
itself caches compiles under.  Everything is best-effort: a lowering
or parse failure yields ``{}`` rather than an error on the hot path
(the launch itself already ran or will run regardless).

Keep this module import-light: importing it must not pull in jax at
module import time beyond what the backends already require.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterator, Tuple

from repro.obs import trace as _trace

__all__ = ["annotate", "hlo_features", "clear_feature_cache"]

_FEATURE_KEYS = ("flops", "hbm_bytes", "collective_wire_bytes")

_cache: Dict[Tuple, Dict[str, float]] = {}
_cache_lock = threading.Lock()


@contextmanager
def annotate(name: str) -> Iterator[None]:
    """``jax.profiler.TraceAnnotation`` that degrades to a no-op."""
    try:
        import jax
        cm = jax.profiler.TraceAnnotation(name)
    except Exception:
        yield
        return
    with cm:
        yield


def _shape_sig(x: Any) -> Tuple:
    shape = getattr(x, "shape", None)
    if shape is None:
        return (type(x).__name__,)
    return (tuple(shape), str(getattr(x, "dtype", "?")))


def hlo_features(tag: str, fn: Callable, *args: Any,
                 n_partitions: int = 1, **static: Any) -> Dict[str, float]:
    """Flops/bytes features for ``fn(*args, **static)``'s optimized HLO.

    ``fn`` must be jit-traceable with ``args`` as array arguments and
    ``static`` as keyword constants.  Returns a dict with keys
    ``flops`` / ``hbm_bytes`` / ``collective_wire_bytes`` (floats), or
    ``{}`` when lowering/analysis fails.  Memoized per shape class.
    """
    key = ((tag, int(n_partitions))
           + tuple(_shape_sig(a) for a in args)
           + tuple(sorted((k, repr(v)) for k, v in static.items())))
    with _cache_lock:
        hit = _cache.get(key)
    if hit is not None:
        return dict(hit)
    feats: Dict[str, float] = {}
    try:
        import jax

        from repro.launch.hlo_analyzer import analyze_hlo

        lowered = jax.jit(lambda *xs: fn(*xs, **static)).lower(*args)
        hlo_text = lowered.compile().as_text()
        stats = analyze_hlo(hlo_text, int(n_partitions))
        feats = {k: float(getattr(stats, k)) for k in _FEATURE_KEYS}
    except Exception:
        feats = {}
    with _cache_lock:
        _cache[key] = feats
    return dict(feats)


def annotate_span(prefix: str, feats: Dict[str, float]) -> None:
    """Land HLO features on the ambient span as ``<prefix>_<key>``."""
    if feats:
        _trace.set_attrs(**{"%s_%s" % (prefix, k): v
                            for k, v in feats.items()})


def clear_feature_cache() -> None:
    with _cache_lock:
        _cache.clear()
