"""qwen3-moe-235b-a22b — 128 experts top-8 MoE.

[hf:Qwen/Qwen3-30B-A3B; hf]
94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    n_experts=128,
    moe_top_k=8,
    d_ff_expert=1536,
    rope_theta=1000000.0,
    act="silu",
    sub_quadratic=False,
)
