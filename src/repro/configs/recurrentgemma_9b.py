"""recurrentgemma-9b — RG-LRU + local attention, 1:2.  [arXiv:2402.19427; unverified]

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000.
Pattern: (rec, rec, local) repeated; 38 layers => 12 triples + (rec, rec).
Sub-quadratic (RG-LRU state + 2048 local window) => long_500k runs.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    act="gelu",
    block_pattern=("rec", "rec", "local"),
    window=2048,
    tie_embeddings=True,
    scale_embeds=True,
    sub_quadratic=True,
)
