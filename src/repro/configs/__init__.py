"""Architecture registry: ``--arch <id>`` resolves through here."""
from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ArchConfig,
    ShapeConfig,
)
from repro.configs.lda_default import DEFAULT as LDA_DEFAULT
from repro.configs.lda_default import LDAConfig

from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4
from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3moe
from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.configs.qwen3_1_7b import CONFIG as _qwen3
from repro.configs.smollm_360m import CONFIG as _smollm
from repro.configs.gemma_2b import CONFIG as _gemma
from repro.configs.qwen2_5_14b import CONFIG as _qwen25
from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.whisper_tiny import CONFIG as _whisper
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma

ARCHS = {
    c.name: c
    for c in (
        _llama4,
        _qwen3moe,
        _xlstm,
        _qwen3,
        _smollm,
        _gemma,
        _qwen25,
        _llava,
        _whisper,
        _rgemma,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES_BY_NAME:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES_BY_NAME)}")
    return SHAPES_BY_NAME[name]


def all_cells():
    """All 40 (arch x shape) cells; yields (arch, shape, runnable)."""
    for arch in ARCHS.values():
        for shape in ALL_SHAPES:
            yield arch, shape, arch.supports_shape(shape)


__all__ = [
    "ARCHS",
    "ALL_SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "LDAConfig",
    "LDA_DEFAULT",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "get_arch",
    "get_shape",
    "all_cells",
]
