"""llava-next-34b — VLM backbone, anyres tiling (frontend stub).

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

The vision tower is a STUB: ``input_specs()`` provides precomputed
patch embeddings (anyres default: 2880 patch positions = 5 tiles x 576)
that are spliced in front of the token embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    n_patches=2880,
    rope_theta=1000000.0,
    sub_quadratic=False,
)
