"""xlstm-1.3b — sLSTM + mLSTM blocks.  [arXiv:2405.04517; unverified]

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0: the xLSTM
blocks carry their own up/down projections, there is no separate FFN.
Pattern: 7 mLSTM : 1 sLSTM (period 8) — 42 mLSTM + 6 sLSTM layers.
Sub-quadratic (constant-size recurrent state) => long_500k runs.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("m", "m", "m", "m", "m", "m", "m", "s"),
    tie_embeddings=True,
    sub_quadratic=True,
)
