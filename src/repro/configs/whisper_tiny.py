"""whisper-tiny — enc-dec, conv frontend (STUB).  [arXiv:2212.04356; unverified]

4L d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865.  LayerNorm + GELU.
The conv frontend is a STUB: ``input_specs()`` provides precomputed
mel-frame embeddings (1500 frames) for the encoder.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
    norm="layernorm",
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_seq=1500,
    norm_eps=1e-5,
    sub_quadratic=False,
)
