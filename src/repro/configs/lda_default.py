"""Default LDA configuration for the MLego core (the paper's own model).

Paper setting (§VI.A): K=100 topics, 100 max iterations.  K is padded to
128 on the TPU path for MXU lane alignment (the pad topics carry zero
mass and do not change the posterior — see core/lda.py).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class LDAConfig:
    n_topics: int = 100
    vocab_size: int = 8192
    alpha: float = 0.5         # document-topic Dirichlet prior
    eta: float = 0.01          # topic-word Dirichlet prior
    max_iters: int = 100       # M_i in the paper's cost model
    e_step_iters: int = 20     # inner coordinate-ascent iterations
    gibbs_sweeps: int = 30
    decay: float = 0.95        # DSGS decay factor lambda (Eq. 9)
    mean_change_tol: float = 1e-3
    seed: int = 0

    @property
    def padded_topics(self) -> int:
        return ((self.n_topics + 127) // 128) * 128

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + 127) // 128) * 128


DEFAULT = LDAConfig()
