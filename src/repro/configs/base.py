"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; reduced variants
(for CPU smoke tests) come from ``ArchConfig.reduced()``.  The full
configs are only ever *lowered* (ShapeDtypeStruct dry-run) — never
allocated on this host.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class ArchConfig:
    """A transformer-family architecture from the assigned pool."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- optional knobs -------------------------------------------------
    head_dim: Optional[int] = None        # defaults to d_model // n_heads
    qk_norm: bool = False                 # qwen3-style per-head RMSNorm on q/k
    qkv_bias: bool = False                # qwen2.5-style bias on qkv projections
    act: str = "silu"                     # silu (SwiGLU) | gelu (GeGLU)
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0                    # 0 => dense FFN
    moe_top_k: int = 0
    d_ff_expert: int = 0                  # per-expert hidden dim
    n_shared_experts: int = 0             # always-on shared expert(s)
    capacity_factor: float = 1.25

    # Block pattern for non-pure-attention stacks.  Entries:
    #   "attn"  — global self attention + FFN
    #   "local" — sliding-window attention + FFN
    #   "rec"   — RG-LRU recurrent block + FFN
    #   "m"     — mLSTM block
    #   "s"     — sLSTM block
    block_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0                       # sliding-window size for "local"

    # Encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0                  # stub frontend frame count

    # VLM (llava): number of stub patch-embedding positions
    n_patches: int = 0

    # Whether the architecture is sub-quadratic and can run long_500k
    sub_quadratic: bool = False

    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    scale_embeds: bool = False            # gemma-style sqrt(d) embed scaling

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a multiple of 256 (TPU lane alignment; also
        makes V divisible by the 16-wide `model` mesh axis)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def supports_shape(self, shape: ShapeConfig) -> bool:
        """Which assigned (arch x shape) cells are runnable (cf. DESIGN.md)."""
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False
        return True

    # --- parameter accounting (used by the cost model & roofline) ------
    def param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        n = v * d  # embed
        if not self.tie_embeddings:
            n += v * d  # unembed
        for kind in self.layer_kinds():
            if kind in ("attn", "local"):
                n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                n += self._ffn_params()
            elif kind == "rec":
                # Griffin recurrent block: in/out proj + conv4 + gates
                d_rnn = d
                n += 2 * d * d_rnn + 4 * d_rnn + 2 * d_rnn * d_rnn + d_rnn * d
                n += self._ffn_params()
            elif kind == "m":
                # mLSTM: qkv + gates + out
                n += 4 * d * d + 2 * d * self.n_heads
            elif kind == "s":
                n += 4 * d * d + 4 * d * self.n_heads
            n += 2 * d  # norms
        if self.is_encoder_decoder:
            # encoder layers: attn + ffn; decoder cross-attn already in layers
            for _ in range(self.n_encoder_layers):
                n += 4 * d * d + self._ffn_params() + 2 * d
            # decoder cross attention
            n += self.n_layers * (4 * d * d)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        expert_p = 3 * d * self.d_ff_expert
        all_expert = self.n_layers * self.n_experts * expert_p
        active_expert = self.n_layers * (self.moe_top_k + self.n_shared_experts) * expert_p
        return total - all_expert + active_expert

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.is_moe:
            return (
                self.n_experts * 3 * d * self.d_ff_expert
                + self.n_shared_experts * 3 * d * self.d_ff_expert
                + d * self.n_experts  # router
            )
        return 3 * d * self.d_ff  # gate/up/down

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expand block_pattern to exactly n_layers entries."""
        reps = (self.n_layers + len(self.block_pattern) - 1) // len(self.block_pattern)
        return (self.block_pattern * reps)[: self.n_layers]

    def model_flops(self, shape: ShapeConfig) -> float:
        """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for §Roofline."""
        n = self.active_param_count()
        if shape.kind == "train":
            return 6.0 * n * shape.tokens
        if shape.kind == "prefill":
            return 2.0 * n * shape.tokens
        # decode: one new token per sequence
        return 2.0 * n * shape.global_batch

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        pattern = self.block_pattern
        n_layers = max(2, min(len(pattern) + 1, 4))
        if self.family == "hybrid":
            n_layers = 4  # covers (rec, rec, attn) + tail rec
        if self.family == "ssm":
            n_layers = 3  # m, m, s with period shrunk below
            pattern = ("m", "m", "s")
        kv = min(self.n_kv_heads, 2)
        heads = max(2 * kv, 2)
        hd = 16
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=hd * heads,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=64,
            d_ff_expert=32 if self.is_moe else 0,
            n_experts=min(self.n_experts, 4),
            moe_top_k=min(self.moe_top_k, 2) if self.is_moe else 0,
            capacity_factor=4.0 if self.is_moe else self.capacity_factor,
            vocab_size=256,
            block_pattern=pattern,
            window=min(self.window, 16) if self.window else 0,
            n_encoder_layers=2 if self.is_encoder_decoder else 0,
            encoder_seq=8 if self.is_encoder_decoder else 0,
            n_patches=4 if self.n_patches else 0,
            dtype="float32",
        )
