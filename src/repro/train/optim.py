"""Optimizers — AdamW and Adafactor, pure-pytree JAX implementations.

AdamW keeps f32 (m, v) per parameter (2x param memory, FSDP-sharded by
the same rules as the parameters).  Adafactor factors the second moment
of matrices into row/col statistics (O(n+m) instead of O(nm)) — the
memory-saving choice for the large dry-run cells.

Both expose the same (init, update) pair:

    state = init(params)
    new_params, new_state, gnorm = update(grads, state, params, step)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # adafactor
    decay_offset: float = 0.8    # beta2_t = 1 - step^-decay_offset
    factored_min_dim: int = 128


def schedule(cfg: OptimizerConfig, step) -> jnp.ndarray:
    """Linear warmup -> constant (the dry-run cells run a few hundred
    steps; decay schedules are a config knob, not a structural need)."""
    warm = jnp.minimum((step.astype(jnp.float32) + 1.0)
                       / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def _map3(fn, params, grads, *states):
    """tree-map ``fn(p, g, *s) -> (new_p, *new_s)`` over flattened leaves."""
    p_flat, treedef = jax.tree_util.tree_flatten(params)
    g_flat = treedef.flatten_up_to(grads)
    s_flats = [treedef.flatten_up_to(s) for s in states]
    outs = [fn(p, g, *ss) for p, g, *ss in zip(p_flat, g_flat, *s_flats)]
    n_out = len(outs[0])
    return tuple(jax.tree_util.tree_unflatten(treedef, [o[i] for o in outs])
                 for i in range(n_out))


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(cfg: OptimizerConfig):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = schedule(cfg, step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
            step_ = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            if p.ndim >= 2:   # no decay on norms/biases
                step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

        new_params, new_m, new_v = _map3(upd, params, grads,
                                         state["m"], state["v"])
        return new_params, {"m": new_m, "v": new_v}, gnorm

    return init, update


# ---------------------------------------------------------------------------
# Adafactor (factored second moment)
# ---------------------------------------------------------------------------

def _is_factored(p, min_dim: int) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= min_dim and p.shape[-2] >= min_dim


def adafactor(cfg: OptimizerConfig):
    def init(params):
        def st(p):
            if _is_factored(p, cfg.factored_min_dim):
                return (jnp.zeros(p.shape[:-1], jnp.float32),        # vr
                        jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                  jnp.float32))                      # vc
            return (jnp.zeros(p.shape, jnp.float32),)                # v
        return {"s": jax.tree.map(st, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = schedule(cfg, step)
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-cfg.decay_offset)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + 1e-30
            if len(s) == 2:
                vr = beta2 * s[0] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * s[1] + (1 - beta2) * g2.mean(-2)
                denom = jnp.sqrt(
                    vr[..., :, None] * vc[..., None, :]
                    / jnp.maximum(vr.mean(-1)[..., None, None], 1e-30))
                ns = (vr, vc)
            else:
                v = beta2 * s[0] + (1 - beta2) * g2
                denom = jnp.sqrt(v)
                ns = (v,)
            step_ = g / jnp.maximum(denom, 1e-30)
            # adafactor update clipping (RMS <= 1)
            rms = jnp.sqrt(jnp.mean(jnp.square(step_)) + 1e-30)
            step_ = step_ / jnp.maximum(1.0, rms)
            if p.ndim >= 2:
                step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), ns

        p_flat, treedef = jax.tree_util.tree_flatten(params)
        g_flat = treedef.flatten_up_to(grads)
        s_flat = treedef.flatten_up_to(state["s"])
        outs = [upd(p, g, s) for p, g, s in zip(p_flat, g_flat, s_flat)]
        new_params = jax.tree_util.tree_unflatten(treedef,
                                                  [o[0] for o in outs])
        new_s = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return new_params, {"s": new_s}, gnorm

    return init, update


def build_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return adamw(cfg)
    if cfg.name == "adafactor":
        return adafactor(cfg)
    raise ValueError(f"unknown optimizer {cfg.name!r}")
