"""Training loop: train_step factory + fault-tolerant Trainer.

``make_train_step`` composes model.loss + grad + optimizer update into
one jit-able function — the exact function the multi-pod dry-run lowers
with in/out shardings.  ``Trainer`` wraps it with the checkpoint
manager (atomic save/restore of params, optimizer state, PRNG key and
the data cursor) so a killed-and-restarted run continues bit-identically
— the restart test in tests/test_checkpoint.py asserts this.

Straggler/fault policy: training is synchronous SPMD inside a pod; the
LDA side (the paper's workload) tolerates stragglers through the DSGS
decay merge (distributed/merge_collective.py) and recovers failed
partitions by retraining only the lost range (core/query.py).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.sharding import MeshEnv, infer_param_specs, set_env
from repro.models.model import Model, build_model
from repro.train.optim import OptimizerConfig, build_optimizer


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray            # () int32
    rng: jnp.ndarray             # PRNGKey
    data_cursor: int = 0         # host-side; checkpointed


def make_train_step(model: Model, opt_cfg: OptimizerConfig, env: MeshEnv,
                    *, remat: bool = True):
    """(params, opt_state, step, batch) -> (params', opt_state', metrics)."""
    _, opt_update = build_optimizer(opt_cfg)

    from repro.models.model import _dtype, cast_params

    def train_step(params, opt_state, step, batch):
        with set_env(env):
            # Differentiate wrt the COMPUTE-dtype copies: the per-layer
            # gradient sync inside the backward scan then moves bf16
            # instead of f32 (halves the dominant collective on the
            # dense train cells).  Masters stay f32 for the update.
            dt = _dtype(model.cfg)
            p_compute = cast_params(params, dt)

            def loss_fn(p):
                loss, metrics = model.loss(p, batch, env, remat=remat)
                return loss, metrics

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(p_compute)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            if env.mesh.size > 1:
                # ZeRO gradient layout: pin grads to the master sharding
                # (reduce-scatter where the partitioner honors it; the
                # in-loop dW sync is carried full by GSPMD until the
                # Shardy migration — documented in EXPERIMENTS.md §Perf).
                from repro.distributed.sharding import param_shardings
                grads = jax.tree.map(
                    jax.lax.with_sharding_constraint, grads,
                    param_shardings(grads, env))
            new_params, new_opt, gnorm = opt_update(grads, opt_state,
                                                    params, step)
        out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return new_params, new_opt, step + 1, out_metrics

    return train_step


class Trainer:
    def __init__(self, model: Model, opt_cfg: OptimizerConfig, env: MeshEnv,
                 *, ckpt_dir: Optional[str] = None, keep: int = 3,
                 save_every: int = 50, remat: bool = True, seed: int = 0):
        self.model = model
        self.opt_cfg = opt_cfg
        self.env = env
        self.save_every = save_every
        self.ckpt = (CheckpointManager(ckpt_dir, keep=keep)
                     if ckpt_dir else None)
        opt_init, _ = build_optimizer(opt_cfg)
        self._opt_init = opt_init
        self._step_fn = jax.jit(make_train_step(model, opt_cfg, env,
                                                remat=remat))
        self._seed = seed

    # ------------------------------------------------------------------
    def init_state(self) -> TrainState:
        rng = jax.random.PRNGKey(self._seed)
        params = self.model.init(rng)
        return TrainState(params=params,
                          opt_state=self._opt_init(params),
                          step=jnp.zeros((), jnp.int32),
                          rng=rng, data_cursor=0)

    def restore_or_init(self) -> TrainState:
        if self.ckpt is not None:
            loaded = self.ckpt.restore_latest()
            if loaded is not None:
                tree, meta = loaded
                return TrainState(params=tree["params"],
                                  opt_state=tree["opt_state"],
                                  step=jnp.asarray(meta["step"], jnp.int32),
                                  rng=jnp.asarray(tree["rng"]),
                                  data_cursor=int(meta["data_cursor"]))
        return self.init_state()

    def save(self, state: TrainState) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(
            {"params": state.params, "opt_state": state.opt_state,
             "rng": state.rng},
            meta={"step": int(state.step),
                  "data_cursor": int(state.data_cursor)},
            step=int(state.step))

    # ------------------------------------------------------------------
    def fit(self, state: TrainState, batches: Iterator[Dict[str, Any]],
            n_steps: int, log_every: int = 10,
            log_fn: Callable[[str], None] = print) -> TrainState:
        t0 = time.perf_counter()
        for i in range(n_steps):
            batch = next(batches)
            params, opt_state, step, metrics = self._step_fn(
                state.params, state.opt_state, state.step, batch)
            state = TrainState(params=params, opt_state=opt_state,
                               step=step, rng=state.rng,
                               data_cursor=state.data_cursor + 1)
            if log_every and (i + 1) % log_every == 0:
                dt = time.perf_counter() - t0
                log_fn(f"step {int(state.step):5d} "
                       f"loss {float(metrics['loss']):.4f} "
                       f"gnorm {float(metrics['grad_norm']):.3f} "
                       f"({dt / (i + 1):.3f}s/step)")
            if self.ckpt is not None and int(state.step) % self.save_every == 0:
                self.save(state)
        if self.ckpt is not None:
            self.save(state)
        return state
