from repro.train.optim import OptimizerConfig, build_optimizer
from repro.train.trainer import Trainer, TrainState, make_train_step

__all__ = ["OptimizerConfig", "build_optimizer", "Trainer", "TrainState",
           "make_train_step"]
