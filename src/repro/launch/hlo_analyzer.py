"""Trip-count-aware HLO analysis: FLOPs, HBM bytes, collective bytes.

Why not ``compiled.cost_analysis()``?  XLA's HloCostAnalysis visits a
``while`` body ONCE, so everything inside a ``lax.scan`` (our layer
stacks, ring-attention steps, E-step fixed points) is undercounted by
its trip count — for a 94-layer scan that is a 94x error.  This module
re-derives the three roofline inputs from the optimized HLO text with
loop multipliers propagated through the call graph:

  * computations are parsed into (ops, shapes) tables;
  * a worklist walk from ENTRY accumulates a multiplier per computation:
    ``while`` bodies/conditions multiply by the loop trip count (parsed
    from backend_config known_trip_count, falling back to the condition
    constant), fusions/calls/conditionals/to_apply inherit x1
    (conditionals count every branch — a documented upper bound);
  * FLOPs: 2 * numel(out) * prod(contracting dims) per ``dot``
    (+ numel for transcendental-heavy elementwise sets — negligible and
    omitted), times the computation multiplier;
  * HBM bytes: operand+output bytes of boundary ops in control
    computations (fusion calls are the boundary — their internals are
    on-chip), with in-place semantics for dynamic-update-slice and
    row-access semantics for gather/dynamic-slice;
  * collective wire bytes: ring-algorithm per-chip costs per op
    (all-reduce 2x(n-1)/n, all-gather/all-to-all (n-1)/n,
    reduce-scatter (n-1)x, collective-permute 1x), times multiplier.

Validated in tests against cost_analysis on loop-free graphs and
against hand-counted scan examples.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s+\(.*\)\s*->.*\{$")
_CALLED_RE = re.compile(
    r"(?:calls|body|condition|to_apply|true_computation|false_computation)"
    r"=(%[\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[\\"{:n]*?(\d+)')

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_ZERO_COST = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "while", "conditional", "call", "after-all",
              "add-dependency", "reshape", "iota", "partition-id",
              "replica-id", "custom-call", "rng-bit-generator",
              "get-dimension-size", "domain", "opt-barrier"}


def _array_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _ARRAY_RE.findall(shape_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _array_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _numel(shape_str: str) -> int:
    total = 0
    for _, dims in _array_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    shape: str            # result shape string
    kind: str             # op code
    rest: str             # remainder of the line (operands + attrs)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, str]        # op name -> result shape


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    # bytes inside named_scope("kernel_interior") regions — traffic the
    # Pallas kernels keep in VMEM on the TPU target; the roofline
    # reports memory terms with and without it.
    hbm_bytes_kernel_interior: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    collective_bytes_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    flops_by_comp: Dict[str, float] = dataclasses.field(default_factory=dict)
    loop_trip_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _HDR_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], {})
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.ops.append(op)
            cur.shapes[op.name] = op.shape
    return comps, entry


def _while_trip_count(op: Op, comps: Dict[str, Computation]) -> Optional[int]:
    m = _TRIP_RE.search(op.rest)
    if m:
        return int(m.group(1))
    # fall back: condition computation comparing against a constant
    cm = re.search(r"condition=(%[\w.\-]+)", op.rest)
    if cm and cm.group(1) in comps:
        cond = comps[cm.group(1)]
        consts = [o for o in cond.ops if o.kind == "constant"]
        if len(consts) == 1:
            vm = re.search(r"constant\((\d+)\)", consts[0].rest)
            if vm is None:
                vm = re.search(r"\((\d+)\)", "(" + consts[0].rest)
            if vm:
                return int(vm.group(1))
    return None


def _static_edges(comps: Dict[str, Computation], stats: HloStats
                  ) -> Dict[str, List[Tuple[str, float]]]:
    """caller -> [(callee, per-call multiplier)] from the op list."""
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    for comp in comps.values():
        for op in comp.ops:
            factor = 1.0
            if op.kind == "while":
                tc = _while_trip_count(op, comps)
                if tc is None:
                    stats.unknown_trip_loops += 1
                    tc = 1
                stats.loop_trip_counts[op.name] = tc
                factor = float(tc)
            called = _CALLED_RE.findall(op.rest)
            bm = _BRANCHES_RE.search(op.rest)
            if bm:
                called += [c.strip() for c in bm.group(1).split(",")]
            for c in called:
                if c in comps:
                    edges[comp.name].append((c, factor))
    return edges


def compute_multipliers(comps: Dict[str, Computation], entry: str,
                        stats: HloStats) -> Dict[str, float]:
    """Accumulate execution counts per computation in topological order
    (the computation call graph is a DAG)."""
    edges = _static_edges(comps, stats)
    # topo order via DFS from entry
    order: List[str] = []
    seen = set()

    def dfs(name: str):
        if name in seen:
            return
        seen.add(name)
        for c, _ in edges.get(name, ()):
            dfs(c)
        order.append(name)

    dfs(entry)
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry] = 1.0
    for name in reversed(order):          # callers before callees
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for c, factor in edges.get(name, ()):
            mult[c] = mult.get(c, 0.0) + m * factor
    return mult


def _classify(comps: Dict[str, Computation]) -> Dict[str, str]:
    """computation -> 'control' (bytes counted at op boundary) or
    'fused' (on-chip: bytes not counted, flops still counted)."""
    cls = {c: "control" for c in comps}
    for comp in comps.values():
        for op in comp.ops:
            refs = _CALLED_RE.findall(op.rest)
            if op.kind in ("fusion", "reduce", "map", "sort", "scatter",
                           "reduce-window", "select-and-scatter",
                           "all-reduce", "reduce-scatter"):
                for r in refs:
                    if r in cls:
                        cls[r] = "fused"
    return cls


def _operand_names(op: Op) -> List[str]:
    # operands are at the start of `rest`, up to the closing paren at depth 0
    depth = 1
    end = 0
    for i, ch in enumerate(op.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = op.rest[:end]
    return re.findall(r"%[\w.\-]+", inner)


def _dot_flops(op: Op, comp: Computation) -> float:
    out_n = _numel(op.shape)
    lhs = _operand_names(op)
    if not lhs:
        return 0.0
    lhs_shape = comp.shapes.get(lhs[0])
    if lhs_shape is None:
        return 0.0
    dims = _array_dims(lhs_shape)
    if not dims:
        return 0.0
    lhs_dims = dims[0][1]
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    k = 1
    if cm and cm.group(1):
        for c in cm.group(1).split(","):
            ci = int(c)
            if ci < len(lhs_dims):
                k *= lhs_dims[ci]
    return 2.0 * out_n * k


def _op_bytes(op: Op, comp: Computation,
              comps: Optional[Dict[str, Computation]] = None) -> float:
    """Approximate HBM traffic of one boundary op.

    Slicing semantics: gather / dynamic-slice read only the rows they
    produce; dynamic-update-slice / scatter write (and read) only the
    update region (XLA performs them in place at loop boundaries).  For
    ``fusion`` ops the same rules are applied *through* the fusion: an
    operand whose only consumers inside the fused computation are
    slices is charged at the sliced size, and a fused root DUS is
    charged as in-place — otherwise scan bodies that slice stacked
    parameters would be billed the whole stack every iteration.
    """
    out_b = _shape_bytes(op.shape)
    if op.kind in ("gather", "dynamic-slice"):
        return 2.0 * out_b                       # read rows + write out
    if op.kind in ("dynamic-update-slice",):
        ops_ = _operand_names(op)
        upd = comp.shapes.get(ops_[1]) if len(ops_) > 1 else None
        ub = _shape_bytes(upd) if upd else out_b
        return 2.0 * ub                          # in-place: read+write update
    if op.kind == "scatter":
        ops_ = _operand_names(op)
        upd = comp.shapes.get(ops_[-1]) if ops_ else None
        ub = _shape_bytes(upd) if upd else out_b
        return 2.0 * ub
    if op.kind == "fusion" and comps is not None:
        return _fusion_bytes(op, comp, comps)
    in_b = 0.0
    for name in _operand_names(op):
        s = comp.shapes.get(name)
        if s is not None:
            in_b += _shape_bytes(s)
    return in_b + out_b


_SLICE_KINDS = ("dynamic-slice", "gather", "slice")


def _fusion_bytes(op: Op, comp: Computation,
                  comps: Dict[str, Computation]) -> float:
    m = re.search(r"calls=(%[\w.\-]+)", op.rest)
    operands = _operand_names(op)
    if not m or m.group(1) not in comps:
        in_b = sum(_shape_bytes(comp.shapes.get(n, "")) for n in operands)
        return in_b + _shape_bytes(op.shape)
    fused = comps[m.group(1)]
    # map parameter index -> consumers inside the fused computation
    param_name = {}
    for fop in fused.ops:
        if fop.kind == "parameter":
            # Op.rest holds everything after "parameter(" -> "N), ..."
            pm = re.match(r"(\d+)\)", fop.rest)
            if pm:
                param_name[int(pm.group(1))] = fop.name
    consumers: Dict[str, List[Op]] = {}
    for fop in fused.ops:
        for name in _operand_names(fop):
            consumers.setdefault(name, []).append(fop)

    # root DUS (possibly behind a bitcast): in-place semantics
    root = fused.ops[-1] if fused.ops else None
    while root is not None and root.kind in ("bitcast", "reshape"):
        prev = _operand_names(root)
        root = next((f for f in fused.ops if prev and f.name == prev[0]),
                    None)
    dus_base = None
    dus_update_bytes = 0.0
    if root is not None and root.kind == "dynamic-update-slice":
        ops_ = _operand_names(root)
        if len(ops_) > 1:
            dus_base = ops_[0]
            upd = fused.shapes.get(ops_[1])
            dus_update_bytes = _shape_bytes(upd) if upd else 0.0

    in_b = 0.0
    for i, operand in enumerate(operands):
        pname = param_name.get(i)
        full = _shape_bytes(comp.shapes.get(operand, ""))
        if pname is None:
            in_b += full
            continue
        if pname == dus_base or (
                dus_base is not None
                and _only_feeds(consumers, pname, dus_base)):
            continue   # untouched in-place base
        cons = consumers.get(pname, [])
        if cons and all(c.kind in _SLICE_KINDS for c in cons):
            in_b += min(sum(_shape_bytes(c.shape) for c in cons), full)
        else:
            in_b += full

    if dus_base is not None:
        return in_b + 2.0 * dus_update_bytes
    return in_b + _shape_bytes(op.shape)


def _only_feeds(consumers: Dict[str, List[Op]], pname: str,
                target: str) -> bool:
    cons = consumers.get(pname, [])
    return len(cons) == 1 and cons[0].name == target and \
        cons[0].kind in ("bitcast", "reshape")


def _collective_wire(op: Op, n_default: int) -> Tuple[str, float, int]:
    kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
    out_b = _shape_bytes(op.shape)
    m = re.search(r"replica_groups=\{\{([^}]*)\}", op.rest)
    if m:
        n = len(m.group(1).split(","))
    else:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.rest)
        n = int(m.group(2)) if m else n_default
    frac = (n - 1) / max(n, 1)
    if kind == "all-reduce":
        wire = 2.0 * out_b * frac
    elif kind == "all-gather":
        wire = out_b * frac
    elif kind == "reduce-scatter":
        wire = out_b * (n - 1)
    elif kind == "all-to-all":
        wire = out_b * frac
    else:  # collective-permute
        wire = float(out_b)
    return kind, wire, n


def analyze_hlo(hlo: str, n_partitions: int) -> HloStats:
    comps, entry = parse_computations(hlo)
    stats = HloStats()
    if entry is None:
        return stats
    mult = compute_multipliers(comps, entry, stats)
    cls = _classify(comps)
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0.0:
            continue
        fused = cls[comp.name] == "fused"
        comp_flops = 0.0
        for op in comp.ops:
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base in _COLLECTIVES:
                kind, wire, _ = _collective_wire(op, n_partitions)
                stats.collective_wire_bytes += m * wire
                stats.collective_counts[kind] = (
                    stats.collective_counts.get(kind, 0) + int(m))
                stats.collective_bytes_by_kind[kind] = (
                    stats.collective_bytes_by_kind.get(kind, 0.0) + m * wire)
                if not fused:
                    stats.hbm_bytes += m * _op_bytes(op, comp, comps)
                continue
            if op.kind in ("dot", "convolution"):
                comp_flops += _dot_flops(op, comp)
            if fused or op.kind in _ZERO_COST or op.kind.endswith("-done"):
                continue
            b = m * _op_bytes(op, comp, comps)
            stats.hbm_bytes += b
            if "kernel_interior" in op.rest:
                stats.hbm_bytes_kernel_interior += b
        if comp_flops:
            stats.flops += m * comp_flops
            stats.flops_by_comp[comp.name] = m * comp_flops
    return stats


def byte_hotspots(hlo: str, n_partitions: int, top: int = 25
                  ) -> List[Tuple[float, str, str, str]]:
    """Debug view: largest HBM byte contributors as
    (bytes, computation, op kind, op name)."""
    comps, entry = parse_computations(hlo)
    stats = HloStats()
    mult = compute_multipliers(comps, entry, stats)
    cls = _classify(comps)
    rows = []
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0.0 or cls[comp.name] == "fused":
            continue
        for op in comp.ops:
            if op.kind in _ZERO_COST or op.kind.endswith("-done"):
                continue
            b = m * _op_bytes(op, comp, comps)
            if b > 0:
                rows.append((b, comp.name, op.kind, op.name))
    return sorted(rows, reverse=True)[:top]
