"""Re-derive roofline stats from saved dry-run HLO (no recompilation).

The analyzer evolves during perf iteration; this tool re-runs
``analyze_hlo`` over every ``*.hlo.gz`` artifact and patches the
matching JSON in place.

    PYTHONPATH=src python -m repro.launch.reanalyze --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.launch.hlo_analyzer import analyze_hlo

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    n = 0
    for hlo_path in sorted(glob.glob(os.path.join(args.dir, "*.hlo.gz"))):
        json_path = hlo_path[: -len(".hlo.gz")] + ".json"
        if not os.path.exists(json_path):
            continue
        with open(json_path) as f:
            rec = json.load(f)
        with gzip.open(hlo_path, "rt") as f:
            hlo = f.read()
        st = analyze_hlo(hlo, rec["n_devices"])
        rec["hlo_analysis"] = {
            "flops": st.flops,
            "hbm_bytes_kernel_interior": st.hbm_bytes_kernel_interior,
            "hbm_bytes": st.hbm_bytes,
            "collective_wire_bytes": st.collective_wire_bytes,
            "collective_counts": st.collective_counts,
            "collective_bytes_by_kind": st.collective_bytes_by_kind,
            "unknown_trip_loops": st.unknown_trip_loops,
        }
        rec["roofline"] = {
            "compute_s": st.flops / PEAK_FLOPS,
            "memory_s": st.hbm_bytes / HBM_BW,
            "collective_s": st.collective_wire_bytes / ICI_BW,
            "memory_kernelized_s": (st.hbm_bytes - st.hbm_bytes_kernel_interior) / HBM_BW,
        }
        rec["roofline"]["dominant"] = max(
            ("compute_s", "memory_s", "collective_s"),
            key=rec["roofline"].get)
        with open(json_path, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"re-analyzed {n} cells")


if __name__ == "__main__":
    main()
