"""Serving launcher: batched prefill + greedy decode.

``--reduced`` executes the smoke-scale config end-to-end on this host;
the full cells are exercised through launch/dryrun.py (prefill_32k /
decode_32k / long_500k lower the same functions this driver calls).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.lm import encoder_frames, make_batch
from repro.distributed.sharding import single_device_env, set_env
from repro.models.model import build_model


def generate(model, params, batch, env, *, steps: int, cache_len: int):
    """Prefill the prompt then greedy-decode ``steps`` tokens."""
    with set_env(env):
        logits, caches = model.prefill(params, batch, env,
                                       cache_len=cache_len)
        s = batch["tokens"].shape[1]
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

        @jax.jit
        def step(params, caches, tok, pos):
            lg, caches = model.decode_step(params, caches, tok, pos, env)
            nxt = jnp.argmax(lg[:, -1], axis=-1)[:, None].astype(jnp.int32)
            return nxt, caches

        for i in range(steps):
            out.append(tok)
            tok, caches = step(params, caches, tok,
                               jnp.asarray(s + i, jnp.int32))
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    env = single_device_env(profile="serve")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, args.batch, args.prompt_len, 0, 0)
    batch.pop("labels", None)
    t0 = time.perf_counter()
    toks = generate(model, params, batch, env, steps=args.gen_len,
                    cache_len=args.prompt_len + args.gen_len)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen_len / dt:.1f} tok/s)")
    print("sample:", toks[0].tolist())


if __name__ == "__main__":
    main()
