import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count on
first init, and the production meshes need 512 placeholder host
devices ((2,16,16) multi-pod; the single-pod (16,16) mesh uses the
first 256).

For each cell this driver:
  1. builds the LoweringSpec (ShapeDtypeStruct inputs — no allocation),
  2. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``,
  3. records memory_analysis(), cost_analysis(), and the collective
     byte account parsed from the optimized HLO,
  4. writes one JSON artifact per cell under --out.

Any failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the system, not in the cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single,multi --out experiments/dryrun
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import ALL_SHAPES, ARCHS, get_arch, get_shape
from repro.distributed.sharding import MeshEnv
from repro.launch.hlo_analyzer import analyze_hlo
from repro.launch.mesh import make_env
from repro.launch.specs import make_spec

# TPU v5e hardware model for the roofline terms (per chip).
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


def run_cell(arch: str, shape_name: str, env: MeshEnv,
             mesh_name: str, hlo_path: Optional[str] = None
             ) -> Dict[str, Any]:
    t0 = time.perf_counter()
    spec = make_spec(arch, shape_name, env)
    n_dev = env.mesh.size
    jitted = jax.jit(spec.step, in_shardings=spec.in_shardings,
                     out_shardings=spec.out_shardings)
    with env.mesh:
        lowered = jitted.lower(*spec.args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    if hlo_path:
        import gzip
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)
    st = analyze_hlo(hlo, n_dev)

    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": n_dev,
        "mode": spec.static.get("mode"),
        "optimizer": spec.static.get("optimizer"),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # memory_analysis is per-device on SPMD modules
        "bytes_per_device": {
            "argument": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak": int(getattr(mem, "peak_memory_in_bytes", 0)) or None,
        },
        # XLA's own numbers (while bodies counted once) for reference
        "xla_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        # trip-count-aware analysis (per device)
        "hlo_analysis": {
            "flops": st.flops,
            "hbm_bytes_kernel_interior": st.hbm_bytes_kernel_interior,
            "hbm_bytes": st.hbm_bytes,
            "collective_wire_bytes": st.collective_wire_bytes,
            "collective_counts": st.collective_counts,
            "collective_bytes_by_kind": st.collective_bytes_by_kind,
            "unknown_trip_loops": st.unknown_trip_loops,
        },
    }
    # roofline terms (seconds per step, per chip)
    out["roofline"] = {
        "compute_s": st.flops / PEAK_FLOPS,
        "memory_s": st.hbm_bytes / HBM_BW,
        "collective_s": st.collective_wire_bytes / ICI_BW,
        "memory_kernelized_s": (st.hbm_bytes - st.hbm_bytes_kernel_interior) / HBM_BW,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=out["roofline"].get)
    out["roofline"]["dominant"] = dom
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = ([s.name for s in ALL_SHAPES] if args.shape == "all"
              else args.shape.split(","))
    meshes = args.mesh.split(",")
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for mesh_name in meshes:
        env = make_env(multi_pod=(mesh_name == "multi"))
        for arch in archs:
            cfg = get_arch(arch)
            for shape_name in shapes:
                shape = get_shape(shape_name)
                tag = f"{arch}__{shape_name}__{mesh_name}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    n_ok += 1
                    continue
                if not cfg.supports_shape(shape):
                    print(f"SKIP {tag} (full attention at 500k)")
                    n_skip += 1
                    continue
                try:
                    rec = run_cell(arch, shape_name, env, mesh_name,
                                   hlo_path=os.path.join(
                                       args.out, tag + ".hlo.gz"))
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(f"OK   {tag}: compile {rec['compile_s']:.1f}s "
                          f"compute {r['compute_s']*1e3:.2f}ms "
                          f"memory {r['memory_s']*1e3:.2f}ms "
                          f"coll {r['collective_s']*1e3:.2f}ms "
                          f"-> {r['dominant']}", flush=True)
                    n_ok += 1
                except Exception:
                    print(f"FAIL {tag}\n{traceback.format_exc()}",
                          flush=True)
                    n_fail += 1
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
