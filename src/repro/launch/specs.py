"""Per-cell lowering specs: (arch × shape × mesh) -> jit-able step +
ShapeDtypeStruct inputs + shardings.

This is the single source of truth for what the multi-pod dry-run
lowers, what the launchers execute, and what the roofline reads.  No
device memory is ever allocated here — parameters, optimizer state and
caches are all ``jax.eval_shape`` trees.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, get_shape
from repro.configs.base import ArchConfig, ShapeConfig
from repro.data.lm import encoder_frames
from repro.distributed.sharding import (
    MeshEnv,
    batch_specs,
    cache_specs,
    infer_param_specs,
    shardings_of,
)
from repro.models.model import Model, build_model
from repro.train.optim import OptimizerConfig, build_optimizer
from repro.train.trainer import make_train_step

# Optimizer-state memory policy: factored second moment above this many
# parameters (AdamW's 2x f32 state does not fit HBM for the 100B+ cells).
ADAFACTOR_THRESHOLD = 50e9


@dataclasses.dataclass
class LoweringSpec:
    name: str
    step: Callable               # positional-args function to jit
    args: Tuple[Any, ...]        # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    static: Dict[str, Any]


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def make_inputs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {"tokens": _struct((b, s), jnp.int32),
               "labels": _struct((b, s), jnp.int32)}
        if cfg.family == "vlm" and cfg.n_patches:
            out["patch_embeds"] = _struct((b, min(cfg.n_patches, s),
                                           cfg.d_model), jnp.float32)
        if cfg.is_encoder_decoder:
            out["frames"] = _struct((b, encoder_frames(cfg), cfg.d_model),
                                    jnp.float32)
        return out
    if shape.kind == "prefill":
        out = {"tokens": _struct((b, s), jnp.int32)}
        if cfg.family == "vlm" and cfg.n_patches:
            out["patch_embeds"] = _struct((b, min(cfg.n_patches, s),
                                           cfg.d_model), jnp.float32)
        if cfg.is_encoder_decoder:
            out["frames"] = _struct((b, encoder_frames(cfg), cfg.d_model),
                                    jnp.float32)
        return out
    # decode: one token against a cache of seq_len
    return {"token": _struct((b, 1), jnp.int32),
            "pos": _struct((), jnp.int32)}


def pick_optimizer(model: Model) -> OptimizerConfig:
    n = model.param_count()
    if n > ADAFACTOR_THRESHOLD:
        return OptimizerConfig(name="adafactor")
    return OptimizerConfig(name="adamw")


# ---------------------------------------------------------------------------
# per-mode lowering specs
# ---------------------------------------------------------------------------

def train_spec(cfg: ArchConfig, shape: ShapeConfig, env: MeshEnv,
               *, remat: bool = True) -> LoweringSpec:
    model = build_model(cfg)
    opt_cfg = pick_optimizer(model)
    opt_init, _ = build_optimizer(opt_cfg)
    step_fn = make_train_step(model, opt_cfg, env, remat=remat)

    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_s = jax.eval_shape(opt_init, params_s)
    step_s = _struct((), jnp.int32)
    batch_s = make_inputs(cfg, shape)

    p_specs = infer_param_specs(params_s, env)
    o_specs = _opt_specs(opt_s, params_s, p_specs)
    b_specs = batch_specs(batch_s, env)

    in_sh = (shardings_of(p_specs, env), shardings_of(o_specs, env),
             env.sharding(P()), shardings_of(b_specs, env))
    metrics_s = {"loss": P(), "grad_norm": P(), "nll": P(), "aux": P()}
    out_sh = (shardings_of(p_specs, env), shardings_of(o_specs, env),
              env.sharding(P()), shardings_of(metrics_s, env))
    return LoweringSpec(
        name=f"{cfg.name}:{shape.name}",
        step=step_fn,
        args=(params_s, opt_s, step_s, batch_s),
        in_shardings=in_sh,
        out_shardings=out_sh,
        static={"optimizer": opt_cfg.name, "mode": "train"},
    )


def _opt_specs(opt_s, params_s, p_specs):
    """Optimizer state shards like its parameter; factored/scalar leaves
    replicate (vr/vc rows are small)."""
    flat_p, _ = jax.tree_util.tree_flatten(params_s)
    flat_ps, _ = jax.tree_util.tree_flatten(
        p_specs, is_leaf=lambda x: isinstance(x, P))
    by_shape = {}
    for leaf, spec in zip(flat_p, flat_ps):
        by_shape.setdefault((tuple(leaf.shape), str(leaf.dtype)), spec)

    def spec(leaf):
        got = by_shape.get((tuple(leaf.shape), str(leaf.dtype)))
        if got is not None:
            return got
        # factored vr/vc or differently-dtyped m/v: match on shape only
        for (shp, _), sp in by_shape.items():
            if shp == tuple(leaf.shape):
                return sp
        return P(*([None] * len(leaf.shape)))

    return jax.tree.map(spec, opt_s)


def prefill_spec(cfg: ArchConfig, shape: ShapeConfig, env: MeshEnv
                 ) -> LoweringSpec:
    model = build_model(cfg)
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch_s = make_inputs(cfg, shape)
    b = shape.global_batch

    def step(params, batch):
        from repro.distributed.sharding import set_env
        with set_env(env):
            return model.prefill(params, batch, env)

    logits_s, cache_s = jax.eval_shape(step, params_s, batch_s)
    p_specs = infer_param_specs(params_s, env)
    b_specs = batch_specs(batch_s, env)
    c_specs = cache_specs(cache_s, env, b)
    lg_spec = _logits_spec(logits_s, env)
    return LoweringSpec(
        name=f"{cfg.name}:{shape.name}",
        step=step,
        args=(params_s, batch_s),
        in_shardings=(shardings_of(p_specs, env), shardings_of(b_specs, env)),
        out_shardings=(env.sharding(lg_spec), shardings_of(c_specs, env)),
        static={"mode": "prefill"},
    )


def decode_spec(cfg: ArchConfig, shape: ShapeConfig, env: MeshEnv
                ) -> LoweringSpec:
    model = build_model(cfg)
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    b = shape.global_batch
    cache_s = jax.eval_shape(
        functools.partial(model.init_cache, b, shape.seq_len))
    inp = make_inputs(cfg, shape)

    def step(params, caches, token, pos):
        from repro.distributed.sharding import set_env
        with set_env(env):
            return model.decode_step(params, caches, token, pos, env)

    logits_s, _ = jax.eval_shape(step, params_s, cache_s, inp["token"],
                                 inp["pos"])
    p_specs = infer_param_specs(params_s, env)
    c_specs = cache_specs(cache_s, env, b)
    tok_spec = batch_specs(inp["token"], env, seq_sharded=False)
    lg_spec = _logits_spec(logits_s, env)
    return LoweringSpec(
        name=f"{cfg.name}:{shape.name}",
        step=step,
        args=(params_s, cache_s, inp["token"], inp["pos"]),
        in_shardings=(shardings_of(p_specs, env),
                      shardings_of(c_specs, env),
                      env.sharding(tok_spec), env.sharding(P())),
        out_shardings=(env.sharding(lg_spec), shardings_of(c_specs, env)),
        static={"mode": "decode"},
    )


def _logits_spec(logits_s, env: MeshEnv) -> P:
    b, _, v = logits_s.shape
    names = [None, None, None]
    if b % env.dp_size == 0:
        names[0] = env.dp_axes
    if env.tp_axis and v % env.tp_size == 0:
        names[2] = env.tp_axis
    return P(*names)


def make_spec(arch: str, shape_name: str, env: MeshEnv) -> LoweringSpec:
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    if not cfg.supports_shape(shape):
        raise ValueError(f"{arch} skips {shape_name} "
                         f"(sub-quadratic attention required)")
    if shape.kind == "train":
        return train_spec(cfg, shape, env)
    if shape.kind == "prefill":
        return prefill_spec(cfg, shape, env)
    return decode_spec(cfg, shape, env)
