"""Training launcher.

On real hardware this runs the full config on the production mesh; on
this CPU host use ``--reduced`` (the per-arch smoke config) to execute
real steps, or ``--dry`` to lower+compile the full cell only (same path
as launch/dryrun.py, single cell).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --shape train_4k --dry
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch, get_shape
from repro.data.lm import batch_stream
from repro.distributed.sharding import single_device_env
from repro.models.model import build_model
from repro.train.optim import OptimizerConfig
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--reduced", action="store_true",
                    help="run the smoke-scale config on this host")
    ap.add_argument("--dry", action="store_true",
                    help="lower+compile the full cell instead of running")
    args = ap.parse_args()

    if args.dry:
        # defer: device count must be forced before jax init
        import os
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape,
               "--mesh", "single,multi", "--out", "experiments/dryrun"]
        raise SystemExit(subprocess.call(cmd))

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    env = single_device_env()
    model = build_model(cfg)
    opt = OptimizerConfig(name=args.optimizer, lr=args.lr,
                          warmup_steps=max(args.steps // 10, 1))
    trainer = Trainer(model, opt, env, ckpt_dir=args.ckpt_dir,
                      remat=not args.reduced)
    state = trainer.restore_or_init()
    print(f"{cfg.name}: {model.param_count():,} params, "
          f"start step {int(state.step)}")
    stream = batch_stream(cfg, args.batch, args.seq,
                          start_cursor=state.data_cursor)
    state = trainer.fit(state, stream, args.steps, log_every=5)
    print(f"finished at step {int(state.step)}")


if __name__ == "__main__":
    main()
