"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any
device initialization.
"""
from __future__ import annotations

import jax

from repro.distributed.sharding import MeshEnv


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_env(*, multi_pod: bool = False, profile: str = "train") -> MeshEnv:
    return MeshEnv(mesh=make_production_mesh(multi_pod=multi_pod),
                   profile=profile)
