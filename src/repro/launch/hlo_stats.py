"""HLO parsing: collective byte accounting for the roofline.

``cost_analysis()`` reports FLOPs and HBM bytes but not collective
traffic, so we parse the post-SPMD optimized HLO text and sum, per
collective op, the *wire bytes per chip* under ring algorithms:

    all-reduce        2 · size · (n-1)/n     (reduce-scatter + all-gather)
    all-gather        out_size · (n-1)/n     (each chip receives the rest)
    reduce-scatter    in_size  · (n-1)/n
    all-to-all        size · (n-1)/n
    collective-permute size                  (one hop)

``n`` is the replica-group size parsed from the op's replica_groups (or
the partition count when groups are flat).  Shapes in the partitioned
module are per-device shapes, which is what the per-chip wire formula
wants.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:   # iota form [num_groups, group_size]
        return int(m.group(2))
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0              # per-chip ring wire bytes
    payload_bytes: float = 0.0           # raw op result bytes
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, kind: str, wire: float, payload: float):
        self.wire_bytes += wire
        self.payload_bytes += payload
        self.counts[kind] = self.counts.get(kind, 0) + 1
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + wire


def collective_stats(hlo_text: str, n_partitions: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) ([a-z\-]+)\(", line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        out_bytes = _shape_bytes(shape_str)
        n = _group_size(line, n_partitions)
        frac = (n - 1) / max(n, 1)
        if kind == "all-reduce":
            wire = 2.0 * out_bytes * frac
        elif kind == "all-gather":
            wire = out_bytes * frac
        elif kind == "reduce-scatter":
            wire = out_bytes * (n - 1)     # input is n x output
        elif kind == "all-to-all":
            wire = out_bytes * frac
        else:  # collective-permute
            wire = float(out_bytes)
        stats.add(kind, wire, float(out_bytes))
    return stats


def hlo_op_histogram(hlo_text: str, top: int = 20) -> List[Tuple[str, int]]:
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT )?%?[\w.\-]+ = .+? ([a-z\-]+)\(",
                     line)
        if m:
            counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
