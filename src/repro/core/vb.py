"""Batch mean-field Variational Bayes for LDA (Hoffman-style), in JAX.

The E-step inner loop is two MXU matmuls per iteration over the
doc-term matrix — this is LDA's compute hot spot and maps onto
``kernels/vb_estep`` (Pallas) on TPU; the pure-jnp path here doubles as
its reference and as the CPU execution path.

Distribution: ``vb_fit_sharded`` shards documents over the data axes
(DP) and the vocabulary over the ``model`` axis (TP).  The M-step's
sufficient-statistic reduction **is the paper's model merge** (Alg. 1)
executed as a psum — merging materialized models and merging per-device
partial models are the same exponential-family addition.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.lda_default import LDAConfig
from repro.distributed.sharding import MeshEnv


def _exp_dirichlet_expectation(x):
    """exp(E[log p]) for Dirichlet rows: exp(ψ(x) − ψ(Σx))."""
    return jnp.exp(
        jax.scipy.special.digamma(x)
        - jax.scipy.special.digamma(x.sum(-1, keepdims=True))
    )


def vb_estep(x, exp_elog_beta, gamma0, alpha: float, n_iters: int,
             *, use_kernel: bool = False):
    """Coordinate-ascent E-step over a doc-block.

    x:              (D, V) counts, f32
    exp_elog_beta:  (K, V) f32
    gamma0:         (D, K) f32 initial document-topic Dirichlet params
    Returns (gamma, sstats) with sstats (K, V) = Σ_d n_dw φ_dwk
    (already multiplied by expElogbeta).
    """
    if use_kernel:
        from repro.kernels.vb_estep import ops as _ops
        return _ops.vb_estep(x, exp_elog_beta, gamma0, alpha, n_iters)

    def body(gamma, _):
        exp_elog_theta = _exp_dirichlet_expectation(gamma)  # (D, K)
        phinorm = exp_elog_theta @ exp_elog_beta + 1e-30    # (D, V)
        gamma = alpha + exp_elog_theta * ((x / phinorm) @ exp_elog_beta.T)
        return gamma, None

    gamma, _ = jax.lax.scan(body, gamma0, None, length=n_iters)
    exp_elog_theta = _exp_dirichlet_expectation(gamma)
    phinorm = exp_elog_theta @ exp_elog_beta + 1e-30
    sstats = (exp_elog_theta.T @ (x / phinorm)) * exp_elog_beta
    return gamma, sstats


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel"))
def vb_fit(x, key, cfg: LDAConfig, *, use_kernel: bool = False):
    """Batch VB on a dense doc-term matrix.  Returns λ (K, V) f32."""
    k = cfg.n_topics
    d, v = x.shape
    lam0 = jax.random.gamma(key, 100.0, (k, v), jnp.float32) * 0.01

    def outer(lam, _):
        gamma0 = jnp.ones((d, k), jnp.float32)
        _, sstats = vb_estep(x, _exp_dirichlet_expectation(lam), gamma0,
                             cfg.alpha, cfg.e_step_iters,
                             use_kernel=use_kernel)
        lam = cfg.eta + sstats
        return lam, None

    lam, _ = jax.lax.scan(outer, lam0, None, length=cfg.max_iters)
    return lam


# ---------------------------------------------------------------------------
# sharded training: docs over DP axes, vocab over `model`
# ---------------------------------------------------------------------------

def vb_fit_sharded(x, key, cfg: LDAConfig, env: MeshEnv,
                   max_iters: Optional[int] = None):
    """Distributed batch VB.

    x is (D, V) with D sharded over (pod?, data) and V sharded over
    `model`.  Each step:
      - phinorm needs the full Σ_k over local V — local matmul
      - the γ update sums over V         — psum over `model`
      - the λ update sums over documents — psum over DP axes
    The DP psum of per-shard sufficient statistics is exactly the
    paper's Alg. 1 merge of per-partition models.
    """
    iters = max_iters if max_iters is not None else cfg.max_iters
    dp = env.dp_axes
    tp = env.tp_axis
    k = cfg.n_topics

    def local(x_l, key):
        d_l, v_l = x_l.shape
        lam_l = jax.random.gamma(key, 100.0, (k, v_l), jnp.float32) * 0.01

        # NOTE: Dirichlet expectation over a V-sharded λ needs the *global*
        # row sum — one small psum per outer iteration.
        def outer(lam_l, _):
            row = lam_l.sum(-1, keepdims=True)
            if tp is not None and env.tp_size > 1:
                row = jax.lax.psum(row, tp)
            ee_beta = jnp.exp(jax.scipy.special.digamma(lam_l)
                              - jax.scipy.special.digamma(row))
            gamma = jnp.ones((d_l, k), jnp.float32)

            def estep(gamma, _):
                ee_theta = _exp_dirichlet_expectation(gamma)
                phinorm = ee_theta @ ee_beta + 1e-30
                dot = (x_l / phinorm) @ ee_beta.T            # (D_l, K) partial over V
                if tp is not None and env.tp_size > 1:
                    dot = jax.lax.psum(dot, tp)
                gamma = cfg.alpha + ee_theta * dot
                return gamma, None

            gamma, _ = jax.lax.scan(estep, gamma, None, length=cfg.e_step_iters)
            ee_theta = _exp_dirichlet_expectation(gamma)
            phinorm = ee_theta @ ee_beta + 1e-30
            sstats = (ee_theta.T @ (x_l / phinorm)) * ee_beta  # (K, V_l)
            if dp and env.dp_size > 1:
                sstats = jax.lax.psum(sstats, dp)   # <- Alg.1 merge as psum
            return cfg.eta + sstats, None

        lam_l, _ = jax.lax.scan(outer, lam_l, None, length=iters)
        return lam_l

    if env.dp_size == 1 and env.tp_size == 1:
        return local(x, key)
    return jax.shard_map(
        local, mesh=env.mesh,
        in_specs=(P(dp, tp), P()),
        out_specs=P(None, tp),
        check_vma=False,
    )(x, key)
