"""Plan IR — the typed execution plan behind every query (Fig. 2).

The searchers (§V.B), the batch optimizer (§V.C) and the session
planner all answer the same question — *how* to materialize β for a
predicate σ — and before this module they answered it with a bare
tuple of ``MaterializedModel``s, leaving the training/merge structure
implicit for the executor to re-derive.  The IR makes the full plan
first-class: a ``Plan`` is an ordered tuple of typed steps

  ``FetchStep``    bring one materialized model's Θ to the execution
                   backend (a device-cache hit costs ~0, a miss pays
                   the host→device transfer)
  ``TrainGapStep`` fit a fresh model on one uncovered range
  ``MergeStep``    combine every fetched + fresh part into β (Alg. 1/2)

so cost providers can price exactly what the backend will do (see
``repro.core.cost``), the session can cache plans by value, and the
executor consumes steps instead of re-deriving gaps from model tuples.

Steps reference store models by id (plans stay light and hashable);
the executor resolves ids against the session's ``ModelStore`` at
execution time.  A plan is immutable and order-normalized: fetches
sorted by range start, then gaps sorted likewise, then the single
merge step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple, Union

from repro.core.plans import Interval, subtract


@dataclass(frozen=True)
class FetchStep:
    """Fetch one materialized model's Θ onto the execution backend."""

    model_id: int
    o: Interval                 # range the model covers
    n_tokens: int               # data volume behind the model


@dataclass(frozen=True)
class TrainGapStep:
    """Train a fresh model on one uncovered range of σ."""

    gap: Interval
    n_tokens: int               # tokens the trainer will see (may be 0)


@dataclass(frozen=True)
class MergeStep:
    """Merge all fetched + freshly trained parts into β."""

    n_parts: int                # planned part count (fetches + nonempty gaps)


PlanStep = Union[FetchStep, TrainGapStep, MergeStep]


@dataclass(frozen=True)
class Plan:
    """One query component's execution plan: fetches, gaps, one merge."""

    sigma: Interval
    steps: Tuple[PlanStep, ...] = ()

    # --- step views -------------------------------------------------------
    @property
    def fetches(self) -> Tuple[FetchStep, ...]:
        return tuple(s for s in self.steps if isinstance(s, FetchStep))

    @property
    def gaps(self) -> Tuple[TrainGapStep, ...]:
        return tuple(s for s in self.steps if isinstance(s, TrainGapStep))

    @property
    def merge(self) -> MergeStep:
        return next(s for s in reversed(self.steps)
                    if isinstance(s, MergeStep))

    # --- the quantities cost providers price ------------------------------
    @property
    def model_ids(self) -> Tuple[int, ...]:
        return tuple(sorted(f.model_id for f in self.fetches))

    @property
    def n_models(self) -> int:
        return sum(1 for s in self.steps if isinstance(s, FetchStep))

    @property
    def uncovered_tokens(self) -> float:
        return float(sum(g.n_tokens for g in self.gaps))

    @property
    def n_parts(self) -> int:
        return self.merge.n_parts

    def key(self) -> Tuple:
        """Value identity (used by the session plan cache)."""
        return (self.sigma.lo, self.sigma.hi, self.model_ids,
                tuple((g.gap.lo, g.gap.hi) for g in self.gaps))

    # --- construction ------------------------------------------------------
    @classmethod
    def from_models(cls, models: Sequence, sigma: Interval, index) -> "Plan":
        """Lower a searcher's model set to the typed step sequence.

        ``index`` prices each uncovered gap in tokens; the merge step's
        part count matches what the executor will actually combine
        (every fetch plus every gap that selects data).
        """
        fetches = tuple(
            FetchStep(m.model_id, m.o, int(m.n_tokens))
            for m in sorted(models, key=lambda m: (m.o.lo, m.o.hi)))
        gaps = tuple(
            TrainGapStep(g, int(index.tokens_in(g.lo, g.hi)))
            for g in subtract(sigma, [f.o for f in fetches]))
        n_parts = len(fetches) + sum(1 for g in gaps if g.n_tokens > 0)
        return cls(sigma, fetches + gaps + (MergeStep(n_parts),))


# ---------------------------------------------------------------------------
# batched-launch scheduling math (§V.C) — shared by the batch optimizer's
# padding pricing and the device backend's launch grouping
# ---------------------------------------------------------------------------

def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def size_buckets(part_counts: Sequence[int]) -> dict:
    """Group launch rows by power-of-two part-count bucket.

    Returns ``{bucket_cap: [indices]}``; within a bucket every plan is
    padded only to the bucket's *actual* maximum, so total padding is
    pointwise ≤ the pad-everything-to-the-widest scheme (bucket max ≤
    global max) while compiled batch shapes stay reusable across calls.
    """
    buckets: dict = {}
    for i, n in enumerate(part_counts):
        buckets.setdefault(_next_pow2(max(n, 1)), []).append(i)
    return buckets


def pad_rows_bucketed(part_counts: Sequence[int]) -> int:
    """Zero-weight rows a size-bucketed batched launch carries."""
    total = 0
    for _, idxs in size_buckets(part_counts).items():
        widest = max(part_counts[i] for i in idxs)
        total += sum(widest - part_counts[i] for i in idxs)
    return total


def pad_rows_widest(part_counts: Sequence[int]) -> int:
    """Zero-weight rows the old pad-to-widest single launch carried."""
    if not part_counts:
        return 0
    widest = max(part_counts)
    return sum(widest - n for n in part_counts)
