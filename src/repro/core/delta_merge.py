"""Delta merging for LM parameters — the paper's Eq. 6 analogue for
non-exponential-family models (DESIGN.md §4).

LDA models merge exactly because their posteriors are exponential-family
(Alg. 1: λ* = η + Σ w_i (λ_i − η)).  LM fine-tunes have no such
guarantee, but the same *shape* of update — accumulate weighted deltas
from a common prior — is the task-vector merge: given a base parameter
tree θ0 and fine-tuned trees θ_i trained on n_i tokens,

    θ* = θ0 + Σ_i w_i (θ_i − θ0),      w_i = n_i / Σ n_j  (or custom)

This lets the MLego store/planner manage LM range-models with the SAME
⟨o, N, Θ⟩ tuple and the SAME plan search: only the merge operator
differs (approximate here, exact for LDA).  The merged-model quality
enters the planner through the fitted monotone loss P(x), exactly as
§V.B.2 prescribes for any domain-specific cost model.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np


def merge_param_deltas(base, tuned: Sequence, weights: Optional[Sequence[float]] = None):
    """θ* = θ0 + Σ w_i (θ_i − θ0) over pytrees.

    ``weights`` defaults to uniform 1/n (the SDA-Bayes form uses data
    counts — pass n_i / Σ n_j).  Order-independent and associative in
    Θ-space, like Alg. 1.
    """
    if not tuned:
        raise ValueError("nothing to merge")
    n = len(tuned)
    w = [1.0 / n] * n if weights is None else list(weights)
    if len(w) != n:
        raise ValueError("weights/models length mismatch")

    def combine(b, *ts):
        b32 = np.asarray(b, np.float32)
        delta = sum(wi * (np.asarray(t, np.float32) - b32)
                    for wi, t in zip(w, ts))
        return (b32 + delta).astype(np.asarray(b).dtype)

    return jax.tree.map(lambda b, *ts: combine(b, *ts), base, *tuned)
