"""Collapsed Gibbs Sampling for LDA + DSGS partition deltas (paper Eq. 7–9).

The token sweep is genuinely sequential (each draw conditions on all
other assignments), so it is expressed as a ``lax.scan`` over tokens —
exactly the per-partition CGS that DSGS assumes.  Distribution comes
from *partitioning*, not from parallelizing the sweep: each worker runs
CGS on its partition against a fixed global ``N_kv`` prior (Eq. 8) and
emits ``ΔN_kv``; merging deltas (Alg. 2) is an all-reduce.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.lda_default import LDAConfig


@functools.partial(jax.jit, static_argnames=("n_topics", "n_docs", "vocab",
                                             "sweeps"))
def _cgs_sweeps(tokens, doc_ids, key, global_nkv, n_topics: int,
                n_docs: int, vocab: int, sweeps: int, alpha: float,
                beta: float):
    """Run ``sweeps`` full CGS sweeps.  Returns (z, local n_kv).

    global_nkv is the fixed prior count matrix (Eq. 8's β + N_kv);
    the sampler's conditional uses (n_kv_local + global_nkv + β).
    """
    t = tokens.shape[0]
    k0, key = jax.random.split(key)
    z0 = jax.random.randint(k0, (t,), 0, n_topics)

    nkd = jnp.zeros((n_docs, n_topics), jnp.float32).at[doc_ids, z0].add(1.0)
    nkv = jnp.zeros((n_topics, vocab), jnp.float32).at[z0, tokens].add(1.0)
    nk = jnp.zeros((n_topics,), jnp.float32).at[z0].add(1.0)
    gk = global_nkv.sum(axis=1)

    def token_step(carry, inp):
        z, nkd, nkv, nk = carry
        idx, u = inp
        d = doc_ids[idx]
        w = tokens[idx]
        old = z[idx]
        # decrement
        nkd = nkd.at[d, old].add(-1.0)
        nkv = nkv.at[old, w].add(-1.0)
        nk = nk.at[old].add(-1.0)
        # conditional  (Eq. 7, with the DSGS global prior)
        p = (nkd[d] + alpha) * (nkv[:, w] + global_nkv[:, w] + beta) / (
            nk + gk + vocab * beta)
        c = jnp.cumsum(p)
        new = jnp.searchsorted(c, u * c[-1])
        new = jnp.clip(new, 0, n_topics - 1)
        z = z.at[idx].set(new)
        nkd = nkd.at[d, new].add(1.0)
        nkv = nkv.at[new, w].add(1.0)
        nk = nk.at[new].add(1.0)
        return (z, nkd, nkv, nk), None

    def sweep(carry, key_s):
        u = jax.random.uniform(key_s, (t,))
        carry, _ = jax.lax.scan(token_step, carry,
                                (jnp.arange(t), u))
        return carry, None

    keys = jax.random.split(key, sweeps)
    (z, nkd, nkv, nk), _ = jax.lax.scan(sweep, (z0, nkd, nkv, nk), keys)
    return z, nkv


def cgs_fit(tokens: np.ndarray, doc_ids: np.ndarray, cfg: LDAConfig, key,
            global_nkv: Optional[np.ndarray] = None,
            sweeps: Optional[int] = None) -> np.ndarray:
    """Train a CGS partition model.  Returns ΔN_kv (K, V) float32.

    With ``global_nkv`` provided this is one DSGS step (Eq. 8):
    ΔN_kv = CGS(α, β + N_kv, W^t).
    """
    if tokens.size == 0:
        return np.zeros((cfg.n_topics, _vocab(cfg, global_nkv)), np.float32)
    vocab = _vocab(cfg, global_nkv)
    gnkv = (jnp.zeros((cfg.n_topics, vocab), jnp.float32)
            if global_nkv is None else jnp.asarray(global_nkv, jnp.float32))
    n_docs = int(doc_ids.max()) + 1
    _, nkv = _cgs_sweeps(
        jnp.asarray(tokens, jnp.int32), jnp.asarray(doc_ids, jnp.int32),
        key, gnkv, cfg.n_topics, n_docs, vocab,
        sweeps if sweeps is not None else cfg.gibbs_sweeps,
        cfg.alpha, cfg.eta,
    )
    return np.asarray(nkv)


def _vocab(cfg: LDAConfig, global_nkv) -> int:
    return cfg.vocab_size if global_nkv is None else global_nkv.shape[1]
