"""Collapsed Gibbs Sampling for LDA + DSGS partition deltas (paper Eq. 7–9).

The exact token sweep is genuinely sequential (each draw conditions on
all other assignments), so ``cgs_fit`` expresses it as a ``lax.scan``
over tokens — exactly the per-partition CGS that DSGS assumes.
Distribution comes from *partitioning*, not from parallelizing the
sweep: each worker runs CGS on its partition against a fixed global
``N_kv`` prior (Eq. 8) and emits ``ΔN_kv``; merging deltas (Alg. 2) is
an all-reduce.

``cgs_fit_blocked`` applies the same fixed-prior independence one
level down: documents are sharded into *doc blocks*, each block keeps
its ``n_kd`` exact and resamples its tokens sequentially against a
per-sweep snapshot of ``n_kv + global N_kv``, and block-local count
deltas are reduced between sweeps (kernels/gibbs_sweep).  The
sequential chain per sweep shrinks from Σ tokens to max tokens per
block, which is what makes device-resident Gibbs gap training viable
in the query hot path; ``cgs_fit`` remains the exact-scan parity
reference (and the HostBackend default).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.lda_default import LDAConfig


@functools.partial(jax.jit, static_argnames=("n_topics", "n_docs", "vocab",
                                             "sweeps"))
def _cgs_sweeps(tokens, doc_ids, key, global_nkv, n_topics: int,
                n_docs: int, vocab: int, sweeps: int, alpha: float,
                beta: float):
    """Run ``sweeps`` full CGS sweeps.  Returns (z, local n_kv).

    global_nkv is the fixed prior count matrix (Eq. 8's β + N_kv);
    the sampler's conditional uses (n_kv_local + global_nkv + β).
    """
    t = tokens.shape[0]
    k0, key = jax.random.split(key)
    z0 = jax.random.randint(k0, (t,), 0, n_topics)

    nkd = jnp.zeros((n_docs, n_topics), jnp.float32).at[doc_ids, z0].add(1.0)
    nkv = jnp.zeros((n_topics, vocab), jnp.float32).at[z0, tokens].add(1.0)
    nk = jnp.zeros((n_topics,), jnp.float32).at[z0].add(1.0)
    gk = global_nkv.sum(axis=1)

    def token_step(carry, inp):
        z, nkd, nkv, nk = carry
        idx, u = inp
        d = doc_ids[idx]
        w = tokens[idx]
        old = z[idx]
        # decrement
        nkd = nkd.at[d, old].add(-1.0)
        nkv = nkv.at[old, w].add(-1.0)
        nk = nk.at[old].add(-1.0)
        # conditional  (Eq. 7, with the DSGS global prior)
        p = (nkd[d] + alpha) * (nkv[:, w] + global_nkv[:, w] + beta) / (
            nk + gk + vocab * beta)
        c = jnp.cumsum(p)
        new = jnp.searchsorted(c, u * c[-1])
        new = jnp.clip(new, 0, n_topics - 1)
        z = z.at[idx].set(new)
        nkd = nkd.at[d, new].add(1.0)
        nkv = nkv.at[new, w].add(1.0)
        nk = nk.at[new].add(1.0)
        return (z, nkd, nkv, nk), None

    def sweep(carry, key_s):
        u = jax.random.uniform(key_s, (t,))
        carry, _ = jax.lax.scan(token_step, carry,
                                (jnp.arange(t), u))
        return carry, None

    keys = jax.random.split(key, sweeps)
    (z, nkd, nkv, nk), _ = jax.lax.scan(sweep, (z0, nkd, nkv, nk), keys)
    return z, nkv


def cgs_fit(tokens: np.ndarray, doc_ids: np.ndarray, cfg: LDAConfig, key,
            global_nkv: Optional[np.ndarray] = None,
            sweeps: Optional[int] = None) -> np.ndarray:
    """Train a CGS partition model.  Returns ΔN_kv (K, V) float32.

    With ``global_nkv`` provided this is one DSGS step (Eq. 8):
    ΔN_kv = CGS(α, β + N_kv, W^t).
    """
    if tokens.size == 0:
        return np.zeros((cfg.n_topics, _vocab(cfg, global_nkv)), np.float32)
    vocab = _vocab(cfg, global_nkv)
    gnkv = (jnp.zeros((cfg.n_topics, vocab), jnp.float32)
            if global_nkv is None else jnp.asarray(global_nkv, jnp.float32))
    n_docs = int(doc_ids.max()) + 1
    _, nkv = _cgs_sweeps(
        jnp.asarray(tokens, jnp.int32), jnp.asarray(doc_ids, jnp.int32),
        key, gnkv, cfg.n_topics, n_docs, vocab,
        sweeps if sweeps is not None else cfg.gibbs_sweeps,
        cfg.alpha, cfg.eta,
    )
    return np.asarray(nkv)


def _vocab(cfg: LDAConfig, global_nkv) -> int:
    return cfg.vocab_size if global_nkv is None else global_nkv.shape[1]


# ---------------------------------------------------------------------------
# doc-blocked sweeps (device route; kernels/gibbs_sweep)
# ---------------------------------------------------------------------------

def blocked_layout(tokens: np.ndarray, doc_ids: np.ndarray, n_docs: int,
                   block_docs: int
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack a CSR-ordered token stream into (n_blocks, T) doc blocks.

    Block b owns the contiguous documents [b·BD, (b+1)·BD); its tokens
    are a contiguous ``doc_ids`` slice (the stream is sorted by doc).
    Returns ``(words, ldoc, mask)`` each (n_blocks, T) with T the
    widest block's token count — pad slots carry mask 0 and word/doc 0.
    """
    n_blocks = max(1, math.ceil(n_docs / block_docs))
    edges = np.searchsorted(
        doc_ids, np.arange(n_blocks + 1) * block_docs, side="left")
    t_max = max(1, int(np.diff(edges).max()))
    words = np.zeros((n_blocks, t_max), np.int32)
    ldoc = np.zeros((n_blocks, t_max), np.int32)
    mask = np.zeros((n_blocks, t_max), np.float32)
    for b in range(n_blocks):
        t0, t1 = int(edges[b]), int(edges[b + 1])
        n = t1 - t0
        words[b, :n] = tokens[t0:t1]
        ldoc[b, :n] = doc_ids[t0:t1] - b * block_docs
        mask[b, :n] = 1.0
    return words, ldoc, mask


@functools.partial(jax.jit, static_argnames=("n_topics", "block_docs",
                                             "vocab", "sweeps", "alpha",
                                             "beta", "use_kernel",
                                             "interpret"))
def _blocked_sweeps(words, ldoc, mask, key, global_nkv, n_topics: int,
                    block_docs: int, vocab: int, sweeps: int, alpha: float,
                    beta: float, use_kernel: bool, interpret: bool):
    """Run ``sweeps`` blocked sweeps.  Returns the final local n_kv."""
    from repro.kernels.gibbs_sweep.ops import gibbs_sweep

    b, t = words.shape
    k0, key = jax.random.split(key)
    z0 = jax.random.randint(k0, (b, t), 0, n_topics)
    nkd0 = jax.vmap(
        lambda l, zz, m: jnp.zeros((block_docs, n_topics),
                                   jnp.float32).at[l, zz].add(m)
    )(ldoc, z0, mask)
    nkv0 = jnp.zeros((n_topics, vocab), jnp.float32).at[
        z0.ravel(), words.ravel()].add(mask.ravel())
    gk = global_nkv.sum(axis=1)

    def sweep(carry, key_s):
        z, nkd, nkv = carry
        u = jax.random.uniform(key_s, (b, t))
        prior = nkv + global_nkv + beta           # frozen for this sweep
        prior_k = nkv.sum(axis=1) + gk + vocab * beta
        z, nkd, nkv = gibbs_sweep(words, ldoc, mask, u, z, nkd, prior,
                                  prior_k, alpha, use_kernel=use_kernel,
                                  interpret=interpret)
        return (z, nkd, nkv), None

    keys = jax.random.split(key, sweeps)
    (_, _, nkv), _ = jax.lax.scan(sweep, (z0, nkd0, nkv0), keys)
    return nkv


def cgs_fit_blocked(tokens: np.ndarray, doc_ids: np.ndarray, cfg: LDAConfig,
                    key, global_nkv: Optional[np.ndarray] = None,
                    sweeps: Optional[int] = None, *, block_docs: int = 64,
                    use_kernel: Optional[bool] = None,
                    interpret: Optional[bool] = None) -> np.ndarray:
    """Doc-blocked CGS partition model.  Returns ΔN_kv (K, V) float32.

    Same contract as :func:`cgs_fit` (a DSGS step when ``global_nkv``
    is given) but sampled with the blocked sweep: per-sweep-stale
    ``n_kv`` across doc blocks, exact ``n_kd`` within each.  Not
    bit-comparable to the exact scan — parity is *statistical*
    (perplexity / top-word overlap; see tests/test_gibbs_blocked.py).

    ``use_kernel=None`` routes to the Pallas kernel on TPU (or when
    ``MLEGO_KERNEL_INTERPRET=1``) and to the vmapped jnp sweep
    elsewhere; both run the identical blocked math.
    """
    from repro.kernels.gibbs_sweep.ops import default_use_kernel
    from repro.kernels.common import default_interpret

    if tokens.size == 0:
        return np.zeros((cfg.n_topics, _vocab(cfg, global_nkv)), np.float32)
    vocab = _vocab(cfg, global_nkv)
    gnkv = (jnp.zeros((cfg.n_topics, vocab), jnp.float32)
            if global_nkv is None else jnp.asarray(global_nkv, jnp.float32))
    if np.any(np.diff(doc_ids) < 0):
        # blocked_layout needs the CSR doc-sorted stream cgs_fit does
        # not; token order within a doc is immaterial to the sampler
        order = np.argsort(doc_ids, kind="stable")
        tokens, doc_ids = tokens[order], doc_ids[order]
    n_docs = int(doc_ids.max()) + 1
    words, ldoc, mask = blocked_layout(tokens, doc_ids, n_docs, block_docs)
    use_kernel = default_use_kernel(use_kernel)
    nkv = _blocked_sweeps(
        jnp.asarray(words), jnp.asarray(ldoc), jnp.asarray(mask), key, gnkv,
        cfg.n_topics, block_docs, vocab,
        sweeps if sweeps is not None else cfg.gibbs_sweeps,
        cfg.alpha, cfg.eta, use_kernel,
        default_interpret(interpret) if use_kernel else False)
    return np.asarray(nkv)
