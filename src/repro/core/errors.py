"""Typed failure taxonomy and the shared retry policy.

Every failure the execution stack can surface is either *transient*
(worth retrying: a flaky device, a stale plan, an injected fault) or
*permanent* (retrying cannot help: a corrupt blob, a malformed spec).
The split is encoded in the class hierarchy so call sites state their
policy with one ``except`` clause instead of enumerating error strings:

``ExecutionError``
    root of the taxonomy (a ``RuntimeError``).
``TransientExecutionError``
    retry may succeed.  ``DeviceLostError`` (a device backend stopped
    responding; the *backend* is suspect, not the query) specializes it.
``PermanentExecutionError``
    retry cannot succeed.  ``CorruptModelError`` (a stored blob failed
    its checksum or could not be deserialized) specializes it, and also
    subclasses ``IOError`` so legacy callers of
    ``ModelStore.load(verify=True)`` that catch ``IOError`` keep
    working.

``RetryPolicy`` is the one retry object the whole stack shares: capped
exponential backoff with *deterministic* jitter (hashed from the site
name and attempt index, so replays under fault injection are exactly
reproducible), per-site attempt budgets, and thread-safe per-site retry
counters that services surface in their reports.  Permanent errors are
never retried regardless of budget.
"""
from __future__ import annotations

import threading
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple, Type, TypeVar

T = TypeVar("T")


class ExecutionError(RuntimeError):
    """Root of the MLego failure taxonomy."""


class TransientExecutionError(ExecutionError):
    """A failure that a retry (possibly on another backend) may clear."""


class PermanentExecutionError(ExecutionError):
    """A failure no retry can clear; fail fast to the caller."""


class DeviceLostError(TransientExecutionError):
    """A device backend raised from the runtime mid-merge/train.

    Transient from the *query's* point of view (replay on the fallback
    chain usually succeeds) but a strong health signal for the backend
    that raised it: callers quarantine the backend and let the circuit
    breaker's half-open probe re-admit it.
    """

    def __init__(self, message: str, *, backend: Optional[str] = None):
        super().__init__(message)
        self.backend = backend


class CorruptModelError(IOError, PermanentExecutionError):
    """A stored blob failed verification (checksum/deserialization).

    Subclasses ``IOError`` so pre-taxonomy callers of
    ``ModelStore.load(verify=True)`` that catch ``IOError`` still do.
    """

    def __init__(self, message: str, *, model_id: Optional[str] = None,
                 blob: Optional[str] = None):
        super().__init__(message)
        self.model_id = model_id
        self.blob = blob


def _jitter_unit(site: str, attempt: int) -> float:
    """Deterministic uniform-ish value in [0, 1) for (site, attempt)."""
    h = zlib.crc32(f"{site}:{attempt}".encode("utf-8")) & 0xFFFFFFFF
    return h / 4294967296.0


@dataclass
class RetryPolicy:
    """Capped exponential backoff with deterministic jitter.

    ``max_attempts`` counts *total* tries (1 = no retry).  Delay before
    retry ``i`` (the i-th re-try, 1-based) is
    ``min(max_delay_s, base_delay_s * 2**(i-1)) * (1 - jitter * u)``
    where ``u`` is hashed from ``(site, i)`` — reproducible across
    processes, no RNG state.  ``site_attempts`` overrides the budget
    for specific sites (longest matching prefix wins, mirroring the
    fault-injection harness's site matching).

    The policy is shared across threads; ``retries_by_site`` counters
    are guarded by an internal lock and snapshotted via
    ``snapshot()``.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.01
    max_delay_s: float = 0.5
    jitter: float = 0.5
    site_attempts: Dict[str, int] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")
        self._lock = threading.Lock()
        self._retries: Dict[str, int] = {}

    # -- budgets ---------------------------------------------------------

    def attempts_for(self, site: str) -> int:
        """Attempt budget for ``site`` (longest matching prefix wins)."""
        best, best_len = self.max_attempts, -1
        for prefix, n in self.site_attempts.items():
            if (site == prefix or site.startswith(prefix + ".")) \
                    and len(prefix) > best_len:
                best, best_len = n, len(prefix)
        return max(1, best)

    def delay_s(self, attempt: int, site: str = "") -> float:
        """Backoff before re-try ``attempt`` (1-based)."""
        base = min(self.max_delay_s,
                   self.base_delay_s * (2.0 ** (attempt - 1)))
        return base * (1.0 - self.jitter * _jitter_unit(site, attempt))

    # -- counters --------------------------------------------------------

    def _note_retry(self, site: str) -> None:
        with self._lock:
            self._retries[site] = self._retries.get(site, 0) + 1

    def snapshot(self) -> Dict[str, int]:
        """Copy of the per-site retry counters (retries, not attempts)."""
        with self._lock:
            return dict(self._retries)

    @property
    def total_retries(self) -> int:
        with self._lock:
            return sum(self._retries.values())

    # -- driver ----------------------------------------------------------

    def run(self, fn: Callable[[], T], *, site: str,
            sleep: Optional[Callable[[float], None]] = None,
            on_retry: Optional[Callable[[BaseException, int], None]] = None,
            no_retry: Tuple[Type[BaseException], ...] = ()) -> T:
        """Call ``fn`` under this policy.

        Retries anything except ``PermanentExecutionError`` (and the
        extra ``no_retry`` types, checked first — use it when the call
        site has its own recovery for e.g. ``DeviceLostError``).
        ``on_retry(exc, attempt)`` fires before each re-try, after the
        backoff sleep.  ``sleep`` defaults to ``time.sleep``; tests
        pass a stub.

        Each re-try also lands a zero-duration ``retry`` event on the
        ambient trace span (``repro.obs.trace``), so a query's span
        tree shows every attempt with its site and the error that
        forced it; free when no span is active.
        """
        import time as _time

        from repro.obs import trace as _obs

        do_sleep = sleep if sleep is not None else _time.sleep
        budget = self.attempts_for(site)
        attempt = 0
        while True:
            attempt += 1
            try:
                return fn()
            except no_retry:
                raise
            except PermanentExecutionError:
                raise
            except Exception as exc:
                if attempt >= budget:
                    raise
                delay = self.delay_s(attempt, site)
                if delay > 0.0:
                    do_sleep(delay)
                self._note_retry(site)
                _obs.instant("retry", site=site, attempt=attempt,
                             error=type(exc).__name__)
                if on_retry is not None:
                    on_retry(exc, attempt)


__all__ = [
    "CorruptModelError",
    "DeviceLostError",
    "ExecutionError",
    "PermanentExecutionError",
    "RetryPolicy",
    "TransientExecutionError",
]
