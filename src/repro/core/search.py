"""Plan searching — NAI, GRA, PSOA, PSOA++ (paper §V.B, Alg. 3).

All four searchers solve Definition 2 (score-based plan searching):

    p* = argmin_{p in P} sc(p)   s.t. sc(p) > 0 or p reuses models,
    sc  = alpha * l_p + (1 - alpha) * c_t                       (Eq. 2)

(Def. 2's sc > 0 constraint exists to bar the *empty* plan — scratch
training has perfect quality, so it scores 0 whenever alpha = 1 and
would trivially win.  A nonempty zero-score plan is the opposite
extreme and genuinely optimal: a single stored model exactly covering
sigma has no merges, no training, and no fetch cost — the direct-hit
plan every alpha must prefer over retraining.)

  * ``nai_search``   — generate-and-rank: enumerate every candidate plan
    (all antichains of usable models — exponential), score all, rank.
  * ``gra_search``   — the [20] baseline: DAG over range endpoints,
    shortest path = max-coverage plan.  Only valid when the score
    reduces to training cost (alpha = 0, merge cost negligible).
  * ``psoa_search``  — hierarchical threshold (top-k) search over three
    ordered lists (l_p, c_t(merge), c_t(train)) seeded by RL plans,
    kept sorted with the Thm. 2 "push down" rule.
  * PSOA++           — the §V.B.5 improvement: when alpha = 0 the l_p
    list is dropped, and when the plan width is under the Thm. 3/4
    critical point x* the merge list is dropped too; the problem
    degenerates to maximize-coverage and is answered from the first
    c_t(train) layer directly (this is exactly where GRA applies).

Every searcher returns a ``SearchResult`` carrying the chosen plan —
both the legacy model tuple and its lowered Plan IR (``ir``) — its
exact score and work counters (#plans scored, #layers generated) so the
Fig. 10–12 benchmarks can report search effort as well as wall time.

Candidate scoring goes through the pluggable ``CostProvider``
(``cost.score_models``): the analytic ``CostModel`` reproduces the
paper's Eq. 2 exactly, while a ``CalibratedCostModel`` additionally
prices device-cache hits and host→device transfers per model, so the
same searchers become backend-aware without changing their control
flow.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cost import CostProvider, plan_stats
from repro.core.plan_ir import Plan
from repro.core.plans import Interval, all_plans, children, plan_key, rl_plans, subtract, usable


@dataclass
class SearchResult:
    plan: Tuple                  # legacy model-tuple view of the plan
    score: float
    alpha: float
    n_scored: int = 0            # exact score evaluations
    n_generated: int = 0         # candidate plans materialized
    n_layers: int = 0            # layers expanded (PSOA)
    elapsed_s: float = 0.0
    method: str = ""
    ir: Optional[Plan] = None    # lowered Plan IR (what executors consume)

    @property
    def model_ids(self) -> Tuple[int, ...]:
        return tuple(sorted(m.model_id for m in self.plan))


def lower(plan: Tuple, query: Interval, index) -> Plan:
    """Model tuple -> Plan IR (searchers lower their chosen plan once)."""
    return Plan.from_models(plan, query, index)


def _scratch_tokens(query: Interval, index) -> float:
    return float(index.tokens_in(query.lo, query.hi))


def _exact_score(plan, query, index, cost: CostProvider, alpha: float,
                 scratch: float) -> float:
    return cost.score_models(plan, query, index, alpha, scratch)


# ---------------------------------------------------------------------------
# NAI — generate-and-rank (paper §V.B.1)
# ---------------------------------------------------------------------------

def nai_search(models: Sequence, query: Interval, index,
               cost: CostProvider, alpha: float) -> SearchResult:
    t0 = time.perf_counter()
    scratch = _scratch_tokens(query, index)
    plans = all_plans(models, query)
    best, best_sc = (), float("inf")
    n_scored = 0
    for p in plans:
        sc = _exact_score(p, query, index, cost, alpha, scratch)
        n_scored += 1
        if (sc > 0.0 or p) and sc < best_sc:
            best, best_sc = p, sc
    return SearchResult(best, best_sc, alpha, n_scored=n_scored,
                        n_generated=len(plans),
                        elapsed_s=time.perf_counter() - t0, method="NAI",
                        ir=lower(best, query, index))


# ---------------------------------------------------------------------------
# GRA — DAG shortest path (the [20] baseline; max-coverage regime only)
# ---------------------------------------------------------------------------

def gra_search(models: Sequence, query: Interval, index,
               cost: CostProvider) -> SearchResult:
    """Left-to-right DP over range endpoints minimizing trained tokens.

    Node set: query endpoints + usable-model endpoints, sorted.  Edges:
      gap   (node_i -> node_{i+1})  weight c_train(tokens between)
      model (m.lo  -> m.hi)         weight t_m (one merge)
    The shortest path is the coverage-maximal plan; valid when the
    score is pure time cost (alpha = 0 regime of Fig. 10).
    """
    t0 = time.perf_counter()
    cand = usable(models, query)
    nodes = sorted({query.lo, query.hi}
                   | {m.o.lo for m in cand} | {m.o.hi for m in cand})
    pos = {x: i for i, x in enumerate(nodes)}
    n = len(nodes)
    dist = [float("inf")] * n
    back: List[Optional[Tuple[int, Optional[object]]]] = [None] * n
    dist[0] = 0.0
    by_lo: Dict[int, List] = {}
    for m in cand:
        by_lo.setdefault(pos[m.o.lo], []).append(m)
    n_scored = 0
    for i in range(n):
        if dist[i] == float("inf"):
            continue
        if i + 1 < n:
            w = cost.c_train(index.tokens_in(nodes[i], nodes[i + 1]))
            n_scored += 1
            if dist[i] + w < dist[i + 1]:
                dist[i + 1] = dist[i] + w
                back[i + 1] = (i, None)
        for m in by_lo.get(i, ()):
            j = pos[m.o.hi]
            w = cost.t_merge
            if dist[i] + w < dist[j]:
                dist[j] = dist[i] + w
                back[j] = (i, m)
    plan: List = []
    i = n - 1
    while i != 0:
        prev, m = back[i]
        if m is not None:
            plan.append(m)
        i = prev
    plan_t = tuple(reversed(plan))
    scratch = _scratch_tokens(query, index)
    sc = _exact_score(plan_t, query, index, cost, 0.0, scratch)
    return SearchResult(plan_t, sc, 0.0, n_scored=n_scored,
                        n_generated=len(cand) + n,
                        elapsed_s=time.perf_counter() - t0, method="GRA",
                        ir=lower(plan_t, query, index))


# ---------------------------------------------------------------------------
# PSOA — hierarchical threshold search (Alg. 3)
# ---------------------------------------------------------------------------

class _BfsLayers:
    """Layered plan generation for the l_p / c_t(merge) lists.

    L_i = all antichains with i models.  Each antichain is produced
    exactly once by extending its sorted prefix at the right end.
    """

    def __init__(self, cand: Sequence):
        self.cand = sorted(cand, key=lambda m: (m.o.lo, m.o.hi))
        self.layer: List[Tuple] = [(m,) for m in self.cand]
        self.i = 0
        self.n_generated = len(self.layer)

    def next_layer(self) -> List[Tuple]:
        if self.i == 0:
            self.i = 1
            return self.layer
        new: List[Tuple] = []
        for p in self.layer:
            end = p[-1].o.hi
            for m in self.cand:
                if m.o.lo >= end:
                    new.append(p + (m,))
        self.layer = new
        self.i += 1
        self.n_generated += len(new)
        return new


class _TrainLayers:
    """Layered c_t(train) list: RL plans first, children next, with the
    Thm. 2 push-down keeping cross-layer train-cost order."""

    def __init__(self, roots: Sequence[Tuple], query: Interval, index):
        self.query = query
        self.index = index
        self.layer: List[Tuple] = list(roots)
        self.emitted: set = set()
        self.n_generated = len(roots)

    def _covered(self, p: Tuple) -> float:
        return float(sum(self.index.tokens_in(m.o.lo, m.o.hi) for m in p))

    def _min_model(self, p: Tuple) -> float:
        return min(float(self.index.tokens_in(m.o.lo, m.o.hi)) for m in p)

    def next_layer(self) -> List[Tuple]:
        if not self.layer:
            return []
        cov = {plan_key(p): self._covered(p) for p in self.layer}
        # Thm. 2: best achievable child coverage this layer
        parents = [p for p in self.layer if len(p) > 0]
        best_child = max((cov[plan_key(p)] - self._min_model(p)
                          for p in parents), default=float("-inf"))
        stay = [p for p in self.layer if cov[plan_key(p)] > best_child]
        pushed = [p for p in self.layer if cov[plan_key(p)] <= best_child]
        if not stay:   # strict progress: keep the max-coverage plan
            top = max(self.layer, key=lambda p: cov[plan_key(p)])
            stay = [top]
            pushed = [p for p in self.layer if p is not top]
        out: List[Tuple] = []
        for p in stay:
            k = plan_key(p)
            if k not in self.emitted:
                self.emitted.add(k)
                out.append(p)
        nxt: Dict[Tuple, Tuple] = {}
        for p in stay:
            for c in children(p):
                k = plan_key(c)
                if k not in self.emitted:
                    nxt[k] = c
        for p in pushed:
            nxt.setdefault(plan_key(p), p)
        self.layer = list(nxt.values())
        self.n_generated += len(self.layer)
        return out


def psoa_search(models: Sequence, query: Interval, index,
                cost: CostProvider, alpha: float, *, use_plus: bool = True,
                max_layers: int = 10_000) -> SearchResult:
    """Alg. 3 — hierarchical plan search with the threshold algorithm.

    ``use_plus`` enables the §V.B.5 list-merging improvement (PSOA++):
    with alpha = 0 the l_p list is dropped, and below the Thm. 3/4
    critical point the merge list collapses into the train list.
    """
    t0 = time.perf_counter()
    cand = [m for m in usable(models, query)
            if index.tokens_in(m.o.lo, m.o.hi) > 0]
    scratch = _scratch_tokens(query, index)
    roots = rl_plans(cand, query)
    n_layers = 0

    # ---- alpha = 1 (Alg. 3 line 5): maximal reuse among RL plans -------
    if alpha >= 1.0:
        best = max(roots, key=len) if roots else ()
        sc = _exact_score(best, query, index, cost, alpha, scratch)
        return SearchResult(best, sc, alpha, n_scored=len(roots),
                            n_generated=len(roots),
                            elapsed_s=time.perf_counter() - t0,
                            method="PSOA", ir=lower(best, query, index))

    # ---- PSOA++: alpha = 0 below the critical point x* ------------------
    if use_plus and alpha == 0.0 and cand:
        width = max((len(p) for p in roots), default=0)
        min_tok = min(float(index.tokens_in(m.o.lo, m.o.hi)) for m in cand)
        if width <= cost.critical_x(min_tok):
            # merge cost negligible -> maximize coverage (GRA regime):
            # answer directly from the first c_t(train) layer.
            def unc(p):
                return plan_stats(p, query, index)[1]
            best = min(roots, key=unc) if roots else ()
            sc = _exact_score(best, query, index, cost, alpha, scratch)
            return SearchResult(best, sc, alpha, n_scored=len(roots),
                                n_generated=len(roots), n_layers=1,
                                elapsed_s=time.perf_counter() - t0,
                                method="PSOA++",
                                ir=lower(best, query, index))

    # ---- general threshold search over the three lists ------------------
    bfs = _BfsLayers(cand)          # drives l_p and c_t(merge) bounds
    tl = _TrainLayers(roots, query, index)
    denom = max(cost.c_train(scratch), 1e-30)

    scored: Dict[Tuple, float] = {}
    best_plan: Tuple = ()
    best_sc = float("inf")
    # the empty plan (train everything) is always a candidate — unless
    # it scores 0 (the alpha = 1 degeneracy Def. 2's constraint bars)
    sc0 = _exact_score((), query, index, cost, alpha, scratch)
    if sc0 > 0.0:
        best_plan, best_sc = (), sc0
    scored[()] = sc0

    def see(p: Tuple):
        nonlocal best_plan, best_sc
        k = plan_key(p)
        if k in scored:
            return
        sc = _exact_score(p, query, index, cost, alpha, scratch)
        scored[k] = sc
        if (sc > 0.0 or p) and sc < best_sc:
            best_plan, best_sc = p, sc

    bfs_done = train_done = False
    r = 0
    while r < max_layers and not (bfs_done and train_done):
        r += 1
        n_layers += 1
        # advance the joint l_p / merge list (layer r = r-model plans)
        if not bfs_done:
            layer_a = bfs.next_layer()
            if not layer_a:
                bfs_done = True
            for p in layer_a:
                see(p)
        # advance the train list
        if not train_done:
            layer_c = tl.next_layer()
            if not layer_c and not tl.layer:
                train_done = True
            for p in layer_c:
                see(p)
        # ---- threshold (lower bound over every unseen plan) ------------
        # unseen plans have >= r+1 models (list A exhausted layer r)
        if bfs_done:
            lp_lb = float("inf")
            merge_lb = float("inf")
        else:
            lp_lb = cost.ploss.loss(r)           # >= r+1 models -> >= r merges
            merge_lb = cost.c_merge(r) / denom
        if train_done:
            train_lb = float("inf")
        elif tl.layer:
            train_lb = min(cost.c_train(plan_stats(p, query, index)[1])
                           for p in tl.layer) / denom
        else:
            train_lb = float("inf")
        # (guard 0 * inf)
        th = 0.0
        th += alpha * lp_lb if alpha > 0.0 else 0.0
        th += (1.0 - alpha) * (merge_lb + train_lb) if alpha < 1.0 else 0.0
        if best_sc <= th:
            break

    return SearchResult(best_plan, best_sc, alpha, n_scored=len(scored),
                        n_generated=bfs.n_generated + tl.n_generated,
                        n_layers=n_layers,
                        elapsed_s=time.perf_counter() - t0,
                        method="PSOA" if alpha != 0.0 else "PSOA(a0)",
                        ir=lower(best_plan, query, index))


SEARCHERS = {
    "nai": lambda m, q, i, c, a: nai_search(m, q, i, c, a),
    "gra": lambda m, q, i, c, a: gra_search(m, q, i, c),
    "psoa": lambda m, q, i, c, a: psoa_search(m, q, i, c, a, use_plus=False),
    "psoa++": lambda m, q, i, c, a: psoa_search(m, q, i, c, a, use_plus=True),
}
