"""Model merging — the paper's Alg. 1 (MVB) and Alg. 2 (MGS).

Both merges are exponential-family natural-parameter additions, i.e.
**reductions**: order-independent, associative, O(x·K·V).  On the mesh
they run as all-reduces (see ``vb.vb_fit_sharded`` and
``distributed/merge_collective.py``); here is the host/NumPy form used
by the planner and the model store, plus the jnp form the Pallas
``merge_topics`` kernel accelerates.

MVB (weighted SDA-Bayes, Eq. 6):   λ* = η + Σ_i w_i (λ_i − η)
MGS (weighted DSGS,      Eq. 9):   N*_kv = Σ_i decay^{s_i} ΔN_kv^i

``s_i`` is the *staleness rank* of model i (0 = freshest).  With all
models equally fresh (the plan-merge case) every s_i = 0 and the merge
is exactly order-independent; the decay path is the streaming /
straggler-mitigation policy (bounded staleness).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.configs.lda_default import LDAConfig
from repro.core.lda import MaterializedModel, topics_from_gs, topics_from_vb


def merge_vb(models: Sequence[MaterializedModel], cfg: LDAConfig,
             weights: Optional[Sequence[float]] = None) -> np.ndarray:
    """Alg. 1 — returns merged λ (K, V)."""
    if not models:
        raise ValueError("nothing to merge")
    w = np.ones(len(models)) if weights is None else np.asarray(weights, float)
    lam = np.full_like(models[0].lam, cfg.eta)
    for wi, m in zip(w, models):
        lam = lam + wi * (m.lam - cfg.eta)          # Δλ_i = λ_i − λ_0
    return lam


def merge_gs(models: Sequence[MaterializedModel], cfg: LDAConfig,
             staleness: Optional[Sequence[int]] = None,
             decay: Optional[float] = None) -> np.ndarray:
    """Alg. 2 — returns merged N_kv (K, V).

    ``staleness[i]`` = s_i ≥ 0; ``decay`` defaults to cfg.decay but is
    only applied where s_i > 0 (plan merges pass no staleness and are
    exactly order-independent).
    """
    if not models:
        raise ValueError("nothing to merge")
    d = cfg.decay if decay is None else decay
    s = [0] * len(models) if staleness is None else list(staleness)
    nkv = np.zeros_like(models[0].delta_nkv)
    for si, m in zip(s, models):
        nkv = nkv + (d ** si) * m.delta_nkv
    return nkv


def merge_models(models: Sequence[MaterializedModel], cfg: LDAConfig,
                 **kw) -> np.ndarray:
    """Merge a homogeneous model list; returns the topic matrix β (K, V)."""
    kinds = {m.kind for m in models}
    if len(kinds) != 1:
        raise ValueError(f"cannot merge mixed kinds {kinds}")
    if kinds == {"vb"}:
        return topics_from_vb(merge_vb(models, cfg, **kw))
    return topics_from_gs(merge_gs(models, cfg, **kw), cfg.eta)


def merged_theta(models: Sequence[MaterializedModel], cfg: LDAConfig):
    """Merged Θ in materializable form (for re-materializing query results)."""
    kind = models[0].kind
    if kind == "vb":
        return {"lam": merge_vb(models, cfg)}, "vb"
    return {"delta_nkv": merge_gs(models, cfg)}, "gs"


# ---------------------------------------------------------------------------
# device form — how each built-in family maps onto the fused
# ``kernels/merge_topics`` reduction  out = bias + Σ w_i (stat_i − base)
# ---------------------------------------------------------------------------

DEVICE_MERGE_FAMILIES = ("vb", "gs")

_DEVICE_STAT_KEYS = {"vb": "lam", "gs": "delta_nkv"}


def device_stat_key(kind: str) -> str:
    """Θ entry that is the merge statistic for a device family
    (cfg-free subset of :func:`device_merge_params`)."""
    try:
        return _DEVICE_STAT_KEYS[kind]
    except KeyError:
        raise KeyError(f"kind {kind!r} has no device merge form "
                       f"(one of {DEVICE_MERGE_FAMILIES})") from None


def device_merge_params(kind: str, cfg: LDAConfig):
    """(stat_key, bias, base, finisher) for a kernel-mergeable kind.

    ``stat_key`` names the Θ entry that is the merge statistic;
    ``finisher`` maps the merged statistic to the topic matrix β —
    the same function the host merge families apply, so host/device
    parity is exact up to the reduction's float ordering.
    """
    if kind == "vb":
        return "lam", cfg.eta, cfg.eta, topics_from_vb
    if kind == "gs":
        return "delta_nkv", 0.0, 0.0, (
            lambda nkv: topics_from_gs(nkv, cfg.eta))
    raise KeyError(f"kind {kind!r} has no device merge form "
                   f"(one of {DEVICE_MERGE_FAMILIES})")


def device_norm_offset(kind: str, cfg: LDAConfig) -> float:
    """Finisher numerator offset for *device-side* normalization.

    Both finishers are ``(merged + offset) / rowsum(merged + offset)``:
    vb normalizes λ directly (offset 0) and gs smooths first —
    ``topics_from_gs`` divides ``nkv + η`` by ``rowsum(nkv) + V·η``,
    which is exactly the row sum of the offset numerator.  That shared
    shape is what lets the vocab-sharded merge normalize on device with
    a single (K,) psum instead of gathering the merged statistic.
    """
    if kind == "vb":
        return 0.0
    if kind == "gs":
        return cfg.eta
    raise KeyError(f"kind {kind!r} has no device merge form "
                   f"(one of {DEVICE_MERGE_FAMILIES})")
