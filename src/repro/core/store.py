"""Materialized-model store.

Holds ⟨o, N, Θ⟩ tuples, answers "which models are usable for range Q",
persists atomically (npz blobs + json manifest with content hashes) and
participates in the checkpoint manager so a restarted cluster resumes
with its full reuse capital.

Persistence is crash-safe: every blob and the manifest are written
tmp + fsync + rename (a crash mid-save leaves the previous consistent
snapshot), and each blob's sha256 rides in the manifest.  ``load``
verifies checksums; with ``on_corrupt="quarantine"`` a bad or
truncated blob is *skipped* instead of failing the whole load — the
store records it in ``quarantined`` and the planner simply never sees
the model, so Alg. 4 plans around the hole (gap-train or alternate
cover).  ``on_corrupt="raise"`` keeps the legacy fail-fast contract
(the error is a ``CorruptModelError``, an ``IOError`` subclass).

The store is also the lifecycle spine of the streaming-ingestion path
(``repro.ingest``): slice models *append* through ``add``, compaction
*swaps* a run of fine slices for one coarse segment through
``replace`` (atomic under the store lock; listeners see the coarse
"add" before the fine "remove"s, so there is no event ordering in
which the range appears uncovered), and cold capital *evicts* through
``remove``.  All three flow through the one ``subscribe`` channel, so
plan caches and device LRUs invalidate identically for manual saves
and background ingestion.  ``get`` stamps a monotone access clock per
model — ``last_access`` is what the compactor's eviction pass ranks
cold capital by.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import tempfile
import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.errors import CorruptModelError
from repro.core.lda import MaterializedModel
from repro.core.plans import Interval
from repro.testing.faults import maybe_fail


_BLOB_RE = re.compile(r"model_(-?\d+)\.npz")


@dataclass(frozen=True)
class QuarantinedBlob:
    """One model the store refused to serve (bad checksum, truncated
    blob, or a runtime ``quarantine`` call).  ``o``/``kind`` are kept
    from the manifest so recovery (``distributed.elastic``) knows what
    interval to retrain without re-reading the corrupt file."""

    model_id: int
    file: str
    reason: str
    o: Optional[Interval] = None
    kind: Optional[str] = None


StoreListener = Callable[[str, int], None]


class ModelStore:
    def __init__(self):
        self._models: Dict[int, MaterializedModel] = {}
        self._next_id = 0
        self._lock = threading.Lock()
        self._listeners: List[StoreListener] = []
        # monotone access stamps (model_id -> tick); approximate under
        # concurrency — races only reorder near-simultaneous reads,
        # which is irrelevant for a cold-vs-hot eviction ranking
        self._access: Dict[int, int] = {}
        self._access_clock = 0
        # blobs load() skipped or quarantine() pulled at runtime; the
        # planner never sees these, so plans route around them
        self.quarantined: List[QuarantinedBlob] = []

    # --- change notification -------------------------------------------
    # Execution backends cache device-resident copies of Θ keyed by
    # model id; they subscribe here so mutations invalidate those
    # copies.  Listeners fire outside the lock with (event, model_id),
    # event in {"add", "remove"}.
    def subscribe(self, fn: StoreListener) -> None:
        """Idempotent: a listener is registered at most once, however
        many sessions over this store bind the same shared cache."""
        with self._lock:
            if fn not in self._listeners:
                self._listeners.append(fn)

    def unsubscribe(self, fn: StoreListener) -> None:
        with self._lock:
            if fn in self._listeners:
                self._listeners.remove(fn)

    def _notify(self, event: str, model_id: int) -> None:
        with self._lock:
            listeners = list(self._listeners)
        for fn in listeners:
            fn(event, model_id)

    # --- CRUD ---------------------------------------------------------
    def add(self, o: Interval, n_docs: int, n_tokens: int, kind: str,
            theta: Dict[str, np.ndarray]) -> MaterializedModel:
        with self._lock:
            mid = self._next_id
            self._next_id += 1
            m = MaterializedModel(mid, o, n_docs, n_tokens, kind, theta)
            self._models[mid] = m
        self._notify("add", mid)
        return m

    def remove(self, model_id: int) -> None:
        with self._lock:
            existed = self._models.pop(model_id, None) is not None
            self._access.pop(model_id, None)
        if existed:
            self._notify("remove", model_id)

    def replace(self, old_ids: Sequence[int], o: Interval, n_docs: int,
                n_tokens: int, kind: str,
                theta: Dict[str, np.ndarray]) -> MaterializedModel:
        """Compaction primitive: atomically swap ``old_ids`` for one
        coarser model covering their union.

        The insert and the removals commit under one lock acquisition,
        so no concurrent reader ever sees a store missing both the fine
        slices and the coarse segment.  Listeners are notified outside
        the lock, coarse "add" first, then one "remove" per fine slice
        — the same channel (and the same net effect on plan caches and
        device LRUs) as a manual remove-and-retrain.
        """
        old_ids = list(old_ids)
        with self._lock:
            missing = [i for i in old_ids if i not in self._models]
            if missing:
                raise KeyError(f"replace: unknown model ids {missing}")
            mid = self._next_id
            self._next_id += 1
            m = MaterializedModel(mid, o, n_docs, n_tokens, kind, theta)
            self._models[mid] = m
            for i in old_ids:
                self._models.pop(i)
                self._access.pop(i, None)
        self._notify("add", mid)
        for i in old_ids:
            self._notify("remove", i)
        return m

    def get(self, model_id: int) -> MaterializedModel:
        maybe_fail("store.get")
        m = self._models[model_id]
        self._access_clock += 1
        self._access[model_id] = self._access_clock
        return m

    # --- quarantine ------------------------------------------------------
    def quarantine(self, model_id: int, reason: str = "runtime") -> None:
        """Pull a live model from service, remembering what was lost.

        Same invalidation path as ``remove`` (plan caches and device
        LRUs drop it), but the interval/kind land in ``quarantined``
        so ``distributed.elastic.recover_quarantined`` can retrain the
        hole later.
        """
        with self._lock:
            m = self._models.pop(model_id, None)
            self._access.pop(model_id, None)
            if m is not None:
                self.quarantined.append(QuarantinedBlob(
                    model_id=model_id, file=f"model_{model_id}.npz",
                    reason=reason, o=m.o, kind=m.kind))
        if m is not None:
            self._notify("remove", model_id)

    def clear_quarantined(self) -> List[QuarantinedBlob]:
        """Drain the quarantine ledger (after recovery retrained it)."""
        with self._lock:
            drained, self.quarantined = self.quarantined, []
        return drained

    def last_access(self, model_id: int) -> int:
        """Access-clock stamp of the last ``get`` (0 = never fetched) —
        the compactor's cold-capital eviction ranks by this."""
        return self._access.get(model_id, 0)

    def __len__(self) -> int:
        return len(self._models)

    def models(self, kind: Optional[str] = None) -> List[MaterializedModel]:
        # snapshot under the lock: the store is shared by concurrent
        # sessions (the serving layer), and a mid-iteration add/remove
        # must not corrupt a reader's view
        with self._lock:
            ms = list(self._models.values())
        return ms if kind is None else [m for m in ms if m.kind == kind]

    def usable(self, query: Interval, kind: Optional[str] = None
               ) -> List[MaterializedModel]:
        return [m for m in self.models(kind) if query.contains(m.o)]

    def nbytes(self) -> int:
        return sum(m.nbytes() for m in self.models())

    # --- persistence ----------------------------------------------------
    def save(self, path: str) -> None:
        maybe_fail("store.save")
        os.makedirs(path, exist_ok=True)
        manifest = {"next_id": self._next_id, "models": []}
        for m in self.models():
            blob = os.path.join(path, f"model_{m.model_id}.npz")
            with tempfile.NamedTemporaryFile(dir=path, delete=False) as f:
                np.savez(f, **m.theta)
                f.flush()
                os.fsync(f.fileno())
                tmp = f.name
            os.replace(tmp, blob)
            manifest["models"].append({
                "model_id": m.model_id,
                "lo": m.o.lo, "hi": m.o.hi,
                "n_docs": m.n_docs, "n_tokens": m.n_tokens,
                "kind": m.kind,
                "sha": _sha(blob),
                "file": os.path.basename(blob),
            })
        mf = os.path.join(path, "manifest.json")
        with tempfile.NamedTemporaryFile("w", dir=path, delete=False) as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
            tmp = f.name
        os.replace(tmp, mf)
        _fsync_dir(path)
        # prune blobs of models removed since the last save.  Only ids
        # this store has allocated (< next_id) are candidates — a fresh
        # or stale store saving into a shared directory must not delete
        # blobs it never knew about.
        live = {e["file"] for e in manifest["models"]}
        for name in os.listdir(path):
            m = _BLOB_RE.fullmatch(name)
            if m is None or name in live:
                continue
            if 0 <= int(m.group(1)) < self._next_id:
                os.remove(os.path.join(path, name))

    @classmethod
    def load(cls, path: str, verify: bool = True,
             on_corrupt: str = "raise") -> "ModelStore":
        """Restore a saved store.

        ``on_corrupt="raise"`` (legacy): the first bad blob aborts the
        load with ``CorruptModelError`` (an ``IOError``).
        ``on_corrupt="quarantine"``: bad blobs are skipped, recorded
        in ``store.quarantined`` with their manifest interval/kind,
        and every healthy model still loads — queries covering the
        hole plan around it (gap-train or alternate cover).
        """
        maybe_fail("store.load")
        if on_corrupt not in ("raise", "quarantine"):
            raise ValueError(
                f"on_corrupt must be 'raise' or 'quarantine', "
                f"got {on_corrupt!r}")
        store = cls()
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        store._next_id = manifest["next_id"]
        for e in manifest["models"]:
            blob = os.path.join(path, e["file"])
            reason = None
            theta = None
            try:
                if verify and _sha(blob) != e["sha"]:
                    reason = "checksum mismatch"
                else:
                    with np.load(blob) as z:
                        theta = {k: z[k] for k in z.files}
            except CorruptModelError:
                raise
            except Exception as exc:  # truncated zip, missing file, ...
                reason = f"unreadable ({type(exc).__name__}: {exc})"
            if reason is not None:
                if on_corrupt == "raise":
                    raise CorruptModelError(
                        f"{reason} for {blob}",
                        model_id=e["model_id"], blob=blob)
                store.quarantined.append(QuarantinedBlob(
                    model_id=e["model_id"], file=e["file"], reason=reason,
                    o=Interval(e["lo"], e["hi"]), kind=e["kind"]))
                continue
            m = MaterializedModel(
                e["model_id"], Interval(e["lo"], e["hi"]),
                e["n_docs"], e["n_tokens"], e["kind"], theta)
            store._models[m.model_id] = m
        return store


def _fsync_dir(path: str) -> None:
    """Make the renames themselves durable (POSIX: fsync the directory)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass  # some filesystems refuse directory fsync; best-effort
    finally:
        os.close(fd)


def _sha(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()
