"""LDA state and the materialized-model tuple ⟨o, N, Θ⟩ (paper §III.B).

A materialized model is exactly the paper's tuple:
  o : the dimension-attribute range the model was trained on (Interval)
  N : data volume — we track both #docs and #tokens (the cost model is
      token-based, the merge weights are doc-based)
  Θ : mergeable parameters, depending on the inference algorithm:
        kind == "vb": {"lam": λ (K, V) Dirichlet variational params}
        kind == "gs": {"delta_nkv": ΔN_kv (K, V) topic-word count delta}
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.lda_default import LDAConfig
from repro.core.plans import Interval


@dataclass(frozen=True)
class MaterializedModel:
    model_id: int
    o: Interval                 # predicate range the model covers
    n_docs: int
    n_tokens: int
    kind: str                   # "vb" | "gs"
    theta: Dict[str, np.ndarray]

    @property
    def lam(self) -> np.ndarray:
        return self.theta["lam"]

    @property
    def delta_nkv(self) -> np.ndarray:
        return self.theta["delta_nkv"]

    def nbytes(self) -> int:
        return sum(int(v.nbytes) for v in self.theta.values())


def topics_from_vb(lam: np.ndarray) -> np.ndarray:
    """Posterior-mean topic-word distributions from Dirichlet params."""
    return lam / lam.sum(axis=1, keepdims=True)


def topics_from_gs(nkv: np.ndarray, eta: float) -> np.ndarray:
    """φ_kv = (N_kv + η) / (N_k + V η)  (paper Alg. 2 line 8)."""
    v = nkv.shape[1]
    return (nkv + eta) / (nkv.sum(axis=1, keepdims=True) + v * eta)


def model_topics(model: MaterializedModel, cfg: LDAConfig) -> np.ndarray:
    if model.kind == "vb":
        return topics_from_vb(model.lam)
    return topics_from_gs(model.delta_nkv, cfg.eta)


def greedy_topic_overlap(beta_a: np.ndarray, beta_b: np.ndarray,
                         top_n: int = 20) -> float:
    """Fraction of shared top-``top_n`` words under greedy 1:1 topic
    matching — the sampler-agnostic quality-parity metric the blocked
    Gibbs bench and its regression tests share (samplers permute
    topics, so rows must be matched before comparing)."""
    k = beta_a.shape[0]
    tops_a = [set(np.argsort(beta_a[i])[-top_n:].tolist()) for i in range(k)]
    tops_b = [set(np.argsort(beta_b[i])[-top_n:].tolist()) for i in range(k)]
    m = np.array([[len(a & b) for b in tops_b] for a in tops_a])
    total = 0
    for _ in range(k):
        i, j = np.unravel_index(np.argmax(m), m.shape)
        total += m[i, j]
        m[i, :] = -1
        m[:, j] = -1
    return total / (k * top_n)


def log_predictive_probability(
    beta: np.ndarray,
    x_test: np.ndarray,
    alpha: float = 0.5,
    n_iters: int = 30,
) -> float:
    """Held-out per-token log predictive probability (paper's lpp metric).

    Fold-in: estimate θ_d on held-out docs by EM against fixed ``beta``
    (row-stochastic (K, V)), then score Σ n_dw log(θ_d·β_:,w) / Σ n_dw.
    """
    k = beta.shape[0]
    d = x_test.shape[0]
    if d == 0 or x_test.sum() == 0:
        return 0.0
    beta = np.maximum(beta, 1e-12)
    theta = np.full((d, k), 1.0 / k)
    for _ in range(n_iters):
        # E: responsibilities implicit via the normalizer
        mix = theta @ beta  # (D, V)
        ratio = x_test / np.maximum(mix, 1e-12)
        theta_new = theta * (ratio @ beta.T) + alpha
        theta = theta_new / theta_new.sum(axis=1, keepdims=True)
    mix = np.maximum(theta @ beta, 1e-12)
    total = float(x_test.sum())
    return float((x_test * np.log(mix)).sum() / total)
