"""Interval algebra + candidate-plan generation (paper §V.B.3).

A *plan* for query range Q is a set of pairwise-disjoint materialized
models whose ranges are contained in Q, plus the implicit "train the
uncovered remainder" step.  *RL plans* ("relatively longest") are the
maximal such sets — every other candidate plan is obtained by removing
models from some RL plan (Theorem 1), which makes them the roots of the
hierarchical plan search.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Sequence, Tuple


@dataclass(frozen=True, order=True)
class Interval:
    lo: float
    hi: float

    def __post_init__(self):
        if self.hi < self.lo:
            raise ValueError(f"bad interval [{self.lo}, {self.hi})")

    @property
    def length(self) -> float:
        return self.hi - self.lo

    def contains(self, other: "Interval") -> bool:
        return self.lo <= other.lo and other.hi <= self.hi

    def overlaps(self, other: "Interval") -> bool:
        return self.lo < other.hi and other.lo < self.hi

    def intersect(self, other: "Interval") -> "Interval | None":
        lo, hi = max(self.lo, other.lo), min(self.hi, other.hi)
        return Interval(lo, hi) if lo < hi else None


def union_length(intervals: Iterable[Interval]) -> float:
    total, end = 0.0, float("-inf")
    for iv in sorted(intervals):
        lo = max(iv.lo, end)
        if iv.hi > lo:
            total += iv.hi - lo
            end = iv.hi
        end = max(end, iv.hi)
    return total


def subtract(universe: Interval, pieces: Sequence[Interval]) -> List[Interval]:
    """universe minus the union of pieces — the *uncovered* ranges."""
    out: List[Interval] = []
    cursor = universe.lo
    for iv in sorted(pieces):
        lo = max(iv.lo, universe.lo)
        hi = min(iv.hi, universe.hi)
        if hi <= lo:
            continue
        if lo > cursor:
            out.append(Interval(cursor, lo))
        cursor = max(cursor, hi)
    if cursor < universe.hi:
        out.append(Interval(cursor, universe.hi))
    return out


def intersect_lists(a: Sequence[Interval], b: Sequence[Interval]) -> List[Interval]:
    out: List[Interval] = []
    for x in a:
        for y in b:
            z = x.intersect(y)
            if z is not None:
                out.append(z)
    return sorted(out)


# ---------------------------------------------------------------------------
# candidate plans
# ---------------------------------------------------------------------------

def usable(models: Sequence, query: Interval) -> List:
    """Materialized models whose range is contained in the query range."""
    return [m for m in models if query.contains(m.o)]


def _disjoint(ivs: Sequence[Interval]) -> bool:
    s = sorted(ivs)
    return all(s[i].hi <= s[i + 1].lo for i in range(len(s) - 1))


def all_plans(models: Sequence, query: Interval) -> List[Tuple]:
    """Every candidate plan (all antichains of usable models), incl. {}.

    Exponential — this is the NAI baseline's generator.
    """
    cand = sorted(usable(models, query), key=lambda m: (m.o.lo, m.o.hi))
    plans: List[Tuple] = [()]
    for m in cand:
        new = []
        for p in plans:
            if all(not m.o.overlaps(x.o) for x in p):
                new.append(p + (m,))
        plans.extend(new)
    return plans


def rl_plans(models: Sequence, query: Interval) -> List[Tuple]:
    """All *maximal* antichains of usable models (Theorem 1 roots).

    Left-to-right enumeration: a disjoint set, listed in sorted order, is
    maximal iff no candidate fits wholly inside any unchosen gap.  Each
    maximal set is produced exactly once (its sorted order is unique).
    """
    cand = sorted(usable(models, query), key=lambda m: (m.o.lo, m.o.hi))
    if not cand:
        return [()]
    results: List[Tuple] = []

    def extend(chosen: Tuple, end: float) -> None:
        nxt = [m for m in cand if m.o.lo >= end]
        if not nxt:
            results.append(chosen)
            return
        for m in nxt:
            # choosing m next strands any candidate wholly inside the
            # gap [end, m.lo) — that set would not be maximal.
            if any(c is not m and c.o.hi <= m.o.lo for c in nxt):
                continue
            extend(chosen + (m,), m.o.hi)

    extend((), float("-inf"))
    return results


def children(plan: Tuple) -> List[Tuple]:
    """All plans obtained by removing exactly one model (plan-tree edge)."""
    return [plan[:i] + plan[i + 1 :] for i in range(len(plan))]


def plan_key(plan: Tuple) -> Tuple:
    return tuple(sorted(m.model_id for m in plan))
