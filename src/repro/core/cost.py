"""Plan cost model + score function (paper §IV.B–C, §V.B.2).

  sc(p) = α · l_p(p) + (1 − α) · c_t(p)                      (Eq. 2)

  l_p  = 1 − P(x)  — monotone performance-loss in the number of merged
         components x (P(0) = 1, i.e. a single-model plan loses nothing)
  c_t  = c_train(uncovered tokens) + t_m · x
         c_train(N) = κ · M_i · N^e · K  (paper states e = 2; the
         exponent is a calibratable knob — the planner only requires
         monotonicity)

c_t is normalized by the from-scratch cost of the whole query so both
score terms live in [0, 1] and α weighs comparable quantities.

The default P(x) follows the paper's Fig. 3/6 measurement (loss grows
roughly geometrically with merge count) and can be re-fit from the
``benchmarks/merging_effect`` run via ``PerformanceLoss.fit``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.plans import Interval, subtract


@dataclass(frozen=True)
class PerformanceLoss:
    """Monotone P(x): P(0) = 1, decreasing in merge count x."""

    rho: float = 0.98      # per-merge retention

    def p(self, x: int) -> float:
        return self.rho ** max(x, 0)

    def loss(self, x: int) -> float:
        return 1.0 - self.p(x)

    @classmethod
    def fit(cls, xs: Sequence[int], losses: Sequence[float]) -> "PerformanceLoss":
        """Least-squares fit of rho from measured (x, l_p) pairs."""
        xs = np.asarray(xs, float)
        ls = np.clip(np.asarray(losses, float), 0.0, 0.999)
        mask = xs > 0
        if not mask.any():
            return cls()
        # 1 - rho^x = l  =>  x*log(rho) = log(1-l)
        rho = float(np.exp((np.log(1.0 - ls[mask]) / xs[mask]).mean()))
        return cls(rho=min(max(rho, 1e-3), 0.9999))


@dataclass(frozen=True)
class CostModel:
    kappa_train: float = 1e-9   # seconds per (M_i · token^e · K) unit
    train_exponent: float = 2.0  # the paper's O(M_i N² K)
    t_merge: float = 1e-4       # seconds per single K×V merge (t_m)
    max_iters: int = 100        # M_i
    n_topics: int = 100         # K
    ploss: PerformanceLoss = field(default_factory=PerformanceLoss)

    # --- raw costs ------------------------------------------------------
    def c_train(self, n_tokens: float) -> float:
        return (self.kappa_train * self.max_iters
                * float(n_tokens) ** self.train_exponent * self.n_topics)

    def c_merge(self, x: int) -> float:
        return self.t_merge * max(x, 0)

    # --- plan-level -----------------------------------------------------
    def components(self, n_models: int, uncovered_tokens: float) -> int:
        """#things merged = models + (1 if a fresh model is trained)."""
        return n_models + (1 if uncovered_tokens > 0 else 0)

    def merges(self, n_models: int, uncovered_tokens: float) -> int:
        return max(self.components(n_models, uncovered_tokens) - 1, 0)

    def plan_lp(self, n_models: int, uncovered_tokens: float) -> float:
        return self.ploss.loss(self.merges(n_models, uncovered_tokens))

    def plan_ct(self, uncovered_tokens: float, n_models: int,
                scratch_tokens: float) -> float:
        """Normalized time cost in [0, ~1]."""
        x = self.merges(n_models, uncovered_tokens)
        raw = self.c_train(uncovered_tokens) + self.c_merge(x)
        denom = max(self.c_train(scratch_tokens), 1e-30)
        return raw / denom

    def score(self, alpha: float, n_models: int, uncovered_tokens: float,
              scratch_tokens: float) -> float:
        lp = self.plan_lp(n_models, uncovered_tokens)
        ct = self.plan_ct(uncovered_tokens, n_models, scratch_tokens)
        return alpha * lp + (1.0 - alpha) * ct

    # --- Theorem 3/4 critical point x* ----------------------------------
    def critical_x(self, min_model_tokens: float) -> float:
        """x* = c_t(min model) / t_m — below this width, merge cost is
        negligible and the merge list can be dropped (PSOA++)."""
        return self.c_train(min_model_tokens) / max(self.t_merge, 1e-30)


def plan_stats(plan: Tuple, query: Interval, index) -> Tuple[int, float]:
    """(n_models, uncovered_tokens) for a plan against a DataIndex."""
    gaps = subtract(query, [m.o for m in plan])
    unc = float(sum(index.tokens_in(g.lo, g.hi) for g in gaps))
    return len(plan), unc
