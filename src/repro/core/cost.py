"""Plan cost providers + score function (paper §IV.B–C, §V.B.2).

  sc(p) = α · l_p(p) + (1 − α) · c_t(p)                      (Eq. 2)

  l_p  = 1 − P(x)  — monotone performance-loss in the number of merged
         components x (P(0) = 1, i.e. a single-model plan loses nothing)
  c_t  = c_train(uncovered tokens) + t_m · x
         c_train(N) = κ · M_i · N^e · K  (paper states e = 2; the
         exponent is a calibratable knob — the planner only requires
         monotonicity)

c_t is normalized by the from-scratch cost of the whole query so both
score terms live in [0, 1] and α weighs comparable quantities.

Pricing is pluggable through the ``CostProvider`` base: the analytic
``CostModel`` is the parity default (exactly the pre-IR behavior), and
``CalibratedCostModel`` re-fits κ/t_m from *measured* session timings
and adds the terms the analytic model is blind to on the device
backend — device-cache hits (a cached model's fetch costs ~0), cache
misses (host→device transfer per part), and padding rows in batched
launches.  Providers price plans through two equivalent entry points:

  ``score_models(models, query, index, alpha, scratch)`` — the
      searcher hot path (bare model tuples, no IR construction)
  ``price_plan(plan_ir, alpha, scratch)`` — the Plan-IR form used by
      the session planner and benchmarks

both funnel into one ``_score_from`` so they can never disagree.

The default P(x) follows the paper's Fig. 3/6 measurement (loss grows
roughly geometrically with merge count) and can be re-fit from the
``benchmarks/merging_effect`` run via ``PerformanceLoss.fit``.
"""
from __future__ import annotations

import contextlib
import json
import os
import tempfile
import threading
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.plan_ir import Plan
from repro.core.plans import Interval, subtract


@dataclass(frozen=True)
class PerformanceLoss:
    """Monotone P(x): P(0) = 1, decreasing in merge count x."""

    rho: float = 0.98      # per-merge retention

    def p(self, x: int) -> float:
        return self.rho ** max(x, 0)

    def loss(self, x: int) -> float:
        return 1.0 - self.p(x)

    @classmethod
    def fit(cls, xs: Sequence[int], losses: Sequence[float]) -> "PerformanceLoss":
        """Least-squares fit of rho from measured (x, l_p) pairs."""
        xs = np.asarray(xs, float)
        ls = np.clip(np.asarray(losses, float), 0.0, 0.999)
        mask = xs > 0
        if not mask.any():
            return cls()
        # 1 - rho^x = l  =>  x*log(rho) = log(1-l)
        rho = float(np.exp((np.log(1.0 - ls[mask]) / xs[mask]).mean()))
        return cls(rho=min(max(rho, 1e-3), 0.9999))


class CostProvider:
    """What the plan searchers and the batch optimizer require.

    Concrete providers supply the primitives (``c_train``, ``t_merge``,
    ``ploss``); everything plan-level derives from them here, so the
    analytic and calibrated providers share one scoring skeleton.

    ``version`` changes whenever the provider's prices change (the
    calibrated model bumps it on every refit) — the session plan cache
    keys on it so stale plans are never served at new prices.
    """

    ploss: PerformanceLoss
    t_merge: float
    version: int = 0

    # --- primitives (provider-specific) ----------------------------------
    def c_train(self, n_tokens: float) -> float:
        raise NotImplementedError

    def c_merge(self, x: int) -> float:
        return self.t_merge * max(x, 0)

    # --- plan-level (shared) ----------------------------------------------
    def components(self, n_models: int, uncovered_tokens: float) -> int:
        """#things merged = models + (1 if a fresh model is trained)."""
        return n_models + (1 if uncovered_tokens > 0 else 0)

    def merges(self, n_models: int, uncovered_tokens: float) -> int:
        return max(self.components(n_models, uncovered_tokens) - 1, 0)

    def plan_lp(self, n_models: int, uncovered_tokens: float) -> float:
        return self.ploss.loss(self.merges(n_models, uncovered_tokens))

    def plan_ct(self, uncovered_tokens: float, n_models: int,
                scratch_tokens: float,
                model_ids: Tuple[int, ...] = ()) -> float:
        """Normalized time cost in [0, ~1]."""
        x = self.merges(n_models, uncovered_tokens)
        raw = (self.c_train(uncovered_tokens) + self.c_merge(x)
               + self.fetch_cost(model_ids, uncovered_tokens))
        denom = max(self.c_train(scratch_tokens), 1e-30)
        return raw / denom

    def fetch_cost(self, model_ids: Tuple[int, ...],
                   uncovered_tokens: float) -> float:
        """Backend data-movement cost of bringing the parts to the
        merge — 0 for the analytic model (host merges read Θ in place);
        the calibrated provider prices cache hits vs transfers here."""
        return 0.0

    def _score_from(self, alpha: float, n_models: int,
                    uncovered_tokens: float, scratch_tokens: float,
                    model_ids: Tuple[int, ...] = ()) -> float:
        lp = self.plan_lp(n_models, uncovered_tokens)
        ct = self.plan_ct(uncovered_tokens, n_models, scratch_tokens,
                          model_ids)
        return alpha * lp + (1.0 - alpha) * ct

    def score(self, alpha: float, n_models: int, uncovered_tokens: float,
              scratch_tokens: float) -> float:
        """Aggregate form (no model identity — analytic-equivalent)."""
        return self._score_from(alpha, n_models, uncovered_tokens,
                                scratch_tokens)

    def score_models(self, models: Tuple, query: Interval, index,
                     alpha: float, scratch_tokens: float) -> float:
        """Searcher hot path: price a candidate model set directly."""
        n, unc = plan_stats(models, query, index)
        ids = tuple(m.model_id for m in models)
        return self._score_from(alpha, n, unc, scratch_tokens, ids)

    def price_plan(self, plan: Plan, alpha: float,
                   scratch_tokens: float) -> float:
        """Plan-IR form: price a lowered ``Plan`` (same number as
        ``score_models`` on the model set it was lowered from)."""
        return self._score_from(alpha, plan.n_models,
                                plan.uncovered_tokens, scratch_tokens,
                                plan.model_ids)

    # --- Theorem 3/4 critical point x* ----------------------------------
    def critical_x(self, min_model_tokens: float) -> float:
        """x* = c_t(min model) / t_m — below this width, merge cost is
        negligible and the merge list can be dropped (PSOA++)."""
        return self.c_train(min_model_tokens) / max(self.t_merge, 1e-30)

    # --- speculation payoff (repro.ingest.speculate) ----------------------
    def predict_train_seconds(self, n_tokens: float) -> float:
        """Wall-seconds forecast for training a gap of ``n_tokens`` on
        the backend last named via ``set_train_backend`` — ``c_train``
        is already in raw seconds, so the forecast is the price."""
        return self.c_train(n_tokens)

    def speculation_pays(self, n_tokens: float, next_arrival_s: float,
                         margin: float = 1.0) -> bool:
        """Should a speculative trainer pre-train this gap?

        True when the forecast training time (scaled by ``margin``, a
        safety factor > 1 for conservative speculation) fits inside the
        predicted time until the hot range's next query arrival — i.e.
        the trained capital lands before the query that would repay it.
        Zero-token gaps never pay (nothing to train)."""
        if n_tokens <= 0:
            return False
        return (self.predict_train_seconds(n_tokens) * margin
                <= max(next_arrival_s, 0.0))

    # --- padding (batched device launches, §V.C) --------------------------
    def padding_cost(self, pad_rows: int) -> float:
        """Cost of zero-weight padding rows in a bucketed batch launch
        (0 for the analytic model; calibrated fits it from timings)."""
        return 0.0

    # --- measurement intake (no-ops except on calibrated providers) ------
    def observe_train(self, n_tokens: float, seconds: float,
                      backend: str = "host") -> None:
        pass

    def set_train_backend(self, backend: str) -> None:
        """Name the execution backend whose gap training the next plan
        prices — host and device samplers have different κ (the device
        route runs the blocked Gibbs sweep / fused E-step kernel)."""

    def observe_merge_host(self, n_merges: int, seconds: float) -> None:
        pass

    def observe_merge_device(self, hit_bytes: int, miss_bytes: int,
                             seconds: float,
                             backend: str = "device") -> None:
        """One fused device launch: *bytes* read from the device cache
        (hits) vs transferred host→device (misses).  Per-byte, not
        per-part, so prices stay correct once heterogeneous model
        shapes land.  ``backend`` names which device backend's fit the
        sample feeds — the sharded backend reports per-shard bytes."""

    def observe_pad(self, pad_bytes: int, seconds: float,
                    backend: str = "device") -> None:
        pass


@dataclass(frozen=True)
class CostModel(CostProvider):
    """The paper's analytic model — the parity-default provider."""

    kappa_train: float = 1e-9   # seconds per (M_i · token^e · K) unit
    train_exponent: float = 2.0  # the paper's O(M_i N² K)
    t_merge: float = 1e-4       # seconds per single K×V merge (t_m)
    max_iters: int = 100        # M_i
    n_topics: int = 100         # K
    ploss: PerformanceLoss = field(default_factory=PerformanceLoss)

    def c_train(self, n_tokens: float) -> float:
        return (self.kappa_train * self.max_iters
                * float(n_tokens) ** self.train_exponent * self.n_topics)


# ---------------------------------------------------------------------------
# calibration — fit the provider to measured session timings
# ---------------------------------------------------------------------------

_MAX_OBS = 512    # rolling window per observation kind


@contextlib.contextmanager
def _sidecar_lock(path: str):
    """Advisory exclusive lock serializing sidecar read-merge-replace
    cycles across *processes* (``<path>.lock`` + flock).  Without it a
    concurrent writer pair — e.g. service ``close()`` racing an ingest
    builder's shutdown save — can both read the same on-disk log and
    the slower replace drops the faster writer's samples.  On platforms
    without ``fcntl`` the lock degrades to best-effort (the atomic
    replace still prevents torn files, only the union guarantee
    weakens)."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    f = open(path + ".lock", "a")
    try:
        try:
            import fcntl
            fcntl.flock(f, fcntl.LOCK_EX)
        except ImportError:     # pragma: no cover - non-POSIX fallback
            pass
        yield
    finally:
        try:
            import fcntl
            fcntl.flock(f, fcntl.LOCK_UN)
        except ImportError:     # pragma: no cover
            pass
        f.close()

# JSON sidecar format version; unknown versions load as a cold start
# (never crash a session over a stale sidecar).  2: device_obs/pad_obs
# record *bytes* (hit_bytes, miss_bytes / pad_bytes), not part/row
# counts — format-1 sidecars cold-start rather than mis-scale.
# 3: device_obs/pad_obs are keyed by backend name like train_obs — the
# vocab-sharded backend observes *per-shard* bytes, so mixing its
# samples into the unsharded backend's fit would skew both; format-2
# sidecars cold-start rather than mis-attribute.
CALIBRATION_FORMAT = 3


@dataclass
class Calibration:
    """Rolling measurement log a session accumulates per backend.

    train_obs  : backend name -> (tokens, seconds) per trained gap —
                 κ is fit per backend, so the planner can price host
                 (exact scan) and device (blocked kernel) gap training
                 separately
    host_obs   : (x merges, seconds) per host merge
    device_obs : backend name -> (hit_bytes, miss_bytes, seconds) per
                 fused device launch — bytes read from the device cache
                 vs bytes transferred host→device.  The sharded backend
                 reports *per-shard* bytes (its cache accounts per
                 device), so its per-byte rates are directly comparable
                 to wall time and never pollute the unsharded fit
    pad_obs    : backend name -> (pad_bytes, seconds) per batch launch

    Mutation is serialized by an internal lock: service workers and
    concurrent sessions feed one shared log.
    """

    train_obs: Dict[str, List[Tuple[float, float]]] = field(
        default_factory=dict)
    host_obs: List[Tuple[int, float]] = field(default_factory=list)
    device_obs: Dict[str, List[Tuple[int, int, float]]] = field(
        default_factory=dict)
    pad_obs: Dict[str, List[Tuple[int, float]]] = field(
        default_factory=dict)

    def __post_init__(self):
        self._lock = threading.RLock()

    def _push(self, log: list, sample) -> None:
        with self._lock:
            log.append(sample)
            if len(log) > _MAX_OBS:
                del log[: len(log) - _MAX_OBS]

    def push_train(self, backend: str, sample: Tuple[float, float]) -> None:
        with self._lock:
            self._push(self.train_obs.setdefault(backend, []), sample)

    def push_device(self, backend: str,
                    sample: Tuple[int, int, float]) -> None:
        with self._lock:
            self._push(self.device_obs.setdefault(backend, []), sample)

    def push_pad(self, backend: str, sample: Tuple[int, float]) -> None:
        with self._lock:
            self._push(self.pad_obs.setdefault(backend, []), sample)

    def __len__(self) -> int:
        return (sum(len(o) for o in self.train_obs.values())
                + len(self.host_obs)
                + sum(len(o) for o in self.device_obs.values())
                + sum(len(o) for o in self.pad_obs.values()))

    # --- persistence (the store's JSON sidecar) ---------------------------
    def to_json_dict(self) -> dict:
        with self._lock:
            return {
                "format": CALIBRATION_FORMAT,
                "train_obs": {b: [list(s) for s in obs]
                              for b, obs in self.train_obs.items()},
                "host_obs": [list(s) for s in self.host_obs],
                "device_obs": {b: [list(s) for s in obs]
                               for b, obs in self.device_obs.items()},
                "pad_obs": {b: [list(s) for s in obs]
                            for b, obs in self.pad_obs.items()},
            }

    @classmethod
    def from_json_dict(cls, doc: dict) -> Optional["Calibration"]:
        """None on a version/shape mismatch (callers cold-start)."""
        if not isinstance(doc, dict) \
                or doc.get("format") != CALIBRATION_FORMAT:
            return None
        try:
            return cls(
                train_obs={str(b): [(float(t), float(s)) for t, s in obs]
                           for b, obs in doc["train_obs"].items()},
                host_obs=[(int(x), float(s)) for x, s in doc["host_obs"]],
                device_obs={str(b): [(int(h), int(m), float(s))
                                     for h, m, s in obs]
                            for b, obs in doc["device_obs"].items()},
                pad_obs={str(b): [(int(p), float(s)) for p, s in obs]
                         for b, obs in doc["pad_obs"].items()},
            )
        except (KeyError, TypeError, ValueError, AttributeError):
            return None

    def merged_with(self, other: "Calibration") -> "Calibration":
        """Union of two observation logs, deduplicated by observation
        identity (the sample tuples themselves).  ``other``'s samples
        that this log doesn't already hold are *prepended* — this log
        is the fresher one, so under the rolling window its samples
        survive trimming first."""
        def union(theirs: list, ours: list) -> list:
            have = set(map(tuple, ours))
            out = [s for s in map(tuple, theirs) if s not in have]
            out.extend(map(tuple, ours))
            return out[-_MAX_OBS:]

        def union_keyed(theirs: dict, ours: dict) -> dict:
            return {b: union(theirs.get(b, []), ours.get(b, []))
                    for b in set(theirs) | set(ours)}

        with self._lock:
            merged = Calibration(
                host_obs=union(other.host_obs, self.host_obs),
                device_obs=union_keyed(other.device_obs, self.device_obs),
                pad_obs=union_keyed(other.pad_obs, self.pad_obs),
                train_obs=union_keyed(other.train_obs, self.train_obs),
            )
        return merged

    def save(self, path: str, merge: bool = True) -> None:
        """Atomic write of the JSON sidecar.

        With ``merge`` (the default) the on-disk log is first merged in
        (dedup by observation identity), so two sessions saving to one
        shared sidecar union their logs instead of last-writer-wins
        clobbering.  The whole read-merge-replace runs under an
        advisory file lock (``<path>.lock``), making it a transaction:
        concurrent writer pairs serialize instead of the slower one
        dropping the faster one's samples."""
        with _sidecar_lock(path):
            out = self
            if merge:
                existing = Calibration.load(path)
                if existing is not None:
                    out = self.merged_with(existing)
            d = os.path.dirname(os.path.abspath(path))
            with tempfile.NamedTemporaryFile("w", dir=d, delete=False) as f:
                json.dump(out.to_json_dict(), f, indent=1)
                tmp = f.name
            os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> Optional["Calibration"]:
        """None when missing/unreadable/stale-format (cold start)."""
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            return None
        return cls.from_json_dict(doc)

    # Fits are *robust*: jit compilation inflates the first launch /
    # first training call by orders of magnitude, and a mean over raw
    # samples would keep the coefficients (and the provider version
    # the plan cache keys on) churning for many queries.  Medians damp
    # run-to-run jitter, and once three samples exist the single
    # hottest per-unit sample (the compile warm-up) is dropped.
    @staticmethod
    def _robust(unit_rates: Sequence[float]) -> Optional[float]:
        rates = sorted(unit_rates)
        if not rates:
            return None
        if len(rates) >= 3:
            rates = rates[:-1]          # drop the warm-up outlier
        return float(np.median(rates))

    # --- fits -------------------------------------------------------------
    def fit_kappa(self, base: CostModel,
                  backend: str = "host") -> Optional[float]:
        """κ from seconds ≈ κ · M_i · tokens^e · K per trained gap."""
        return self._robust(
            [(s / (base.max_iters * t ** base.train_exponent
                   * base.n_topics))
             for t, s in self.train_obs.get(backend, ())
             if t > 0 and s > 0])

    def fit_kappas(self, base: CostModel) -> Dict[str, float]:
        """Backend name -> fitted κ, for every backend with samples."""
        out = {}
        for backend in self.train_obs:
            kappa = self.fit_kappa(base, backend)
            if kappa is not None:
                out[backend] = kappa
        return out

    def fit_t_merge(self) -> Optional[float]:
        return self._robust(
            [s / x for x, s in self.host_obs if x > 0 and s > 0])

    def fit_device(self, backend: str = "device"
                   ) -> Optional[Tuple[float, float, float]]:
        """(t_launch, t_hit, t_miss): seconds ≈ t_launch
        + t_hit·hit_bytes + t_miss·miss_bytes, nonnegative least
        squares over one backend's log.  t_hit/t_miss are **per byte**
        (per-*shard* byte for the vocab-sharded backend)."""
        obs = [(h, m, s)
               for h, m, s in self.device_obs.get(backend, ()) if s > 0]
        if not obs:
            return None
        if len(obs) >= 3:
            # drop the hottest per-byte launch (jit compile warm-up)
            obs.remove(max(obs, key=lambda o: o[2] / max(o[0] + o[1], 1)))
        a = np.array([[1.0, h, m] for h, m, _ in obs])
        y = np.array([s for _, _, s in obs])
        if len(obs) < 3 or np.linalg.matrix_rank(a) < 3:
            # under-determined: attribute the median per-byte launch
            # cost to the bytes actually moved/read, keeping hit < miss
            t_byte = float(np.median(y / np.maximum(a[:, 1] + a[:, 2], 1)))
            return 0.0, 0.25 * t_byte, t_byte
        sol, *_ = np.linalg.lstsq(a, y, rcond=None)
        return tuple(float(max(v, 0.0)) for v in sol)

    def fit_devices(self) -> Dict[str, Tuple[float, float, float]]:
        """Backend name -> device fit, for every backend with samples."""
        out = {}
        for backend in self.device_obs:
            fit = self.fit_device(backend)
            if fit is not None:
                out[backend] = fit
        return out

    def fit_t_pad(self, backend: str = "device") -> Optional[float]:
        """Per padding *byte* in one backend's batch launches."""
        return self._robust(
            [s / p for p, s in self.pad_obs.get(backend, ())
             if p > 0 and s > 0])

    def fit_t_pads(self) -> Dict[str, float]:
        """Backend name -> fitted t_pad, for every backend with samples."""
        out = {}
        for backend in self.pad_obs:
            t_pad = self.fit_t_pad(backend)
            if t_pad is not None:
                out[backend] = t_pad
        return out


class CalibratedCostModel(CostProvider):
    """Backend-aware provider fitted from measured report timings.

    Starts at exact parity with ``base`` (no observations → analytic
    prices) and tightens as the session feeds it measurements:

      κ (per backend) training cost per token^e, fit separately per
                    execution backend (host exact Gibbs scan vs the
                    blocked device sweep have very different rates);
                    ``set_train_backend`` names the backend whose κ
                    the next plan search prices — **per calling
                    thread** (thread-local), so concurrent sessions,
                    service workers and the speculator can each hold
                    "set, then price" atomic on one shared provider
      t_merge       per-merge host cost
      t_hit/t_miss  per-**byte** device fetch cost split by cache
                    state — ``cache_probe(model_id)`` (wired to the
                    device backend's LRU by the session) decides which
                    applies; ``size_probe(model_id)`` supplies each
                    part's byte size (wired to the store), falling
                    back to ``part_bytes_hint`` so prices stay correct
                    once heterogeneous model shapes land
      t_pad         per padding **byte** in bucketed batch launches

    ``version`` increments on every refit so the session plan cache
    drops plans priced under stale coefficients.  ``calibration`` can
    be preloaded from the store's JSON sidecar (``Calibration.load``)
    so a new session starts at the previous session's prices instead
    of the analytic cold start.  Observation intake and refits are
    lock-serialized, so one provider can be shared by every session
    of a multi-tenant service.
    """

    def __init__(self, base: Optional[CostModel] = None, *,
                 cache_probe: Optional[Callable[[int], bool]] = None,
                 size_probe: Optional[Callable[[int], Optional[int]]] = None,
                 part_bytes_hint: Optional[float] = None,
                 calibration: Optional[Calibration] = None):
        self.base = base or CostModel()
        self.calibration = calibration if calibration is not None \
            else Calibration()
        self.cache_probe = cache_probe
        self.size_probe = size_probe
        self.part_bytes_hint = part_bytes_hint
        # backend name -> device count its cached models are sliced
        # across (sessions populate it).  Sharded backends observe
        # per-shard bytes, so their fetch prices must scale part sizes
        # down by the same factor to stay in the fitted unit.
        self.backend_shards: Dict[str, int] = {}
        # thread-local: one provider is shared by every worker, tenant
        # thread and the speculator of a service, and "set the backend,
        # then price" must be atomic per caller — a plain attribute let
        # a concurrent session's set_train_backend retarget κ between a
        # speculator's set and its speculation_pays read (mis-priced
        # speculative trains)
        self._train_backend = threading.local()
        self._lock = threading.RLock()
        self._version = 0
        self._dirty = len(self.calibration) > 0
        self._kappa: Dict[str, float] = {}
        self._t_merge: Optional[float] = None
        # per-backend device fits: backend name -> (t_hit, t_miss) /
        # t_pad.  Price reads resolve the calling thread's active
        # backend, falling back to the plain "device" fit (same shape
        # as κ's host fallback).
        self._t_fetch: Dict[str, Tuple[float, float]] = {}
        self._t_pads: Dict[str, float] = {}

    # Observations only mark the fit dirty; the (sort + median + lstsq)
    # refit runs at most once per price read, not once per observe_*
    # call on the submit hot path.
    def _ensure_fit(self) -> None:
        with self._lock:
            if self._dirty:
                self._dirty = False
                self.refit()

    @property
    def version(self) -> int:
        """Refit counter; the serve metrics registry mirrors it as
        ``mlego_calibration_refits_total``."""
        self._ensure_fit()
        return self._version

    # --- primitives --------------------------------------------------------
    @property
    def ploss(self) -> PerformanceLoss:
        return self.base.ploss

    @property
    def t_merge(self) -> float:
        with self._lock:
            self._ensure_fit()
            return self._t_merge if self._t_merge is not None \
                else self.base.t_merge

    @property
    def train_backend(self) -> str:
        """The *calling thread's* active training backend ("host" until
        that thread names one) — see ``set_train_backend``."""
        return getattr(self._train_backend, "name", "host")

    @train_backend.setter
    def train_backend(self, backend: str) -> None:
        self._train_backend.name = backend

    def set_train_backend(self, backend: str) -> None:
        self._train_backend.name = backend

    def load_calibration(self, path: str) -> bool:
        """Replace the measurement log with a persisted sidecar's.
        False (and no change) when missing/unreadable/stale-format.

        A sidecar that *exists* but cannot be parsed (corrupt or
        truncated JSON, wrong format version) cold-starts the provider
        at analytic prices with a warning — a damaged price log must
        never fail session construction, it only costs a re-warmup.
        A missing file stays silent: that is the normal first run.
        """
        cal = Calibration.load(path)
        if cal is None:
            if os.path.exists(path):
                warnings.warn(
                    f"calibration sidecar {path!r} is unreadable or "
                    f"stale-format; cold-starting at analytic prices "
                    f"(the log rebuilds from this session's timings)",
                    RuntimeWarning, stacklevel=2)
            return False
        self.calibration = cal
        self._dirty = len(cal) > 0
        return True

    def c_train(self, n_tokens: float) -> float:
        # the active backend's fitted κ; an unfit device backend falls
        # back to the host fit (closer than the analytic prior), then
        # to the analytic base.  Coefficients are snapshotted under the
        # lock so a concurrent refit can't tear the read.
        with self._lock:
            self._ensure_fit()
            kappa = self._kappa.get(self.train_backend,
                                    self._kappa.get("host",
                                                    self.base.kappa_train))
        return (kappa * self.base.max_iters
                * float(n_tokens) ** self.base.train_exponent
                * self.base.n_topics)

    def _fetch_params_locked(self) -> Tuple[float, float]:
        """(t_hit, t_miss) for the calling thread's active backend;
        callers hold ``self._lock``."""
        fit = self._t_fetch.get(self.train_backend,
                                self._t_fetch.get("device"))
        return fit if fit is not None else (0.0, 0.0)

    @property
    def _t_hit(self) -> float:
        with self._lock:
            self._ensure_fit()
            return self._fetch_params_locked()[0]

    @property
    def _t_miss(self) -> float:
        with self._lock:
            self._ensure_fit()
            return self._fetch_params_locked()[1]

    @property
    def _t_pad(self) -> Optional[float]:
        with self._lock:
            self._ensure_fit()
            return self._t_pads.get(self.train_backend,
                                    self._t_pads.get("device"))

    def _part_bytes(self, model_id: Optional[int] = None) -> float:
        """Byte size of one merge part: the store-wired probe when it
        answers, else the session's hint, else 1.0 (which degrades
        per-byte pricing to the old per-part pricing — relative plan
        ordering survives even unwired)."""
        if model_id is not None and self.size_probe is not None:
            nbytes = self.size_probe(model_id)
            if nbytes is not None:
                return float(nbytes)
        return float(self.part_bytes_hint) if self.part_bytes_hint else 1.0

    def fetch_cost(self, model_ids: Tuple[int, ...],
                   uncovered_tokens: float) -> float:
        with self._lock:                     # consistent (t_hit, t_miss)
            self._ensure_fit()
            t_hit, t_miss = self._fetch_params_locked()
        if t_hit == t_miss == 0.0:
            return 0.0
        cost = 0.0
        for mid in model_ids:
            hit = self.cache_probe is not None and self.cache_probe(mid)
            cost += (t_hit if hit else t_miss) * self._part_bytes(mid)
        if uncovered_tokens > 0:
            # the fresh gap model always uploads (hint-sized: it does
            # not exist yet, so no probe can size it)
            cost += t_miss * self._part_bytes()
        # per-shard unit: a sharded backend's fit is seconds per
        # per-device byte, so scale the (global) part sizes down to
        # what any one device actually moves
        return cost / max(self.backend_shards.get(self.train_backend, 1), 1)

    def padding_cost(self, pad_rows: int) -> float:
        """Padding rows share the merge statistic's shape, so one row
        is one (hint-sized) part's worth of bytes."""
        with self._lock:
            self._ensure_fit()
            t_pad = self._t_pads.get(self.train_backend,
                                     self._t_pads.get("device"))
        return (t_pad or 0.0) * max(pad_rows, 0) * self._part_bytes()

    # --- measurement intake -------------------------------------------------
    def observe_train(self, n_tokens: float, seconds: float,
                      backend: str = "host") -> None:
        self.calibration.push_train(backend,
                                    (float(n_tokens), float(seconds)))
        self._dirty = True

    def observe_merge_host(self, n_merges: int, seconds: float) -> None:
        self.calibration._push(self.calibration.host_obs,
                               (int(n_merges), float(seconds)))
        self._dirty = True

    def observe_merge_device(self, hit_bytes: int, miss_bytes: int,
                             seconds: float,
                             backend: str = "device") -> None:
        self.calibration.push_device(backend,
                                     (int(hit_bytes), int(miss_bytes),
                                      float(seconds)))
        self._dirty = True

    def observe_pad(self, pad_bytes: int, seconds: float,
                    backend: str = "device") -> None:
        """``seconds`` must be the *marginal* time attributable to the
        padding bytes (callers apportion the launch wall time), not
        the whole launch — t_pad multiplies per byte."""
        self.calibration.push_pad(backend, (int(pad_bytes), float(seconds)))
        self._dirty = True

    # Prices within 25% of each other rarely flip a plan choice (the
    # score gaps the searchers discriminate are coarser), but run-to-run
    # kernel timing jitter easily exceeds 5% — a tight threshold would
    # invalidate the plan cache on every submit for nothing.
    @staticmethod
    def _materially_different(a, b, rel: float = 0.25) -> bool:
        for x, y in zip(a, b):
            if (x is None) != (y is None):
                return True
            if x is None:
                continue
            if abs(x - y) > rel * max(abs(x), abs(y), 1e-30):
                return True
        return False

    def refit(self) -> None:
        with self._lock:
            c = self.calibration
            kappas = c.fit_kappas(self.base)
            t_merge = c.fit_t_merge()
            fetch = {b: (hit, miss)
                     for b, (_, hit, miss) in c.fit_devices().items()}
            if t_merge is None and fetch:
                # device sessions never see a host merge; the launch
                # cost amortized over one part's bytes is the closest
                # t_m analogue (taken from the cheapest fitted backend)
                t_hit = min(hit for hit, _ in fetch.values())
                t_merge = max(t_hit * self._part_bytes(),
                              self.base.t_merge)
            pads = c.fit_t_pads()
            for b, (hit, _) in fetch.items():
                # padding bytes stream like cached bytes of bandwidth;
                # the ragged launcher never pads so most backends only
                # ever see this default
                pads.setdefault(b, hit)
            kb = sorted(set(kappas) | set(self._kappa))
            fb = sorted(set(fetch) | set(self._t_fetch))
            pb = sorted(set(pads) | set(self._t_pads))

            def flat(ka, fe, pa, tm):
                out = tuple(ka.get(b) for b in kb) + (tm,)
                for b in fb:
                    out += fe.get(b, (None, None))
                return out + tuple(pa.get(b) for b in pb)

            new = flat(kappas, fetch, pads, t_merge)
            old = flat(self._kappa, self._t_fetch, self._t_pads,
                       self._t_merge)
            self._kappa, self._t_merge = kappas, t_merge
            self._t_fetch, self._t_pads = fetch, pads
            # version gates the session plan cache: bump only when
            # prices moved materially, so a converged calibration keeps
            # repeated queries on the cached plan
            if self._materially_different(new, old):
                self._version += 1


def plan_stats(plan: Tuple, query: Interval, index) -> Tuple[int, float]:
    """(n_models, uncovered_tokens) for a model set against a DataIndex."""
    gaps = subtract(query, [m.o for m in plan])
    unc = float(sum(index.tokens_in(g.lo, g.hi) for g in gaps))
    return len(plan), unc
