"""Batch query optimization (paper §V.C, Alg. 4).

Execution model (the paper's Fig. 5 semantics, made precise):

  A batch Q = {q_1..q_b} chooses one plan per query.  The *uncovered*
  gap ranges of all chosen plans are split into atomic segments at every
  gap endpoint; each atomic segment is trained ONCE and the fresh
  segment model is reused by every query whose gaps contain it.  So

    T(P)      = sum_s c_train(s) over distinct segments + merge costs,
    Benefit   B(P) = sum_s (|s| - 1) * c_train(s)            (Def. 3)

  where |s| is the number of plans whose gaps contain segment s — the
  training time saved versus executing every query alone.

Alg. 4 (heuristic): start from each query's top-1 (alpha = 0) plan; for
each query, take its L_1 (RL) plans, drop every model m whose pseudo-
combination benefit exceeds its training cost
(B({m, P^{-q}}) - c_t(m) > 0 — the paper's line 9 criterion: if m's
range is largely trained by the other queries anyway, training it
shared is cheaper than merging the materialized model), then rank the
pruned plans by B - dt (Thm. 6 scoring) and keep the best.  Queries are
processed in order, updating P in place.

``batch_oracle`` exhaustively scores every plan combination (NP-hard in
general — Thm. 5) for small instances; the property tests assert the
heuristic is never worse than the no-sharing default and never better
than the oracle.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cost import CostProvider, plan_stats
from repro.core.plan_ir import Plan
from repro.core.plans import Interval, plan_key, rl_plans, subtract, usable
from repro.core.search import lower, psoa_search


@dataclass
class BatchResult:
    plans: List[Tuple]           # chosen plan per query (parallel to queries)
    total_time: float            # T(P): shared training + merges
    naive_time: float            # sum of per-query times, no sharing
    benefit: float               # B(P)  (Def. 3)
    n_scored: int = 0
    elapsed_s: float = 0.0
    method: str = ""
    irs: List[Plan] = field(default_factory=list)   # lowered Plan IR per query
    alpha: float = 0.0           # weight used for the initial per-query plans


# ---------------------------------------------------------------------------
# segment algebra
# ---------------------------------------------------------------------------

def _gaps(plan: Tuple, query: Interval) -> List[Interval]:
    return subtract(query, [m.o for m in plan])


def _segments(gap_lists: Sequence[List[Interval]]) -> List[Tuple[float, float, int]]:
    """Atomic segments of the union of all gap lists -> (lo, hi, count)."""
    points = sorted({e for gaps in gap_lists for g in gaps for e in (g.lo, g.hi)})
    out = []
    for lo, hi in zip(points, points[1:]):
        mid = 0.5 * (lo + hi)
        cnt = sum(1 for gaps in gap_lists
                  if any(g.lo <= mid < g.hi for g in gaps))
        if cnt > 0:
            out.append((lo, hi, cnt))
    return out


def shared_time_and_benefit(plans: Sequence[Tuple], queries: Sequence[Interval],
                            index, cost: CostProvider
                            ) -> Tuple[float, float, float]:
    """(T, naive_T, B) for a plan combination (Def. 3 accounting).

    Merge launches are priced pad-free: the ragged segmented kernel
    packs every plan's parts into one launch with zero pad rows, so the
    size-bucketed pad term that used to ride on batched device merges
    no longer appears in T(P).  (``cost.padding_cost`` still prices
    explicit pad rows for callers that bucket — see the benchmarks.)
    """
    gap_lists = [_gaps(p, q) for p, q in zip(plans, queries)]
    segs = _segments(gap_lists)
    t_train = sum(cost.c_train(index.tokens_in(lo, hi)) for lo, hi, _ in segs)
    saved = sum((cnt - 1) * cost.c_train(index.tokens_in(lo, hi))
                for lo, hi, cnt in segs)
    t_merge = 0.0
    for p, gaps in zip(plans, gap_lists):
        comps = len(p) + sum(1 for g in gaps if index.tokens_in(g.lo, g.hi) > 0)
        t_merge += cost.c_merge(max(comps - 1, 0))
    total = t_train + t_merge
    return total, total + saved, saved


# ---------------------------------------------------------------------------
# Alg. 4 heuristic
# ---------------------------------------------------------------------------

def processing_order(queries: Sequence[Interval], index) -> List[int]:
    """§V.C batch reorder: process wide queries first.

    Alg. 4 updates plans in processing order, so earlier queries anchor
    the shared-segment structure later ones prune against.  Visiting
    queries by descending selected-token volume lets the widest ranges
    lay down the shared gaps before narrow queries decide what to drop.
    Ties (and the common all-equal case) preserve submission order.
    """
    toks = [float(index.tokens_in(q.lo, q.hi)) for q in queries]
    return sorted(range(len(queries)), key=lambda i: (-toks[i], i))


def batch_optimize(models: Sequence, queries: Sequence[Interval], index,
                   cost: CostProvider, *, alpha: float = 0.0,
                   max_rl_plans: int = 64,
                   order: Optional[Sequence[int]] = None) -> BatchResult:
    t0 = time.perf_counter()
    b = len(queries)
    # line 2-3: initial P = top-1 plan per query (alpha threaded from the
    # specs; 0.0 keeps the paper's pure time-cost regime)
    plans: List[Tuple] = []
    n_scored = 0
    for q in queries:
        r = psoa_search(models, q, index, cost, alpha)
        plans.append(r.plan)
        n_scored += r.n_scored

    for i in (range(b) if order is None else order):
        q = queries[i]
        others = [plans[j] for j in range(b) if j != i]
        other_qs = [queries[j] for j in range(b) if j != i]
        other_gaps = [_gaps(p, oq) for p, oq in zip(others, other_qs)]
        # loop-invariant: the no-m benefit baseline over the other
        # queries' gaps does not depend on the candidate model
        base = sum((cnt - 1) * cost.c_train(index.tokens_in(lo, hi))
                   for lo, hi, cnt in _segments(other_gaps))

        cand_models = [m for m in usable(models, q)
                       if index.tokens_in(m.o.lo, m.o.hi) > 0]
        roots = rl_plans(cand_models, q)[:max_rl_plans]

        # line 5: pseudo-combination benefit of each model
        drop: Dict[int, bool] = {}
        for m in cand_models:
            pseudo = other_gaps + [[m.o]]
            segs = _segments(pseudo)
            bene = sum((cnt - 1) * cost.c_train(index.tokens_in(lo, hi))
                       for lo, hi, cnt in segs)
            c_m = cost.c_train(index.tokens_in(m.o.lo, m.o.hi))
            drop[m.model_id] = (bene - base) - c_m > 0.0
            n_scored += 1

        # lines 7-13: prune each L_1 plan, rank by T(P) with qi swapped in
        best_plan, best_t = plans[i], None
        seen = set()
        for p in roots + [plans[i]]:
            p_star = tuple(m for m in p if not drop.get(m.model_id, False))
            k = plan_key(p_star)
            if k in seen:
                continue
            seen.add(k)
            trial = [(p_star if j == i else plans[j]) for j in range(b)]
            t_tot, _, _ = shared_time_and_benefit(trial, queries, index, cost)
            n_scored += 1
            if best_t is None or t_tot < best_t:
                best_plan, best_t = p_star, t_tot
        plans[i] = best_plan

    total, naive, bene = shared_time_and_benefit(plans, queries, index, cost)
    return BatchResult(plans, total, naive, bene, n_scored=n_scored,
                       elapsed_s=time.perf_counter() - t0, method="ALG4",
                       irs=[lower(p, q, index)
                            for p, q in zip(plans, queries)],
                       alpha=alpha)


# ---------------------------------------------------------------------------
# exhaustive oracle (Thm. 5 problem, small instances only)
# ---------------------------------------------------------------------------

def batch_oracle(models: Sequence, queries: Sequence[Interval], index,
                 cost: CostProvider, *, max_combos: int = 200_000
                 ) -> BatchResult:
    t0 = time.perf_counter()
    per_query: List[List[Tuple]] = []
    for q in queries:
        cand = [m for m in usable(models, q)
                if index.tokens_in(m.o.lo, m.o.hi) > 0]
        roots = rl_plans(cand, q)
        # all sub-plans of all roots (deduped) — the full plan space
        space: Dict[Tuple, Tuple] = {(): ()}
        stack = list(roots)
        while stack:
            p = stack.pop()
            k = plan_key(p)
            if k in space:
                continue
            space[k] = p
            for j in range(len(p)):
                stack.append(p[:j] + p[j + 1:])
        per_query.append(list(space.values()))

    n_combo = 1
    for s in per_query:
        n_combo *= len(s)
    if n_combo > max_combos:
        raise ValueError(f"{n_combo} combinations exceed the oracle budget")

    best, best_t = None, float("inf")
    n_scored = 0
    for combo in itertools.product(*per_query):
        t_tot, _, _ = shared_time_and_benefit(list(combo), queries, index, cost)
        n_scored += 1
        if t_tot < best_t:
            best, best_t = list(combo), t_tot
    total, naive, bene = shared_time_and_benefit(best, queries, index, cost)
    return BatchResult(best, total, naive, bene, n_scored=n_scored,
                       elapsed_s=time.perf_counter() - t0, method="ORACLE",
                       irs=[lower(p, q, index)
                            for p, q in zip(best, queries)])
