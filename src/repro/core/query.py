"""DEPRECATED query facade — use ``repro.api.MLegoSession`` instead.

The canonical implementation of the Fig. 2 pipeline (plan search ->
gap training -> merge) lives in ``repro.api`` (session / planner /
executor).  ``QueryEngine`` is now a *thin alias* over
``MLegoSession`` kept for one more release so ancient call sites fail
loudly-but-gracefully:

  * construction warns ``DeprecationWarning`` and builds the session
  * ``execute(sigma, alpha, method)`` -> ``submit(QuerySpec(...))``,
    returning the ``QueryReport`` (a superset of the retired
    ``QueryResult`` surface: beta/plan/n_trained_tokens/n_merged/
    train_s/merge_s/search_s/total_s/materialized are all present)
  * ``execute_batch(sigmas)`` -> ``submit_many([...])``, returning
    ``(reports, opt)`` — shared search/train costs now live on the
    ``BatchReport`` (``last_batch_report``), never smeared onto
    ``results[0]`` as the seed engine did

The legacy attribute-plumbing surface (assignable ``corpus``/``index``/
``store``/``cfg``/``cost``/``kind`` properties) and the ``QueryResult``
dataclass are gone — migrate to ``MLegoSession`` (see the migration
table in ``src/repro/api/README.md``).
"""
from __future__ import annotations

import warnings
from typing import List, Optional, Sequence, Tuple

from repro.api.reports import BatchReport, QueryReport
from repro.api.session import MLegoSession
from repro.api.spec import PERSIST, VOLATILE, QuerySpec
from repro.configs.lda_default import LDAConfig
from repro.core.batch_opt import BatchResult
from repro.core.cost import CostModel
from repro.core.plans import Interval
from repro.core.store import ModelStore
from repro.data.corpus import Corpus


class QueryEngine(MLegoSession):
    """Deprecated positional-argument alias of ``MLegoSession``."""

    def __init__(self, corpus: Corpus, store: ModelStore, cfg: LDAConfig,
                 cost: Optional[CostModel] = None, kind: str = "vb",
                 *, materialize_results: bool = True, seed: int = 0):
        warnings.warn(
            "QueryEngine is deprecated; use repro.api.MLegoSession.submit "
            "with a QuerySpec", DeprecationWarning, stacklevel=2)
        super().__init__(corpus, cfg, store=store, cost=cost, kind=kind,
                         seed=seed)
        self.materialize_results = materialize_results
        self.last_batch_report: Optional[BatchReport] = None

    def _spec(self, sigma, alpha: float, method: str = "psoa++") -> QuerySpec:
        return QuerySpec(sigma=sigma, alpha=alpha, kind=self.kind,
                         method=method,
                         materialize=PERSIST if self.materialize_results
                         else VOLATILE)

    def execute(self, sigma: Interval, alpha: float,
                method: str = "psoa++") -> QueryReport:
        """One analytic query: search, train gaps, merge."""
        return self.submit(self._spec(sigma, alpha, method))

    def execute_batch(self, sigmas: Sequence[Interval]
                      ) -> Tuple[List[QueryReport], BatchResult]:
        """§V.C batch path: Alg. 4 plan combination, shared gap training."""
        br = self.submit_many([self._spec(s, 0.0) for s in sigmas])
        self.last_batch_report = br
        return list(br.reports), br.opt


__all__ = ["QueryEngine"]
