"""DEPRECATED query facade — use ``repro.api.MLegoSession`` instead.

The canonical implementation of the Fig. 2 pipeline (plan search ->
gap training -> merge) lives in ``repro.api`` (session / planner /
executor); this module keeps the seed repo's ``QueryEngine`` surface
alive as a thin shim so old call sites keep working:

  * ``execute(sigma, alpha, method)``  -> ``session.submit(QuerySpec(...))``
  * ``execute_batch(sigmas)``          -> ``session.submit_many([...])``,
    re-applying the legacy cost attribution (shared search/train time
    dumped onto ``results[0]``) for bug-for-bug compatibility.  New
    code should read those costs from ``BatchReport`` instead — they
    are also stashed on ``self.last_batch_report``.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.api.reports import BatchReport, QueryReport
from repro.api.session import MLegoSession
from repro.api.spec import PERSIST, VOLATILE, QuerySpec
from repro.api.trainers import resolve_kind
from repro.configs.lda_default import LDAConfig
from repro.core.batch_opt import BatchResult
from repro.core.cost import CostModel
from repro.core.lda import MaterializedModel
from repro.core.plans import Interval
from repro.core.search import SearchResult
from repro.core.store import ModelStore
from repro.data.corpus import Corpus


@dataclass
class QueryResult:
    """Legacy result shape (kept for old call sites; see QueryReport)."""
    beta: np.ndarray             # merged topic-word matrix (K, V)
    plan: SearchResult
    n_trained_tokens: int
    n_merged: int
    train_s: float
    merge_s: float
    search_s: float
    materialized: List[MaterializedModel] = field(default_factory=list)

    @property
    def total_s(self) -> float:
        return self.train_s + self.merge_s + self.search_s


def _legacy(report: QueryReport) -> QueryResult:
    return QueryResult(report.beta, report.plan, report.n_trained_tokens,
                       report.n_merged, report.train_s, report.merge_s,
                       report.search_s, materialized=list(report.materialized))


class QueryEngine:
    """Deprecated: a positional-argument facade over ``MLegoSession``."""

    def __init__(self, corpus: Corpus, store: ModelStore, cfg: LDAConfig,
                 cost: Optional[CostModel] = None, kind: str = "vb",
                 *, materialize_results: bool = True, seed: int = 0):
        warnings.warn(
            "QueryEngine is deprecated; use repro.api.MLegoSession.submit "
            "with a QuerySpec", DeprecationWarning, stacklevel=2)
        self.session = MLegoSession(corpus, cfg, store=store, cost=cost,
                                    kind=kind, seed=seed)
        self.materialize_results = materialize_results
        self.last_batch_report: Optional[BatchReport] = None

    # --- delegated session state (old attribute surface, r/w) ----------
    # Setters mimic the seed engine's plain attributes: assignment
    # swaps the object used from then on, nothing else is recomputed
    # (e.g. setting corpus leaves index stale, exactly as before).
    @property
    def corpus(self) -> Corpus:
        return self.session.corpus

    @corpus.setter
    def corpus(self, v: Corpus) -> None:
        self.session.corpus = v
        self.session.executor.corpus = v

    @property
    def index(self):
        return self.session.index

    @index.setter
    def index(self, v) -> None:
        self.session.index = v
        self.session.planner.index = v

    @property
    def store(self) -> ModelStore:
        return self.session.store

    @store.setter
    def store(self, v: ModelStore) -> None:
        self.session.store = v
        self.session.executor.store = v

    @property
    def cfg(self) -> LDAConfig:
        return self.session.cfg

    @cfg.setter
    def cfg(self, v: LDAConfig) -> None:
        self.session.cfg = v
        self.session.executor.cfg = v

    @property
    def cost(self) -> CostModel:
        return self.session.cost

    @cost.setter
    def cost(self, v: CostModel) -> None:
        self.session.cost = v
        self.session.planner.cost = v

    @property
    def kind(self) -> str:
        return self.session.kind

    @kind.setter
    def kind(self, v: str) -> None:
        self.session.kind = resolve_kind(v)

    def _spec(self, sigma, alpha: float, method: str = "psoa++") -> QuerySpec:
        return QuerySpec(sigma=sigma, alpha=alpha, kind=self.kind,
                         method=method,
                         materialize=PERSIST if self.materialize_results
                         else VOLATILE)

    # ------------------------------------------------------------------
    def train_range(self, lo: float, hi: float) -> Optional[MaterializedModel]:
        """Train one fresh model on [lo, hi) and materialize it."""
        return self.session.train_range(lo, hi)

    def execute(self, sigma: Interval, alpha: float,
                method: str = "psoa++") -> QueryResult:
        """One analytic query: search, train gaps, merge."""
        return _legacy(self.session.submit(self._spec(sigma, alpha, method)))

    def execute_batch(self, sigmas: Sequence[Interval]
                      ) -> Tuple[List[QueryResult], BatchResult]:
        """§V.C batch path: Alg. 4 plan combination, shared gap training."""
        br = self.session.submit_many(
            [self._spec(s, 0.0) for s in sigmas])
        self.last_batch_report = br
        results = [_legacy(r) for r in br.reports]
        # legacy attribution: shared costs dumped on the first result
        # (BatchReport carries them properly — prefer it in new code)
        if results:
            results[0].train_s = br.shared_train_s
            results[0].search_s = br.shared_search_s
        return results, br.opt
