"""Analytic query execution (paper Def. 1: q = {F, alpha, D, sigma, M}).

The executor is the end-to-end path of Fig. 2: predicate -> plan search
-> online training of uncovered ranges -> model merge -> approximate
model m*.  Freshly trained gap models are materialized back into the
store, so the system's reuse capital grows with every query — the
interactivity flywheel the paper describes.

Batch path (§V.C): one plan per query from Alg. 4, shared gap segments
trained once, every query merged from its plan + the shared segment
models.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.lda_default import LDAConfig
from repro.core import merge as merge_mod
from repro.core.batch_opt import BatchResult, batch_optimize, _gaps, _segments
from repro.core.cost import CostModel, plan_stats
from repro.core.gibbs import cgs_fit
from repro.core.lda import MaterializedModel, topics_from_gs, topics_from_vb
from repro.core.plans import Interval, subtract
from repro.core.search import SearchResult, psoa_search, SEARCHERS
from repro.core.store import ModelStore
from repro.core.vb import vb_fit
from repro.data.corpus import Corpus, DataIndex, doc_term_matrix


@dataclass
class QueryResult:
    beta: np.ndarray             # merged topic-word matrix (K, V)
    plan: SearchResult
    n_trained_tokens: int
    n_merged: int
    train_s: float
    merge_s: float
    search_s: float
    materialized: List[MaterializedModel] = field(default_factory=list)

    @property
    def total_s(self) -> float:
        return self.train_s + self.merge_s + self.search_s


class QueryEngine:
    """Executes analytic queries against a corpus + model store."""

    def __init__(self, corpus: Corpus, store: ModelStore, cfg: LDAConfig,
                 cost: Optional[CostModel] = None, kind: str = "vb",
                 *, materialize_results: bool = True, seed: int = 0):
        self.corpus = corpus
        self.index = DataIndex(corpus)
        self.store = store
        self.cfg = cfg
        self.cost = cost or CostModel(max_iters=cfg.max_iters,
                                      n_topics=cfg.n_topics)
        self.kind = kind
        self.materialize_results = materialize_results
        self._key = jax.random.PRNGKey(seed)

    # ------------------------------------------------------------------
    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def train_range(self, lo: float, hi: float) -> Optional[MaterializedModel]:
        """Train one fresh model on [lo, hi) and materialize it."""
        d0, d1 = self.corpus.doc_slice(lo, hi)
        if d1 <= d0:
            return None
        sub = self.corpus.subset(lo, hi)
        if self.kind == "vb":
            x = doc_term_matrix(sub)
            lam = np.asarray(vb_fit(x, self._next_key(), self.cfg))
            theta = {"lam": lam}
        else:
            nkv = cgs_fit(sub.tokens, sub.doc_ids, self.cfg, self._next_key())
            theta = {"delta_nkv": nkv}
        return self.store.add(Interval(lo, hi), sub.n_docs, sub.n_tokens,
                              self.kind, theta)

    # ------------------------------------------------------------------
    def execute(self, sigma: Interval, alpha: float,
                method: str = "psoa++") -> QueryResult:
        """One analytic query: search, train gaps, merge."""
        t0 = time.perf_counter()
        searcher = SEARCHERS[method]
        res = searcher(self.store.models(self.kind), sigma, self.index,
                       self.cost, alpha)
        t_search = time.perf_counter() - t0

        t1 = time.perf_counter()
        fresh: List[MaterializedModel] = []
        n_tok = 0
        for gap in subtract(sigma, [m.o for m in res.plan]):
            m = self.train_range(gap.lo, gap.hi) if self.materialize_results \
                else self._train_volatile(gap.lo, gap.hi)
            if m is not None:
                fresh.append(m)
                n_tok += m.n_tokens
        t_train = time.perf_counter() - t1

        t2 = time.perf_counter()
        parts = list(res.plan) + fresh
        if not parts:
            raise ValueError(f"query {sigma} selects no data")
        beta = merge_mod.merge_models(parts, self.cfg)
        t_merge = time.perf_counter() - t2
        return QueryResult(beta, res, n_tok, len(parts), t_train, t_merge,
                           t_search, materialized=fresh)

    def _train_volatile(self, lo: float, hi: float) -> Optional[MaterializedModel]:
        d0, d1 = self.corpus.doc_slice(lo, hi)
        if d1 <= d0:
            return None
        sub = self.corpus.subset(lo, hi)
        if self.kind == "vb":
            x = doc_term_matrix(sub)
            lam = np.asarray(vb_fit(x, self._next_key(), self.cfg))
            theta = {"lam": lam}
        else:
            nkv = cgs_fit(sub.tokens, sub.doc_ids, self.cfg, self._next_key())
            theta = {"delta_nkv": nkv}
        return MaterializedModel(-1, Interval(lo, hi), sub.n_docs,
                                 sub.n_tokens, self.kind, theta)

    # ------------------------------------------------------------------
    def execute_batch(self, sigmas: Sequence[Interval]
                      ) -> Tuple[List[QueryResult], BatchResult]:
        """§V.C batch path: Alg. 4 plan combination, shared gap training."""
        t0 = time.perf_counter()
        opt = batch_optimize(self.store.models(self.kind), list(sigmas),
                             self.index, self.cost)
        t_search = time.perf_counter() - t0

        # train every atomic shared segment exactly once
        gap_lists = [_gaps(p, q) for p, q in zip(opt.plans, sigmas)]
        seg_models: Dict[Tuple[float, float], MaterializedModel] = {}
        t1 = time.perf_counter()
        for lo, hi, _ in _segments(gap_lists):
            m = self.train_range(lo, hi) if self.materialize_results \
                else self._train_volatile(lo, hi)
            if m is not None:
                seg_models[(lo, hi)] = m
        t_train = time.perf_counter() - t1

        results: List[QueryResult] = []
        for qi, (plan, gaps, sigma) in enumerate(
                zip(opt.plans, gap_lists, sigmas)):
            t2 = time.perf_counter()
            parts = list(plan)
            n_tok = 0
            for (lo, hi), m in seg_models.items():
                if any(g.lo <= lo and hi <= g.hi for g in gaps):
                    parts.append(m)
                    n_tok += m.n_tokens
            beta = merge_mod.merge_models(parts, self.cfg)
            t_merge = time.perf_counter() - t2
            sr = SearchResult(plan, 0.0, 0.0, method="ALG4")
            results.append(QueryResult(beta, sr, n_tok, len(parts),
                                       0.0, t_merge, 0.0))
        # attribute shared costs once (on the batch result)
        if results:
            results[0].train_s = t_train
            results[0].search_s = t_search
        return results, opt
