"""``MLegoSession`` — the canonical entry point to MLego.

The session owns the Def. 1 members that are *not* per-query: the
dataset D (corpus + range index), the analysis function F (LDAConfig +
default trainer kind), the materialized-model store, the plan cost
provider, the RNG state, and the execution backend.  Queries arrive as
typed ``QuerySpec``s through a single ``submit`` path:

    session = MLegoSession(corpus, cfg)
    report  = session.submit(QuerySpec(sigma=Interval(0, 500), alpha=0.5))
    batch   = session.submit_many([spec1, spec2, spec3])

``submit`` runs the Fig. 2 pipeline per predicate component (plan
search -> gap training -> merge); union-of-intervals predicates are
planned per component and merged into one model.  Each component's
search goes through the session **plan cache** first: a repeated query
against an unchanged store (same σ, α, kind, method, backend, prices)
skips the search stage entirely (``QueryReport.plan_cached``); any
store mutation invalidates the cache through ``ModelStore.subscribe``.

``submit_many`` runs the §V.C Alg. 4 batch path: the batch is
reordered for joint planning (widest query first), every shared gap
segment is trained exactly once, the merge stage launches as
one ragged segmented kernel (zero pad rows), and the shared search/train costs are
reported at the batch level (``BatchReport``), not on the first query.

Plan search prices plans through a pluggable cost provider
(``cost="analytic"`` — the paper's Eq. 2 model — or
``cost="calibrated"``, which refits κ/t_m from this session's measured
timings and prices device-cache hits/misses and batch padding; see
``repro.core.cost``).  The data plane (merge + gap training) executes
on a pluggable backend: ``backend="host"`` (default) is the NumPy
reference; ``"device"`` keeps hot model parameters device-resident and
merges through the fused Pallas kernel.  A ``QuerySpec.backend``
overrides the session default per query.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Callable, List, Optional, Sequence, Union

import jax

from repro.api.backend import DeviceBackend, ExecutionBackend, make_backend
from repro.api.executor import Executor, StalePlanError
from repro.core.errors import DeviceLostError, RetryPolicy
from repro.obs import trace as obs
from repro.obs.trace import Tracer
from repro.api.planner import PlanCache, Planner
from repro.api.reports import BatchReport, QueryReport
from repro.api.spec import QuerySpec
from repro.api.trainers import resolve_kind
from repro.configs.lda_default import LDAConfig
from repro.core.batch_opt import BatchResult, _segments
from repro.core.cost import (
    CalibratedCostModel,
    Calibration,
    CostModel,
    CostProvider,
)
from repro.core.lda import MaterializedModel
from repro.core.plans import Interval
from repro.core.search import SearchResult
from repro.core.store import ModelStore
from repro.data.corpus import Corpus, DataIndex

CALIBRATION_SIDECAR = "calibration.json"


def calibration_sidecar(store_path: str) -> str:
    """Path of the calibration JSON sidecar for a store directory."""
    return os.path.join(store_path, CALIBRATION_SIDECAR)


def _store_size_probe(store: ModelStore):
    """Byte-size probe closed over one store (None for unknown ids) —
    homed on the store, not a session, so a session's later store swap
    cannot silently re-aim a probe other sessions price through."""
    def probe(model_id: int) -> Optional[int]:
        try:
            return store.get(model_id).nbytes()
        except KeyError:
            return None
    return probe


class MLegoSession:
    """One corpus + one model store + one RNG stream; many queries."""

    def __init__(self, corpus: Corpus, cfg: LDAConfig, *,
                 store: Optional[ModelStore] = None,
                 cost: Union[CostProvider, str, None] = None,
                 kind: str = "vb", seed: int = 0,
                 backend: Union[str, ExecutionBackend] = "host",
                 plan_cache: Optional[PlanCache] = None,
                 plan_cache_entries: int = 256,
                 calibration_path: Optional[str] = None,
                 retry: Optional[RetryPolicy] = None,
                 tracer: Optional[Tracer] = None,
                 profile: bool = False):
        self.corpus = corpus
        self.index = DataIndex(corpus)
        self._backends = {}
        store = store if store is not None else ModelStore()
        # an externally-owned plan cache (the serving layer's shared
        # cache) must already be homed on this session's store — keys
        # are value-addressed, but adopting a cache that invalidates
        # over a *different* store would clear it out from under its
        # other sessions on the bind below
        if plan_cache is not None and plan_cache.store is not None \
                and plan_cache.store is not store:
            raise ValueError(
                "plan_cache is bound to a different store; a shared "
                "plan cache requires the sharing sessions to share the "
                "store it invalidates over")
        self._owns_plan_cache = plan_cache is None
        self._adopted_backends = set()   # backend *instances* handed in
        self._plan_cache = plan_cache if plan_cache is not None \
            else PlanCache(max_entries=plan_cache_entries)
        self.store = store
        self.cfg = cfg
        self.calibration_path = calibration_path
        # provider *instances* may be shared across sessions (the
        # serving layer's one calibration log); string/None selections
        # construct a private provider this session may re-home freely
        self._owns_cost = cost is None or isinstance(cost, str)
        self.cost = self._make_cost(cost, cfg, calibration_path)
        self._wire_cost_probes()
        self.kind = resolve_kind(kind)       # default backend for train_range
        self._key = jax.random.PRNGKey(seed)
        self._key_lock = threading.Lock()
        # bumped by extend_corpus: plans priced under an older corpus
        # snapshot counted fewer tokens per range, so cached entries
        # keyed on an older epoch are never served (capital aging —
        # the store fingerprint alone can't see corpus growth)
        self._data_epoch = 0
        self.planner = Planner(self.index, self.cost)
        # one retry policy for every data-plane call; shared with the
        # serving layer when it constructs tenant sessions
        self.retry = retry if retry is not None else RetryPolicy()
        self.executor = Executor(corpus, cfg, self.store, self._next_key,
                                 retry=self.retry)
        # tracing: every submit/submit_many opens a root span on this
        # tracer; a private tracer by default, or the serving layer's
        # shared one (so worker-thread spans from many tenant sessions
        # land in one exportable buffer)
        self.tracer = tracer if tracer is not None else Tracer()
        self._profile = profile
        # optional outcome hook: called once per answered query with
        # (answered_by_backend, fallback_from, error) — the serving
        # layer installs its breaker/health feed here so *direct*
        # session use (tenants bypassing the front door) still counts
        self.on_outcome: Optional[
            Callable[[str, Optional[str], Optional[BaseException]],
                     None]] = None
        self.backend = self._register_backend(
            make_backend(backend, profile=profile)
            if isinstance(backend, str) else backend,
            adopted=not isinstance(backend, str))

    @staticmethod
    def _make_cost(cost: Union[CostProvider, str, None],
                   cfg: LDAConfig,
                   calibration_path: Optional[str] = None) -> CostProvider:
        base = CostModel(max_iters=cfg.max_iters, n_topics=cfg.n_topics)
        if cost is None or cost == "analytic":
            if calibration_path is not None:
                # silently ignoring the sidecar would leave the session
                # at analytic prices while the caller believes it
                # warm-started
                raise ValueError(
                    "calibration_path requires cost='calibrated' (or a "
                    "CalibratedCostModel instance); the analytic "
                    "provider has nothing to load it into")
            return base
        if cost == "calibrated":
            provider = CalibratedCostModel(base)
            if calibration_path:
                provider.load_calibration(calibration_path)
            return provider
        if isinstance(cost, str):
            raise ValueError(f"unknown cost provider {cost!r}; "
                             f"one of ('analytic', 'calibrated') or a "
                             f"CostProvider instance")
        if calibration_path is not None:
            if not isinstance(cost, CalibratedCostModel):
                raise ValueError(
                    "calibration_path requires cost='calibrated' (or a "
                    f"CalibratedCostModel instance), got {cost!r}")
            if len(cost.calibration) == 0:
                cost.load_calibration(calibration_path)
        return cost

    def _wire_cost_probes(self) -> None:
        """Point a calibrated provider's byte-size probe at the store
        (fetch terms are per-byte) and seed the part-size hint from the
        config's (K, V) f32 shape.  The probe is homed on the *store*
        (not this session), so sharing the provider requires sharing
        that store — model ids collide across stores, and a foreign
        probe would silently mis-size every fetch."""
        if getattr(self.cost, "size_probe", False) is None:
            self.cost.size_probe = _store_size_probe(self.store)
            self.cost._size_probe_store = self.store
        else:
            wired = getattr(self.cost, "_size_probe_store", None)
            if wired is not None and wired is not self.store:
                raise ValueError(
                    "cost provider's size probe is wired to a different "
                    "store; share a calibrated provider only between "
                    "sessions that share one store")
        if getattr(self.cost, "part_bytes_hint", False) is None:
            self.cost.part_bytes_hint = float(
                self.cfg.n_topics * self.cfg.vocab_size * 4)

    def save_calibration(self, path: Optional[str] = None) -> str:
        """Persist the calibrated provider's measurement log as the
        store's JSON sidecar (versioned) — the next
        ``MLegoSession(cost="calibrated", calibration_path=...)`` over
        this store starts at today's prices instead of the analytic
        cold start.  Returns the path written."""
        path = path or self.calibration_path
        if path is None:
            raise ValueError("no calibration path: pass one here or set "
                             "calibration_path= on the session")
        cal = getattr(self.cost, "calibration", None)
        if cal is None:
            raise ValueError("session's cost provider is not calibrated; "
                             "nothing to persist")
        cal.save(path)
        return path

    # ------------------------------------------------------------------
    @property
    def store(self) -> ModelStore:
        return self._store

    @store.setter
    def store(self, v: ModelStore) -> None:
        # Swapping the store (the legacy-shim path) must re-home every
        # backend cache — stale subscriptions would miss invalidations —
        # and the plan cache, whose entries reference the old model set.
        # Shared resources are the exception: an *adopted* backend may
        # serve other sessions over the old store, so rebinding it here
        # would silently break them — the caller must re-home it
        # explicitly (backend.bind_store) before the swap; a shared
        # plan cache is simply left behind (still homed on the old
        # store, still serving its other sessions) and replaced with a
        # fresh private one.
        for name, b in self._backends.items():
            if name in getattr(self, "_adopted_backends", ()) \
                    and b.bound_store is not None and b.bound_store is not v:
                raise ValueError(
                    "cannot swap the store under an adopted execution "
                    "backend (it may be shared by other sessions over "
                    "the old store); call backend.bind_store(new_store) "
                    "first if the backend really is private")
        probe_store = getattr(getattr(self, "cost", None),
                              "_size_probe_store", None)
        if probe_store is not None and probe_store is not v:
            if getattr(self, "_owns_cost", True):
                # private provider: re-home its byte-size probe
                self.cost.size_probe = _store_size_probe(v)
                self.cost._size_probe_store = v
            else:
                raise ValueError(
                    "cannot swap the store under a shared cost provider "
                    "(its size probe prices fetches against the old "
                    "store, which other sessions may still use)")
        self._store = v
        for b in self._backends.values():
            b.bind_store(v)
        if getattr(self, "_owns_plan_cache", True) \
                or self._plan_cache.store is None \
                or self._plan_cache.store is v:
            # private cache, or shared cache being adopted/kept on its
            # home store: (re)bind — no-op when already homed on v
            self._plan_cache.bind_store(v)
        else:
            # swapping away from a shared cache's home store: leave it
            # behind (still serving its other sessions) and continue
            # with a fresh private cache on the new store
            self._plan_cache = PlanCache(
                max_entries=self._plan_cache.max_entries)
            self._plan_cache.bind_store(v)
            self._owns_plan_cache = True
        if hasattr(self, "executor"):       # unset during __init__
            self.executor.store = v

    @property
    def plan_cache(self) -> PlanCache:
        return self._plan_cache

    def _next_key(self):
        # locked: a service tenant may build capital on its own thread
        # while the worker loop executes the same session — an unlocked
        # read-split-write here would hand both threads the same key
        # (duplicate RNG streams, silently correlated samples)
        with self._key_lock:
            self._key, k = jax.random.split(self._key)
            return k

    def extend_corpus(self, corpus: Corpus) -> None:
        """Install a grown corpus snapshot (streaming ingestion).

        Growth is append-only: the new snapshot must contain at least
        the old one's documents (the ingest pipeline only ever
        concatenates).  The range index, planner and executor are
        re-homed on the new snapshot, and the data epoch bumps so
        cached plans priced under the old token counts are dropped —
        a query over a freshly ingested range must re-plan, not ride a
        cached plan that believed the range was empty.
        """
        if corpus.vocab_size != self.corpus.vocab_size:
            raise ValueError(
                f"extend_corpus: vocab mismatch ({corpus.vocab_size} vs "
                f"{self.corpus.vocab_size})")
        if corpus.n_docs < self.corpus.n_docs:
            raise ValueError(
                "extend_corpus is append-only: the new snapshot has "
                f"{corpus.n_docs} docs, fewer than the current "
                f"{self.corpus.n_docs}")
        index = DataIndex(corpus)
        self.corpus = corpus
        self.index = index
        self.planner.index = index
        self.executor.corpus = corpus
        self._data_epoch += 1

    def adopt_backend(self, inst: ExecutionBackend) -> ExecutionBackend:
        """Register a shared execution backend instance under its name,
        so specs naming that backend route to it instead of a fresh
        private instance — the serving layer's per-name routing."""
        return self._register_backend(inst, adopted=True)

    def _register_backend(self, inst: ExecutionBackend,
                          adopted: bool = False) -> ExecutionBackend:
        bound = inst.bound_store
        if adopted:
            self._adopted_backends.add(inst.name)
        if bound is not None and bound is not self.store:
            # sharing one backend across sessions is supported *over
            # one shared store* (the serving layer's device LRU); two
            # different stores both allocate model id 0, so a shared
            # cache would silently cross-serve parameters
            raise ValueError(
                "execution backend is already bound to a different "
                "store; its device cache is keyed by model id and ids "
                "collide across stores — share a backend only between "
                "sessions that share one store (one backend per session "
                "otherwise)")
        inst.bind_store(self.store)
        self._backends[inst.name] = inst
        # a calibrated provider prices fetches by device-cache state;
        # point its probe at the device backend's LRU once one exists
        if (isinstance(inst, DeviceBackend)
                and getattr(self.cost, "cache_probe", False) is None):
            self.cost.cache_probe = lambda mid: mid in inst.cache
        # a sharded backend observes *per-shard* bytes; tell the
        # provider so fetch prices use the same unit the fit is in
        shards = getattr(self.cost, "backend_shards", None)
        if shards is not None and inst.shards > 1:
            shards[inst.name] = inst.shards
        return inst

    def _backend_for(self, spec: QuerySpec) -> ExecutionBackend:
        """Spec's backend (session default when unset), one instance per
        name so device caches survive across queries."""
        if spec.backend is None:
            return self.backend
        if spec.backend not in self._backends:
            self._register_backend(
                make_backend(spec.backend, profile=self._profile))
        return self._backends[spec.backend]

    # device-loss fallback chain: sharded -> single-device -> host
    # (host is terminal: it cannot lose a device)
    _FALLBACK = {"device_sharded": "device", "device": "host"}

    def _fail_over(self, backend: ExecutionBackend
                   ) -> Optional[ExecutionBackend]:
        """Quarantine a device-lost backend and return the next healthy
        backend on the fallback chain (None when the chain is
        exhausted or the backend has no fallback).  The quarantined
        backend stays registered — a breaker's half-open probe (or an
        explicit ``unquarantine``) re-admits it."""
        backend.quarantine()
        name = backend.name
        while True:
            name = self._FALLBACK.get(name)
            if name is None:
                return None
            if name not in self._backends:
                self._register_backend(
                    make_backend(name, profile=self._profile))
            nxt = self._backends[name]
            if not nxt.quarantined:
                obs.instant("fallback", from_backend=backend.name,
                            to_backend=nxt.name)
                return nxt

    def _models(self, kind: str) -> List[MaterializedModel]:
        """Store models of ``kind``, matching alias tags too — stores
        persisted by the legacy engine may carry e.g. "gibbs" verbatim."""
        out = []
        for m in self.store.models():
            try:
                mk = resolve_kind(m.kind)
            except ValueError:
                mk = m.kind
            if mk == kind:
                out.append(m)
        return out

    def train_range(self, lo: float, hi: float,
                    kind: Optional[str] = None) -> Optional[MaterializedModel]:
        """Materialize one model on [lo, hi) (offline capital building)."""
        return self.executor.train_gap(lo, hi, kind or self.kind,
                                       persist=True, backend=self.backend)

    # ------------------------------------------------------------------
    def _component_key(self, sigma: Interval, spec: QuerySpec, kind: str,
                       backend: ExecutionBackend, fingerprint: int) -> tuple:
        # a calibrated provider prices fetches by device-LRU residency
        # (cache_probe), so residency churn must key the cache too —
        # otherwise a cached plan could be served at stale fetch prices
        return (sigma.lo, sigma.hi, spec.alpha, kind, spec.method,
                backend.name, fingerprint, self.cost,
                getattr(self.cost, "version", 0),
                self._cache_epoch(backend), self._data_epoch)

    def plan_cached_for(self, spec: QuerySpec) -> bool:
        """True when every component of ``spec`` already has a cached
        plan — i.e. answering it costs no search.  Non-counting and
        non-promoting (``PlanCache.peek``): the serving layer's SLO
        degradation loop probes this to decide whether degrading α
        would actually save anything."""
        kind = spec.kind or self.kind
        backend = self._backend_for(spec)
        fingerprint = PlanCache.fingerprint(self._models(kind))
        return all(
            self._plan_cache.peek(self._component_key(
                sigma, spec, kind, backend, fingerprint)) is not None
            for sigma in spec.sigma)

    def _plan_component(self, models, fingerprint: int, sigma: Interval,
                        spec: QuerySpec, kind: str,
                        backend: ExecutionBackend
                        ) -> tuple:
        """(SearchResult, was_cached) for one predicate component."""
        key = self._component_key(sigma, spec, kind, backend, fingerprint)
        cached = self._plan_cache.get(key)
        if cached is not None:
            return cached, True
        # κ is backend-keyed: gap training must be priced at the rate
        # of the backend that will actually run it
        self.cost.set_train_backend(backend.name)
        res = self.planner.plan(models, sigma, spec.alpha, spec.method)
        self._plan_cache.put(key, res)
        return res, False

    def _cache_epoch(self, backend: ExecutionBackend) -> int:
        if getattr(self.cost, "cache_probe", None) is not None \
                and isinstance(backend, DeviceBackend):
            return backend.cache.epoch
        return 0

    def _observe_merge(self, n_merges: int, merge_s: float, d,
                       backend: str = "device") -> None:
        """Feed measured merge timings to the cost provider (fetch and
        pad terms are per-byte, read off the backend's traffic
        counters).  ``backend`` names which device backend's fit the
        samples feed — the sharded backend's counters are per-shard
        bytes, which must never mix into the unsharded fit."""
        if d.merge_device_ms > 0.0:
            secs = d.merge_device_ms * 1e-3
            traffic = d.cache_hit_bytes + d.cache_miss_bytes + d.pad_bytes
            if d.pad_bytes > 0 and traffic > 0:
                # apportion the launch by bytes: the pad share is the
                # *marginal* time the zero-weight rows cost, the rest
                # stays attributed to the real fetches below
                pad_secs = secs * d.pad_bytes / traffic
                self.cost.observe_pad(d.pad_bytes, pad_secs,
                                      backend=backend)
                secs -= pad_secs
            self.cost.observe_merge_device(d.cache_hit_bytes,
                                           d.cache_miss_bytes, secs,
                                           backend=backend)
        elif n_merges > 0:
            self.cost.observe_merge_host(n_merges, merge_s)

    def _emit_outcome(self, answered_by: str,
                      fallback_from: Optional[str],
                      error: Optional[BaseException]) -> None:
        """Fire the outcome hook, never letting observer errors mask
        the query's own result."""
        if self.on_outcome is None:
            return
        try:
            self.on_outcome(answered_by, fallback_from, error)
        except Exception:
            pass

    def submit(self, spec: QuerySpec) -> QueryReport:
        """One analytic query: plan search, gap training, merge.

        ``spec.kind=None`` (the default) uses the session's kind;
        ``spec.backend=None`` the session's execution backend.

        The whole query runs under a ``session.submit`` root span on
        ``self.tracer``; the returned report carries its ``trace`` id,
        so the query's plan/fetch/train/merge breakdown can be looked
        up in the exported Chrome trace.
        """
        with self.tracer.span(
                "session.submit", "session",
                attrs={"sigma": str(spec.sigma), "alpha": spec.alpha,
                       "kind": spec.kind or self.kind}) as root:
            try:
                rep = self._submit_traced(spec)
            except BaseException as exc:
                self._emit_outcome(spec.backend or self.backend.name,
                                   None, exc)
                raise
        if root is not None and rep.trace is None:
            rep.trace = root.trace_id
        self._emit_outcome(rep.backend, rep.fallback_from, None)
        return rep

    def _submit_traced(self, spec: QuerySpec) -> QueryReport:
        """``submit`` body; runs under the root span opened above."""
        kind = spec.kind or self.kind
        backend = self._backend_for(spec)
        plans: List[SearchResult] = []
        fresh: List[MaterializedModel] = []
        parts: List[MaterializedModel] = []
        n_tok = 0
        search_s = train_s = 0.0
        all_cached = True
        fallback_from: Optional[str] = None
        models = self._models(kind)
        fingerprint = PlanCache.fingerprint(models)
        train_device_ms = 0.0
        for sigma in spec.sigma:
            stale_left = 1
            while True:
                t0 = time.perf_counter()
                with obs.span("plan", "session", lo=sigma.lo, hi=sigma.hi):
                    res, was_cached = self._plan_component(
                        models, fingerprint, sigma, spec, kind, backend)
                    obs.set_attrs(cached=was_cached)
                search_s += time.perf_counter() - t0

                # training below may mutate the store (persisted gap
                # models), dropping earlier cache entries; this
                # component's entry is keyed on the snapshot
                # fingerprint its search actually saw, so it can never
                # be served for a different model set
                t1 = time.perf_counter()
                try:
                    c_parts, c_fresh, c_tok, samples = self.executor.gather(
                        res.ir, kind, persist=spec.persist, backend=backend)
                except StalePlanError:
                    # background compaction/eviction removed a planned
                    # model between search and fetch; the mutation
                    # already cleared the plan cache, so one re-plan
                    # over the current snapshot suffices
                    train_s += time.perf_counter() - t1
                    if not stale_left:
                        raise
                    stale_left -= 1
                    models = self._models(kind)
                    fingerprint = PlanCache.fingerprint(models)
                    continue
                except DeviceLostError:
                    # the backend is suspect, not the query: quarantine
                    # it and replay this component on the fallback
                    # chain.  Segments the failed attempt persisted
                    # remain capital and re-enter the re-plan as
                    # fetchable models; plans are backend-keyed, so the
                    # fallback's prices drive a fresh search.
                    train_s += time.perf_counter() - t1
                    nxt = self._fail_over(backend)
                    if nxt is None:
                        raise
                    if fallback_from is None:
                        fallback_from = backend.name
                    backend = nxt
                    models = self._models(kind)
                    fingerprint = PlanCache.fingerprint(models)
                    continue
                train_s += time.perf_counter() - t1
                break
            all_cached &= was_cached
            plans.append(res)
            parts.extend(c_parts)
            fresh.extend(c_fresh)
            n_tok += c_tok
            # device seconds come per-sample from the executor (nonzero
            # only when the backend kernel-routed that gap), so a
            # query's device attribution is *its own* — concurrent
            # sessions sharing the backend no longer leak their train
            # launches into this query's counter the way the old
            # stats-snapshot diff did
            for tok, secs, dev_s in samples:
                self.cost.observe_train(tok, secs, backend=backend.name)
                train_device_ms += dev_s * 1e3

        if not parts:
            raise ValueError(f"query {spec.sigma} selects no data")
        # the snapshot->merge->diff window is held against concurrent
        # sessions sharing this backend: their launches inside it
        # would corrupt this query's counters and the per-byte
        # calibration samples derived from them
        while True:
            try:
                with backend.measure_lock:
                    snap = backend.stats
                    t2 = time.perf_counter()
                    beta = self.executor.merge(parts, backend=backend)
                    merge_s = time.perf_counter() - t2
                    d = backend.stats.delta(snap)
                break
            except DeviceLostError:
                # parts are host-side models — the fallback backend can
                # merge them directly, no re-plan needed at this stage
                nxt = self._fail_over(backend)
                if nxt is None:
                    raise
                if fallback_from is None:
                    fallback_from = backend.name
                backend = nxt
        self._observe_merge(len(parts) - 1, merge_s, d,
                            backend=backend.name)
        return QueryReport(beta, spec, tuple(plans), n_tok, len(parts),
                           train_s, merge_s, search_s, materialized=fresh,
                           backend=backend.name,
                           merge_device_ms=d.merge_device_ms,
                           train_device_ms=train_device_ms,
                           cache_hits=d.cache_hits,
                           cache_misses=d.cache_misses,
                           cache_resident_bytes=d.cache_resident_bytes,
                           plan_cached=all_cached,
                           fallback_from=fallback_from)

    # ------------------------------------------------------------------
    def submit_many(self, specs: Sequence[QuerySpec], *,
                    next_keys: Optional[
                        Sequence[Callable[[], object]]] = None
                    ) -> BatchReport:
        """§V.C batch path: Alg. 4 plan combination, shared gap training.

        All specs must use one trainer kind (shared segments are merged
        into every covering query, so their Θ must be homogeneous) and
        one execution backend (the merge stage launches as one ragged
        segmented kernel).  The joint optimization runs
        under one α (it seeds every query's initial plan); a mixed-α
        batch is *auto-split* into per-α sub-batches — each planned and
        trained jointly on its own, reports re-interleaved into
        submission order (no gap sharing happens *across* α groups).
        Union predicates are supported: each component interval enters
        the joint optimization as its own range, and the owning query
        merges parts from all its components.

        A uniform-α batch consults the session plan cache first: the
        whole Alg. 4 result is memoized under the batch's spec
        fingerprints + store fingerprint, so a repeated identical batch
        over an unchanged store skips the joint search entirely
        (``BatchReport.plan_cached``).

        The batch is *reordered* for joint planning — Alg. 4 visits the
        widest query first so the shared-segment structure is anchored
        before narrow queries prune against it — but reports stay
        parallel to the submitted spec order.  ``spec.method`` is not
        consulted (Alg. 4 supersedes per-query search).

        ``next_keys`` (parallel to ``specs``) supplies a per-query RNG
        key callable; each shared gap segment is trained with the key
        stream of the first (lowest-index) query covering it.  The
        serving layer passes tenant streams here so a coalesced group
        reproduces per-tenant; ``None`` keeps this session's stream.

        The batch runs under one ``session.submit_many`` root span;
        the ``BatchReport`` (and any per-query report that does not
        already carry one) gets its ``trace`` id.
        """
        with self.tracer.span(
                "session.submit_many", "session",
                attrs={"batch": len(specs)}) as root:
            try:
                rep = self._submit_many_inner(list(specs), next_keys)
            except BaseException as exc:
                name = self.backend.name
                for s in specs:
                    self._emit_outcome(s.backend or name, None, exc)
                raise
        if root is not None:
            if rep.trace is None:
                rep.trace = root.trace_id
            for r in rep.reports:
                if r.trace is None:
                    r.trace = root.trace_id
        for r in rep.reports:
            self._emit_outcome(r.backend, r.fallback_from, None)
        return rep

    def _submit_many_inner(self, specs: List[QuerySpec],
                           next_keys: Optional[
                               Sequence[Callable[[], object]]] = None
                           ) -> BatchReport:
        """``submit_many`` body (also the α-split recursion target, so
        sub-batches do not re-open root spans or re-fire outcomes)."""
        if next_keys is not None and len(next_keys) != len(specs):
            raise ValueError(
                f"next_keys must parallel specs: got {len(next_keys)} "
                f"keys for {len(specs)} specs")
        if not specs:
            return BatchReport([], self.planner.plan_batch([], []), 0.0, 0.0)
        alphas = {s.alpha for s in specs}
        if len(alphas) != 1:
            return self._submit_many_split(specs, next_keys)
        alpha = alphas.pop()
        kinds = {s.kind or self.kind for s in specs}
        if len(kinds) != 1:
            raise ValueError(f"submit_many requires one backend kind per "
                             f"batch, got {sorted(kinds)}")
        kind = kinds.pop()
        backends = {self._backend_for(s) for s in specs}
        if len(backends) != 1:
            raise ValueError(
                f"submit_many requires one execution backend per batch, "
                f"got {sorted(b.name for b in backends)}")
        backend = backends.pop()

        # flatten union predicates: one planning range per component
        owner: List[int] = []
        sigmas: List[Interval] = []
        for i, s in enumerate(specs):
            for sigma in s.sigma:
                owner.append(i)
                sigmas.append(sigma)

        # like single-spec submit, the batch path retries StalePlanError
        # once: background compaction/eviction can remove a planned
        # model between the joint search and the assembly fetch, and
        # the mutation already cleared the plan cache — so one in-place
        # re-plan over the current snapshot answers the batch without
        # surfacing the transient to callers (the serving layer's
        # serial fallback stays reserved for real per-spec failures).
        # Device loss mid-batch quarantines the backend and replays the
        # whole batch on the fallback chain.  In both cases, segments
        # the failed attempt persisted remain as capital and enter the
        # re-plan as fetchable models.
        stale_left = 1
        fallback_from: Optional[str] = None
        while True:
            try:
                rep = self._submit_many_once(specs, sigmas, owner, alpha,
                                             kind, backend, next_keys)
            except StalePlanError:
                if not stale_left:
                    raise
                stale_left -= 1
                continue
            except DeviceLostError:
                nxt = self._fail_over(backend)
                if nxt is None:
                    raise
                if fallback_from is None:
                    fallback_from = backend.name
                backend = nxt
                continue
            if fallback_from is not None:
                rep.fallback_from = fallback_from
                for r in rep.reports:
                    r.fallback_from = fallback_from
            return rep

    def _submit_many_once(self, specs: List[QuerySpec],
                          sigmas: List[Interval], owner: List[int],
                          alpha: float, kind: str,
                          backend: ExecutionBackend,
                          next_keys: Optional[
                              Sequence[Callable[[], object]]]
                          ) -> BatchReport:
        """One attempt of the Alg. 4 batch path (see ``submit_many``)."""
        # batch-level plan cache: repeated identical batches over an
        # unchanged store (same specs, prices, residency) skip Alg. 4
        models = self._models(kind)
        bkey = ("batch",
                tuple((s.lo, s.hi) for s in sigmas), tuple(owner),
                alpha, kind, backend.name, PlanCache.fingerprint(models),
                self.cost, getattr(self.cost, "version", 0),
                self._cache_epoch(backend), self._data_epoch)
        t0 = time.perf_counter()
        with obs.span("plan", "session", batch=len(specs),
                      components=len(sigmas)):
            opt = self._plan_cache.get(bkey)
            batch_cached = opt is not None
            if opt is None:
                self.cost.set_train_backend(backend.name)
                opt = self.planner.plan_batch(models, sigmas, alpha)
                self._plan_cache.put(bkey, opt)
            obs.set_attrs(cached=batch_cached)
        shared_search_s = time.perf_counter() - t0

        # train every atomic shared gap segment exactly once (gap
        # structure read off the lowered Plan IR)
        gap_lists = [[g.gap for g in ir.gaps] for ir in opt.irs]
        seg_models = {}
        # per-segment wall time counts as device time iff this backend
        # routes the kind through a kernel — attribution stays with
        # *this batch's* segments even when other sessions share the
        # backend concurrently
        kernel_route = backend.kernel_route(kind)
        train_device_ms = 0.0
        t1 = time.perf_counter()
        for lo, hi, _ in _segments(gap_lists):
            covering = sorted({
                owner[j] for j, gaps in enumerate(gap_lists)
                if any(g.lo <= lo and hi <= g.hi for g in gaps)})
            persist = any(specs[i].persist for i in covering)
            # a shared segment is trained once, on the *first* covering
            # query's stream — deterministic in submission order, so
            # callers that pre-sort (the serving layer sorts by tenant)
            # get reproducible per-tenant results
            key_fn = next_keys[covering[0]] \
                if next_keys is not None and covering else None
            t_gap = time.perf_counter()
            m = self.executor.train_gap(lo, hi, kind, persist=persist,
                                        backend=backend, next_key=key_fn)
            if m is not None:
                dt = time.perf_counter() - t_gap
                seg_models[(lo, hi)] = m
                self.cost.observe_train(m.n_tokens, dt,
                                        backend=backend.name)
                if kernel_route:
                    train_device_ms += dt * 1e3
        shared_train_s = time.perf_counter() - t1

        # assemble every query's part list from its components' IR
        # (fetches resolved by id), then merge the whole batch through
        # one backend call — a single ragged segmented device launch
        part_lists: List[List[MaterializedModel]] = []
        plans_per_q: List[List[SearchResult]] = []
        ntok_per_q: List[int] = []
        gather_s: List[float] = []
        for i, spec in enumerate(specs):
            t2 = time.perf_counter()
            parts: List[MaterializedModel] = []
            plans: List[SearchResult] = []
            n_tok = 0
            for j, (own, ir) in enumerate(zip(owner, opt.irs)):
                if own != i:
                    continue
                plans.append(SearchResult(opt.plans[j], 0.0, alpha,
                                          method="ALG4", ir=ir))
                try:
                    parts.extend(self.store.get(f.model_id)
                                 for f in ir.fetches)
                except KeyError as exc:
                    # a planned model vanished between search and
                    # assembly (background compaction/eviction) — typed
                    # so submit_many's retry loop re-plans in place
                    raise StalePlanError(
                        f"model {exc.args[0]!r} vanished between batch "
                        f"planning and assembly") from exc
                for (lo, hi), m in seg_models.items():
                    if any(g.lo <= lo and hi <= g.hi
                           for g in gap_lists[j]):
                        parts.append(m)
                        n_tok += m.n_tokens
            if not parts:
                raise ValueError(f"query {spec.sigma} selects no data")
            part_lists.append(parts)
            plans_per_q.append(plans)
            ntok_per_q.append(n_tok)
            gather_s.append(time.perf_counter() - t2)

        with backend.measure_lock:
            snap = backend.stats
            t3 = time.perf_counter()
            betas = self.executor.merge_many(part_lists, backend=backend)
            batch_merge_s = time.perf_counter() - t3
            d = backend.stats.delta(snap)
        launch_share = batch_merge_s / len(specs)
        self._observe_merge(sum(max(len(p) - 1, 0) for p in part_lists),
                            batch_merge_s, d, backend=backend.name)

        reports = [
            QueryReport(beta, spec, tuple(plans), n_tok, len(parts),
                        0.0, gather + launch_share, 0.0,
                        backend=backend.name)
            for beta, spec, plans, n_tok, parts, gather in zip(
                betas, specs, plans_per_q, ntok_per_q, part_lists, gather_s)]
        return BatchReport(reports, opt, shared_search_s, shared_train_s,
                           materialized=list(seg_models.values()),
                           backend=backend.name,
                           merge_device_ms=d.merge_device_ms,
                           train_device_ms=train_device_ms,
                           cache_hits=d.cache_hits,
                           cache_misses=d.cache_misses,
                           cache_resident_bytes=d.cache_resident_bytes,
                           pad_rows=d.pad_rows,
                           plan_cached=batch_cached)

    def _submit_many_split(self, specs: List[QuerySpec],
                           next_keys: Optional[
                               Sequence[Callable[[], object]]] = None
                           ) -> BatchReport:
        """Mixed-α batch: one Alg. 4 sub-batch per α, reports stitched
        back into submission order.  Gap segments are shared *within*
        each α group only — queries under different α chose their
        plans under different accuracy/latency preferences, so their
        joint pruning is not comparable."""
        # kind/backend uniformity is a *batch-wide* contract — validate
        # before splitting so a mixed batch fails the same way whether
        # or not its α values happen to coincide
        kinds = {s.kind or self.kind for s in specs}
        if len(kinds) != 1:
            raise ValueError(f"submit_many requires one backend kind per "
                             f"batch, got {sorted(kinds)}")
        if len({self._backend_for(s) for s in specs}) != 1:
            raise ValueError(
                "submit_many requires one execution backend per batch")
        groups: "dict[float, List[int]]" = {}
        for i, s in enumerate(specs):
            groups.setdefault(s.alpha, []).append(i)
        reports: List[Optional[QueryReport]] = [None] * len(specs)
        subs: List[BatchReport] = []
        for idxs in groups.values():
            sub = self._submit_many_inner(
                [specs[i] for i in idxs],
                next_keys=[next_keys[i] for i in idxs]
                if next_keys is not None else None)
            subs.append(sub)
            for i, rep in zip(idxs, sub.reports):
                reports[i] = rep
        opt = BatchResult(
            plans=[], total_time=sum(s.opt.total_time for s in subs),
            naive_time=sum(s.opt.naive_time for s in subs),
            benefit=sum(s.opt.benefit for s in subs),
            n_scored=sum(s.opt.n_scored for s in subs),
            elapsed_s=sum(s.opt.elapsed_s for s in subs),
            method="ALG4/alpha-split")
        return BatchReport(
            reports, opt,
            shared_search_s=sum(s.shared_search_s for s in subs),
            shared_train_s=sum(s.shared_train_s for s in subs),
            materialized=[m for s in subs for m in s.materialized],
            backend=subs[0].backend,
            merge_device_ms=sum(s.merge_device_ms for s in subs),
            train_device_ms=sum(s.train_device_ms for s in subs),
            cache_hits=sum(s.cache_hits for s in subs),
            cache_misses=sum(s.cache_misses for s in subs),
            cache_resident_bytes=subs[-1].cache_resident_bytes,
            pad_rows=sum(s.pad_rows for s in subs),
            plan_cached=all(s.plan_cached for s in subs),
            fallback_from=next(
                (s.fallback_from for s in subs
                 if s.fallback_from is not None), None))
