"""``MLegoSession`` — the canonical entry point to MLego.

The session owns the Def. 1 members that are *not* per-query: the
dataset D (corpus + range index), the analysis function F (LDAConfig +
default trainer kind), the materialized-model store, the plan cost
model, the RNG state, and the execution backend.  Queries arrive as
typed ``QuerySpec``s through a single ``submit`` path:

    session = MLegoSession(corpus, cfg)
    report  = session.submit(QuerySpec(sigma=Interval(0, 500), alpha=0.5))
    batch   = session.submit_many([spec1, spec2, spec3])

``submit`` runs the Fig. 2 pipeline per predicate component (plan
search -> gap training -> merge); union-of-intervals predicates are
planned per component and merged into one model.  ``submit_many`` runs
the §V.C Alg. 4 batch path: one joint plan combination, every shared
gap segment trained exactly once, and the shared search/train costs
reported at the batch level (``BatchReport``), not on the first query.

The data plane (merge + gap training) executes on a pluggable backend:
``backend="host"`` (default) is the NumPy reference; ``"device"``
keeps hot model parameters device-resident and merges through the
fused Pallas kernel — including one batched launch for the whole
``submit_many`` merge stage.  A ``QuerySpec.backend`` overrides the
session default per query.
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence, Union

import jax

from repro.api.backend import ExecutionBackend, make_backend
from repro.api.executor import Executor
from repro.api.planner import Planner
from repro.api.reports import BatchReport, QueryReport
from repro.api.spec import QuerySpec
from repro.api.trainers import resolve_kind
from repro.configs.lda_default import LDAConfig
from repro.core.batch_opt import _gaps, _segments
from repro.core.cost import CostModel
from repro.core.lda import MaterializedModel
from repro.core.plans import Interval
from repro.core.search import SearchResult
from repro.core.store import ModelStore
from repro.data.corpus import Corpus, DataIndex


class MLegoSession:
    """One corpus + one model store + one RNG stream; many queries."""

    def __init__(self, corpus: Corpus, cfg: LDAConfig, *,
                 store: Optional[ModelStore] = None,
                 cost: Optional[CostModel] = None,
                 kind: str = "vb", seed: int = 0,
                 backend: Union[str, ExecutionBackend] = "host"):
        self.corpus = corpus
        self.index = DataIndex(corpus)
        self._backends = {}
        self.store = store if store is not None else ModelStore()
        self.cfg = cfg
        self.cost = cost or CostModel(max_iters=cfg.max_iters,
                                      n_topics=cfg.n_topics)
        self.kind = resolve_kind(kind)       # default backend for train_range
        self._key = jax.random.PRNGKey(seed)
        self.planner = Planner(self.index, self.cost)
        self.executor = Executor(corpus, cfg, self.store, self._next_key)
        self.backend = self._register_backend(
            make_backend(backend) if isinstance(backend, str) else backend)

    # ------------------------------------------------------------------
    @property
    def store(self) -> ModelStore:
        return self._store

    @store.setter
    def store(self, v: ModelStore) -> None:
        # swapping the store (the legacy-shim path) must re-home every
        # backend cache — stale subscriptions would miss invalidations
        self._store = v
        for b in self._backends.values():
            b.bind_store(v)
        if hasattr(self, "executor"):       # unset during __init__
            self.executor.store = v

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def _register_backend(self, inst: ExecutionBackend) -> ExecutionBackend:
        bound = inst.bound_store
        if bound is not None and bound is not self.store:
            raise ValueError(
                "execution backend is already bound to another session's "
                "store; its device cache is keyed by model id and ids "
                "collide across stores — create one backend per session")
        inst.bind_store(self.store)
        self._backends[inst.name] = inst
        return inst

    def _backend_for(self, spec: QuerySpec) -> ExecutionBackend:
        """Spec's backend (session default when unset), one instance per
        name so device caches survive across queries."""
        if spec.backend is None:
            return self.backend
        if spec.backend not in self._backends:
            self._register_backend(make_backend(spec.backend))
        return self._backends[spec.backend]

    def _models(self, kind: str) -> List[MaterializedModel]:
        """Store models of ``kind``, matching alias tags too — stores
        persisted by the legacy engine may carry e.g. "gibbs" verbatim."""
        out = []
        for m in self.store.models():
            try:
                mk = resolve_kind(m.kind)
            except ValueError:
                mk = m.kind
            if mk == kind:
                out.append(m)
        return out

    def train_range(self, lo: float, hi: float,
                    kind: Optional[str] = None) -> Optional[MaterializedModel]:
        """Materialize one model on [lo, hi) (offline capital building)."""
        return self.executor.train_gap(lo, hi, kind or self.kind,
                                       persist=True, backend=self.backend)

    # ------------------------------------------------------------------
    def submit(self, spec: QuerySpec) -> QueryReport:
        """One analytic query: plan search, gap training, merge.

        ``spec.kind=None`` (the default) uses the session's kind;
        ``spec.backend=None`` the session's execution backend.
        """
        kind = spec.kind or self.kind
        backend = self._backend_for(spec)
        plans: List[SearchResult] = []
        fresh: List[MaterializedModel] = []
        parts: List[MaterializedModel] = []
        n_tok = 0
        search_s = train_s = 0.0
        models = self._models(kind)
        for sigma in spec.sigma:
            t0 = time.perf_counter()
            res = self.planner.plan(models, sigma, spec.alpha, spec.method)
            search_s += time.perf_counter() - t0
            plans.append(res)
            parts.extend(res.plan)

            t1 = time.perf_counter()
            for gap in self.planner.gaps(sigma, res.plan):
                m = self.executor.train_gap(gap.lo, gap.hi, kind,
                                            persist=spec.persist,
                                            backend=backend)
                if m is not None:
                    fresh.append(m)
                    n_tok += m.n_tokens
            train_s += time.perf_counter() - t1

        parts += fresh
        if not parts:
            raise ValueError(f"query {spec.sigma} selects no data")
        snap = backend.stats
        t2 = time.perf_counter()
        beta = self.executor.merge(parts, backend=backend)
        merge_s = time.perf_counter() - t2
        d = backend.stats.delta(snap)
        return QueryReport(beta, spec, tuple(plans), n_tok, len(parts),
                           train_s, merge_s, search_s, materialized=fresh,
                           backend=backend.name,
                           merge_device_ms=d.merge_device_ms,
                           cache_hits=d.cache_hits,
                           cache_misses=d.cache_misses)

    # ------------------------------------------------------------------
    def submit_many(self, specs: Sequence[QuerySpec]) -> BatchReport:
        """§V.C batch path: Alg. 4 plan combination, shared gap training.

        All specs must use one trainer kind (shared segments are merged
        into every covering query, so their Θ must be homogeneous) and
        one execution backend (the merge stage is a single batched
        launch).  Union predicates are supported: each component
        interval enters the joint optimization as its own range, and
        the owning query merges parts from all its components.

        Alg. 4 plans the whole batch jointly in the time-cost (α = 0)
        regime and supersedes per-query plan search, so specs with
        α > 0 are rejected (submit them individually instead) and
        ``spec.method`` is not consulted.
        """
        specs = list(specs)
        if not specs:
            return BatchReport([], self.planner.plan_batch([], []), 0.0, 0.0)
        for s in specs:
            if s.alpha != 0.0:
                raise ValueError(
                    f"batch planning (Alg. 4) is the alpha=0 regime; got "
                    f"alpha={s.alpha} for {s.sigma} — submit accuracy-"
                    f"weighted queries individually via submit()")
        kinds = {s.kind or self.kind for s in specs}
        if len(kinds) != 1:
            raise ValueError(f"submit_many requires one backend kind per "
                             f"batch, got {sorted(kinds)}")
        kind = kinds.pop()
        backends = {self._backend_for(s) for s in specs}
        if len(backends) != 1:
            raise ValueError(
                f"submit_many requires one execution backend per batch, "
                f"got {sorted(b.name for b in backends)}")
        backend = backends.pop()

        # flatten union predicates: one planning range per component
        owner: List[int] = []
        sigmas: List[Interval] = []
        for i, s in enumerate(specs):
            for sigma in s.sigma:
                owner.append(i)
                sigmas.append(sigma)

        t0 = time.perf_counter()
        opt = self.planner.plan_batch(self._models(kind), sigmas)
        shared_search_s = time.perf_counter() - t0

        # train every atomic shared gap segment exactly once
        gap_lists = [_gaps(p, q) for p, q in zip(opt.plans, sigmas)]
        seg_models = {}
        t1 = time.perf_counter()
        for lo, hi, _ in _segments(gap_lists):
            persist = any(
                specs[owner[j]].persist
                for j, gaps in enumerate(gap_lists)
                if any(g.lo <= lo and hi <= g.hi for g in gaps))
            m = self.executor.train_gap(lo, hi, kind, persist=persist,
                                        backend=backend)
            if m is not None:
                seg_models[(lo, hi)] = m
        shared_train_s = time.perf_counter() - t1

        # assemble every query's part list, then merge the whole batch
        # through one backend call (a single padded device launch)
        part_lists: List[List[MaterializedModel]] = []
        plans_per_q: List[List[SearchResult]] = []
        ntok_per_q: List[int] = []
        gather_s: List[float] = []
        for i, spec in enumerate(specs):
            t2 = time.perf_counter()
            parts: List[MaterializedModel] = []
            plans: List[SearchResult] = []
            n_tok = 0
            for j, (own, gaps) in enumerate(zip(owner, gap_lists)):
                if own != i:
                    continue
                plans.append(SearchResult(opt.plans[j], 0.0, 0.0,
                                          method="ALG4"))
                parts.extend(opt.plans[j])
                for (lo, hi), m in seg_models.items():
                    if any(g.lo <= lo and hi <= g.hi for g in gaps):
                        parts.append(m)
                        n_tok += m.n_tokens
            if not parts:
                raise ValueError(f"query {spec.sigma} selects no data")
            part_lists.append(parts)
            plans_per_q.append(plans)
            ntok_per_q.append(n_tok)
            gather_s.append(time.perf_counter() - t2)

        snap = backend.stats
        t3 = time.perf_counter()
        betas = self.executor.merge_many(part_lists, backend=backend)
        launch_share = (time.perf_counter() - t3) / len(specs)
        d = backend.stats.delta(snap)

        reports = [
            QueryReport(beta, spec, tuple(plans), n_tok, len(parts),
                        0.0, gather + launch_share, 0.0,
                        backend=backend.name)
            for beta, spec, plans, n_tok, parts, gather in zip(
                betas, specs, plans_per_q, ntok_per_q, part_lists, gather_s)]
        return BatchReport(reports, opt, shared_search_s, shared_train_s,
                           materialized=list(seg_models.values()),
                           backend=backend.name,
                           merge_device_ms=d.merge_device_ms,
                           cache_hits=d.cache_hits,
                           cache_misses=d.cache_misses)
