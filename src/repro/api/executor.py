"""Executor internals — the Fig. 2 "gap training" + "merge" stages.

One trainer body for every model kind (via the trainer registry) and
one materialization switch, replacing the seed repo's four copy-pasted
``train_range`` / ``_train_volatile`` bodies.  ``persist=True`` adds
the fresh model to the store (the reuse-capital flywheel);
``persist=False`` returns an unregistered model (id −1) and leaves the
store untouched.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

from repro.api.trainers import get_merge, get_trainer, resolve_kind
from repro.configs.lda_default import LDAConfig
from repro.core.lda import MaterializedModel
from repro.core.plans import Interval
from repro.core.store import ModelStore
from repro.data.corpus import Corpus


class Executor:
    def __init__(self, corpus: Corpus, cfg: LDAConfig, store: ModelStore,
                 next_key: Callable[[], object]):
        self.corpus = corpus
        self.cfg = cfg
        self.store = store
        self._next_key = next_key

    def train_gap(self, lo: float, hi: float, kind: str,
                  *, persist: bool = True) -> Optional[MaterializedModel]:
        """Train one fresh model on [lo, hi); None if the range is empty."""
        d0, d1 = self.corpus.doc_slice(lo, hi)
        if d1 <= d0:
            return None
        kind = resolve_kind(kind)
        sub = self.corpus.subset(lo, hi)
        theta = get_trainer(kind)(sub, self.cfg, self._next_key())
        if persist:
            return self.store.add(Interval(lo, hi), sub.n_docs, sub.n_tokens,
                                  kind, theta)
        return MaterializedModel(-1, Interval(lo, hi), sub.n_docs,
                                 sub.n_tokens, kind, theta)

    def merge(self, parts: Sequence[MaterializedModel]) -> np.ndarray:
        """Merge a homogeneous part list -> β (K, V), dispatching to the
        kind's registered merge family (Alg. 1 for vb, Alg. 2 for gs).
        Kinds are compared after alias resolution, so legacy stores
        tagged "gibbs" merge with fresh "gs" models."""
        if not parts:
            raise ValueError("nothing to merge")
        kinds = {resolve_kind(m.kind) for m in parts}
        if len(kinds) != 1:
            raise ValueError(f"cannot merge mixed kinds {kinds}")
        return get_merge(kinds.pop())(list(parts), self.cfg)
