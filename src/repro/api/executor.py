"""Executor internals — the Fig. 2 "gap training" + "merge" stages.

One trainer body for every model kind (via the trainer registry) and
one materialization switch, replacing the seed repo's four copy-pasted
``train_range`` / ``_train_volatile`` bodies.  ``persist=True`` adds
the fresh model to the store (the reuse-capital flywheel);
``persist=False`` returns an unregistered model (id −1) and leaves the
store untouched.

Both stages execute through a pluggable ``ExecutionBackend``
(``repro.api.backend``): the host backend preserves the seed's NumPy
semantics; the device backend runs merges as fused Pallas launches
over a device-resident model cache and routes gap training through
the kernel paths (fused VB E-step; doc-blocked Gibbs sweep).  A
persisted gap model is handed back to the backend (``note_trained``)
so device backends can warm their cache with it before the merge that
follows.  ``backend=None`` falls back to host semantics so direct
callers (tests, schedulers) need no wiring.  ``gather`` returns one
measured ``(tokens, seconds, device_seconds)`` sample per trained gap —
the session feeds these to the cost provider keyed by the backend that
ran them, which is how host and device κ are calibrated separately,
and sums the device component into the *per-query*
``train_device_ms`` (attribution by the query's own wall clock, not a
shared counter diff, so concurrent sessions on one backend can't
claim each other's kernel time).

Every stage emits spans through the ambient tracing context
(``repro.obs.trace``): ``fetch`` around the store reads, ``train``
per gap, ``merge`` around the backend merge.  With no enclosing span
(bare executor use) these are no-ops.

The executor consumes the planner's **Plan IR** (``repro.core.plan_ir``):
``gather`` walks a ``Plan``'s ``FetchStep``/``TrainGapStep`` sequence —
resolving fetched model ids against the store and training each gap —
and returns the homogeneous part list the ``MergeStep`` combines,
plus per-gap (tokens, seconds) training observations for cost-provider
calibration.
"""
from __future__ import annotations

import inspect
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.api.backend import ExecutionBackend, HostBackend
from repro.api.trainers import get_trainer, merge_family_name, resolve_kind
from repro.configs.lda_default import LDAConfig
from repro.core.errors import (DeviceLostError, RetryPolicy,
                               TransientExecutionError)
from repro.core.lda import MaterializedModel
from repro.core.plan_ir import Plan
from repro.core.plans import Interval
from repro.core.store import ModelStore
from repro.data.corpus import Corpus
from repro.obs import trace as obs
from repro.testing.faults import maybe_fail


class StalePlanError(KeyError, TransientExecutionError):
    """A plan's fetched model vanished from the store between planning
    and execution — background compaction/eviction (``repro.ingest``)
    removed it mid-query.  The store mutation already invalidated the
    plan cache, so a re-plan over the current model set succeeds;
    ``MLegoSession.submit`` re-plans on this (transient in the
    taxonomy, but a blind same-plan retry can never succeed — callers
    must re-plan, so the executor's own retry loop excludes it)."""


def _resolves_to(tag: str, kind: str) -> bool:
    """Store tags may be aliases ("gibbs") or foreign kinds entirely."""
    try:
        return resolve_kind(tag) == kind
    except ValueError:
        return tag == kind


def _accepts_global_nkv(trainer) -> bool:
    """Trainer registry signatures are (corpus, cfg, key); the DSGS
    prior reaches only trainers that declare the keyword (built-in gs
    and the device blocked route) — custom trainers keep the seed
    contract untouched."""
    try:
        return "global_nkv" in inspect.signature(trainer).parameters
    except (TypeError, ValueError):
        return False


def _parts_kind(parts: Sequence[MaterializedModel]) -> str:
    """Single canonical kind of a homogeneous part list (validated).

    Kinds are compared after alias resolution, so legacy stores tagged
    "gibbs" merge with fresh "gs" models."""
    if not parts:
        raise ValueError("nothing to merge")
    kinds = {resolve_kind(m.kind) for m in parts}
    if len(kinds) != 1:
        raise ValueError(f"cannot merge mixed kinds {kinds}")
    return kinds.pop()


class Executor:
    def __init__(self, corpus: Corpus, cfg: LDAConfig, store: ModelStore,
                 next_key: Callable[[], object],
                 retry: Optional[RetryPolicy] = None):
        self.corpus = corpus
        self.cfg = cfg
        # (kind, frozenset(model ids), summed ΔN_kv) — see _gs_prior.
        # Keyed by id set, which is unambiguous only within one store
        # (ids are never reused there) — so a store swap must drop it.
        self._gs_prior_memo = None
        self.store = store
        self._next_key = next_key
        self._host = HostBackend()
        # One policy object for every data-plane call this executor
        # makes (fetch, train, merge); the session/service surface its
        # per-site counters in reports.
        self.retry = retry if retry is not None else RetryPolicy()

    @property
    def store(self) -> ModelStore:
        return self._store

    @store.setter
    def store(self, v: ModelStore) -> None:
        self._store = v
        self._gs_prior_memo = None

    def train_gap(self, lo: float, hi: float, kind: str,
                  *, persist: bool = True,
                  backend: Optional[ExecutionBackend] = None,
                  next_key: Optional[Callable[[], object]] = None
                  ) -> Optional[MaterializedModel]:
        """Train one fresh model on [lo, hi); None if the range is empty.

        For Gibbs-family kinds the store's merged counts ride along as
        the DSGS ``global_nkv`` prior (Eq. 8): the gap samples against
        the reuse capital's topic structure instead of the zero prior
        the seed used, so fresh gap topics align with the models they
        are about to be merged with.  (The trained model still carries
        only its *own* token counts — the prior shapes the conditional,
        it is never added to ΔN_kv — so merges don't double count.)

        ``next_key`` overrides the executor's key supplier for this one
        training call — the serving layer passes the *owning tenant's*
        stream when it trains shared segments of a coalesced group, so
        a tenant's results don't depend on which neighbors it fused
        with.
        """
        d0, d1 = self.corpus.doc_slice(lo, hi)
        if d1 <= d0:
            return None
        kind = resolve_kind(kind)
        sub = self.corpus.subset(lo, hi)
        trainer = backend.trainer(kind) if backend is not None \
            else get_trainer(kind)
        kwargs = {}
        if merge_family_name(kind) == "gs" and _accepts_global_nkv(trainer):
            prior = self._gs_prior(kind)
            if prior is not None:
                kwargs["global_nkv"] = prior
        key = (next_key or self._next_key)()
        site = "backend.train_gap." + (backend.name if backend is not None
                                       else "host")

        def _train():
            maybe_fail(site)
            return trainer(sub, self.cfg, key, **kwargs)

        # Device loss is excluded: a blind retry would hit the same
        # dead device — the session replays on the fallback chain.
        with obs.span("train", "exec", lo=lo, hi=hi, kind=kind,
                      backend=(backend.name if backend else "host"),
                      tokens=sub.n_tokens):
            theta = self.retry.run(_train, site=site,
                                   no_retry=(DeviceLostError,))
        if persist:
            m = self.store.add(Interval(lo, hi), sub.n_docs, sub.n_tokens,
                               kind, theta)
            if backend is not None:
                # warm the backend's device cache with the fresh model —
                # the merge right after this will read it back
                backend.note_trained(m)
            return m
        return MaterializedModel(-1, Interval(lo, hi), sub.n_docs,
                                 sub.n_tokens, kind, theta)

    def _gs_prior(self, kind: str) -> Optional[np.ndarray]:
        """Σ ΔN_kv over the store's models of ``kind`` — the global
        topic-word counts a DSGS step conditions on.  None when the
        store holds no usable counts (cold store: zero prior, exactly
        the seed behavior).

        Memoized on the eligible model-id set: a submit_many segment
        loop persists one gap per segment, so the common transition is
        "same set plus a few fresh ids" — extended incrementally with
        just the new deltas instead of re-summing the whole store's
        (K, V) arrays per trained gap."""
        eligible = {
            m.model_id: m for m in self.store.models()
            if "delta_nkv" in m.theta and _resolves_to(m.kind, kind)
            and m.theta["delta_nkv"].shape == (self.cfg.n_topics,
                                               self.cfg.vocab_size)}
        if not eligible:
            self._gs_prior_memo = None
            return None
        ids = frozenset(eligible)
        memo = self._gs_prior_memo
        if memo is not None and memo[0] == kind:
            _, mids, mval = memo
            if mids == ids:
                return mval
            if mids < ids:
                val = mval + np.sum(
                    [np.asarray(eligible[i].theta["delta_nkv"], np.float32)
                     for i in ids - mids], axis=0, dtype=np.float32)
                self._gs_prior_memo = (kind, ids, val)
                return val
        val = np.sum(
            [np.asarray(m.theta["delta_nkv"], np.float32)
             for m in eligible.values()], axis=0, dtype=np.float32)
        self._gs_prior_memo = (kind, ids, val)
        return val

    def gather(self, plan: Plan, kind: str, *, persist: bool = True,
               backend: Optional[ExecutionBackend] = None
               ) -> Tuple[List[MaterializedModel],
                          List[MaterializedModel],
                          int, List[Tuple[int, float, float]]]:
        """Consume one Plan IR's fetch + train-gap steps.

        Returns ``(parts, fresh, n_trained_tokens, train_obs)``:
        ``parts`` is everything the plan's merge step will combine —
        fetched store models (resolved by id) followed by freshly
        trained gap models — ``fresh`` the trained subset, and
        ``train_obs`` one measured ``(tokens, seconds,
        device_seconds)`` sample per trained gap: ``seconds`` is the
        κ input for the calibrated cost provider, ``device_seconds``
        equals it when the backend routed this kind through a device
        kernel (``backend.kernel_route``) and is 0.0 on host routes —
        the per-query ``train_device_ms`` attribution.
        """
        def _fetch_parts() -> List[MaterializedModel]:
            try:
                return [self.store.get(f.model_id) for f in plan.fetches]
            except StalePlanError:
                raise
            except KeyError as exc:
                raise StalePlanError(
                    f"planned model {exc.args[0]!r} was removed from the "
                    f"store (background compaction/eviction?)") from exc

        # store.get faults (injected or real I/O hiccups) retry in
        # place; a StalePlanError propagates — only a re-plan helps.
        with obs.span("fetch", "exec", n_fetches=len(plan.fetches)):
            parts = self.retry.run(_fetch_parts, site="store.get",
                                   no_retry=(StalePlanError,))
            obs.set_attrs(bytes=sum(p.nbytes() for p in parts))
        kernel_route = backend is not None and backend.kernel_route(kind)
        fresh: List[MaterializedModel] = []
        n_tok = 0
        samples: List[Tuple[int, float, float]] = []
        for g in plan.gaps:
            t0 = time.perf_counter()
            m = self.train_gap(g.gap.lo, g.gap.hi, kind,
                               persist=persist, backend=backend)
            if m is not None:
                dt = time.perf_counter() - t0
                fresh.append(m)
                parts.append(m)
                n_tok += m.n_tokens
                samples.append((m.n_tokens, dt, dt if kernel_route else 0.0))
        return parts, fresh, n_tok, samples

    def merge(self, parts: Sequence[MaterializedModel],
              backend: Optional[ExecutionBackend] = None) -> np.ndarray:
        """Merge a homogeneous part list -> β (K, V), dispatching to the
        kind's merge family (Alg. 1 for vb, Alg. 2 for gs) on the given
        execution backend (host semantics when None)."""
        kind = _parts_kind(parts)
        b = backend or self._host
        with obs.span("merge", "exec", n_parts=len(parts), kind=kind,
                      backend=b.name):
            return self.retry.run(
                lambda: b.merge(list(parts), kind, self.cfg),
                site=f"backend.merge.{b.name}", no_retry=(DeviceLostError,))

    def merge_many(self, part_lists: Sequence[Sequence[MaterializedModel]],
                   backend: Optional[ExecutionBackend] = None
                   ) -> List[np.ndarray]:
        """Merge several plans at once (the submit_many hot path).

        All lists must share one kind; the device backend turns the
        whole batch into a single padded kernel launch."""
        kinds = {_parts_kind(p) for p in part_lists}
        if len(kinds) != 1:
            raise ValueError(f"cannot batch-merge mixed kinds {kinds}")
        kind = kinds.pop()
        b = backend or self._host
        with obs.span("merge", "exec", n_plans=len(part_lists),
                      n_parts=sum(len(p) for p in part_lists), kind=kind,
                      backend=b.name):
            return self.retry.run(
                lambda: b.merge_many([list(p) for p in part_lists], kind,
                                     self.cfg),
                site=f"backend.merge.{b.name}", no_retry=(DeviceLostError,))
