"""Planner internals — the Fig. 2 "plan search" stage behind the session.

Thin, stateful-only-in-inputs wrapper over the §V.B searchers and the
§V.C Alg. 4 batch optimizer, so the session (and any future scheduler)
talks to one object instead of reaching into ``repro.core.search`` /
``repro.core.batch_opt`` directly.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.core.batch_opt import BatchResult, batch_optimize
from repro.core.cost import CostModel
from repro.core.plans import Interval, subtract
from repro.core.search import SEARCHERS, SearchResult


class Planner:
    def __init__(self, index, cost: CostModel):
        self.index = index
        self.cost = cost

    def plan(self, models: Sequence, sigma: Interval, alpha: float,
             method: str = "psoa++") -> SearchResult:
        """Best plan for one interval (Def. 2 score-based search)."""
        try:
            searcher = SEARCHERS[method]
        except KeyError:
            raise ValueError(f"unknown plan-search method {method!r}; "
                             f"one of {sorted(SEARCHERS)}") from None
        return searcher(models, sigma, self.index, self.cost, alpha)

    def plan_batch(self, models: Sequence,
                   sigmas: Sequence[Interval]) -> BatchResult:
        """Alg. 4 joint plan combination for a batch of intervals."""
        return batch_optimize(models, list(sigmas), self.index, self.cost)

    @staticmethod
    def gaps(sigma: Interval, plan: Sequence) -> List[Interval]:
        """Uncovered ranges of ``sigma`` under ``plan`` (to be trained)."""
        return subtract(sigma, [m.o for m in plan])
