"""Planner internals — the Fig. 2 "plan search" stage behind the session.

Thin, stateful-only-in-inputs wrapper over the §V.B searchers and the
§V.C Alg. 4 batch optimizer, so the session (and any future scheduler)
talks to one object instead of reaching into ``repro.core.search`` /
``repro.core.batch_opt`` directly.  Both paths return results carrying
the lowered **Plan IR** the executor consumes.

``PlanCache`` is the session-level memo over ``Planner.plan``:
interactive exploration replays near-identical queries (pan/zoom over
σ, re-render after a UI tweak), and for those the search is pure —
same predicate, same model set, same α, same prices ⇒ same plan.
Entries are keyed by (normalized σ, model-set fingerprint, α, trainer
kind, search method, backend, cost provider + version) and the whole
cache drops on any ``ModelStore`` mutation through the store's
``subscribe`` channel — the same transport the device backend's model
cache invalidates over.

One ``PlanCache`` may be **shared by many sessions over the same
store** (``MLegoSession(plan_cache=...)``, the serving layer's
default): every key carries the model-set fingerprint *and* the cost
provider identity + version, so entries are value-addressed — a hit in
session B for a plan session A searched is correct by construction,
and sessions pricing through different providers can never serve each
other's plans.  Lookup/insert are lock-serialized.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

from repro.core.batch_opt import BatchResult, batch_optimize, processing_order
from repro.core.cost import CostProvider
from repro.core.plans import Interval, subtract
from repro.core.search import SEARCHERS, SearchResult


class PlanCache:
    """Store-subscribed memo of ``SearchResult``s, LRU-bounded.

    A fingerprint of the usable model set rides in every key, so even
    a stale entry could never be served for a mutated store; clearing
    on the subscribe channel additionally keeps the cache from filling
    with unreachable generations.
    """

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: "OrderedDict[Tuple, SearchResult]" = OrderedDict()
        self._lock = threading.RLock()
        self._store = None
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def store(self):
        """The store this cache invalidates over (None if unbound)."""
        return self._store

    # --- store subscription -------------------------------------------------
    def bind_store(self, store) -> None:
        """Subscribe to ``store``'s mutations.  Binding the already-
        bound store is a no-op, which is what lets many sessions over
        one shared store adopt one shared cache; binding a *different*
        store clears the cache and re-homes the subscription (the
        legacy store-swap path — every sharing session sees the
        clear)."""
        with self._lock:
            if store is self._store:
                return
            if self._store is not None:
                self._store.unsubscribe(self._on_store_event)
            self._store = store
            self.clear()
            if store is not None:
                store.subscribe(self._on_store_event)

    def _on_store_event(self, event: str, model_id: int) -> None:
        with self._lock:
            if self._entries:
                self.invalidations += 1
            self.clear()

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    # --- lookup ---------------------------------------------------------------
    @staticmethod
    def fingerprint(models: Sequence) -> int:
        """Value identity of a model set (ids + ranges)."""
        return hash(tuple(sorted(
            (m.model_id, m.o.lo, m.o.hi) for m in models)))

    def peek(self, key: Tuple) -> Optional[SearchResult]:
        """Non-counting, non-promoting lookup — the serving layer's
        SLO loop probes "is this plan already paid for?" without
        polluting the hit/miss telemetry or the LRU order."""
        with self._lock:
            return self._entries.get(key)

    def get(self, key: Tuple) -> Optional[SearchResult]:
        with self._lock:
            res = self._entries.get(key)
            if res is None:
                self.misses += 1
                return None
            self.hits += 1
            self._entries.move_to_end(key)
            return res

    def put(self, key: Tuple, res: SearchResult) -> None:
        with self._lock:
            self._entries[key] = res
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)


class Planner:
    def __init__(self, index, cost: CostProvider):
        self.index = index
        self.cost = cost

    def plan(self, models: Sequence, sigma: Interval, alpha: float,
             method: str = "psoa++") -> SearchResult:
        """Best plan for one interval (Def. 2 score-based search)."""
        try:
            searcher = SEARCHERS[method]
        except KeyError:
            raise ValueError(f"unknown plan-search method {method!r}; "
                             f"one of {sorted(SEARCHERS)}") from None
        return searcher(models, sigma, self.index, self.cost, alpha)

    def plan_batch(self, models: Sequence, sigmas: Sequence[Interval],
                   alpha: float = 0.0, *, reorder: bool = True
                   ) -> BatchResult:
        """Alg. 4 joint plan combination for a batch of intervals.

        ``alpha`` seeds the initial per-query plans (threaded from the
        specs; Alg. 4's joint pruning itself stays time-cost based).
        ``reorder`` applies the §V.C processing order (widest query
        first); False preserves submission order.
        """
        sigmas = list(sigmas)
        order = processing_order(sigmas, self.index) if reorder else None
        return batch_optimize(models, sigmas, self.index, self.cost,
                              alpha=alpha, order=order)

    @staticmethod
    def gaps(sigma: Interval, plan: Sequence) -> List[Interval]:
        """Uncovered ranges of ``sigma`` under ``plan`` (to be trained)."""
        return subtract(sigma, [m.o for m in plan])
