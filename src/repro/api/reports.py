"""Query/batch result types returned by ``MLegoSession``.

``QueryReport`` is the single-query answer (Fig. 2 output): the merged
topic matrix plus the per-stage cost breakdown.  ``BatchReport`` is the
§V.C batch answer and fixes the seed repo's cost-attribution bug: the
shared plan-search and gap-training costs live **on the batch report**
(``shared_search_s`` / ``shared_train_s``), not smeared onto the first
query's result, so per-query latency stats stay meaningful.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.api.spec import QuerySpec
from repro.core.batch_opt import BatchResult
from repro.core.lda import MaterializedModel
from repro.core.search import SearchResult


@dataclass
class QueryReport:
    """Answer to one ``QuerySpec``.

    ``plans`` holds one ``SearchResult`` per predicate component (a
    single-interval σ has exactly one).  Inside a batch, ``train_s``
    and ``search_s`` are 0.0 — those costs are shared and reported on
    the ``BatchReport``.

    ``backend`` names the execution backend that answered the query.
    On the device backend, ``merge_device_ms`` is the wall time of the
    fused kernel launch (upload + launch + sync; 0.0 on host),
    ``train_device_ms`` the wall time of kernel-route gap training
    (blocked Gibbs sweep / fused E-step; 0.0 on host or when no gap
    was trained), ``cache_hits``/``cache_misses`` count device-cache
    traffic for this query's parts, and ``cache_resident_bytes``
    gauges the device model cache's residency right after the merge.
    Inside a batch the launch is shared, so the traffic counters live
    on the ``BatchReport`` and stay zero here.

    ``plan_cached`` is True when every component's plan came from the
    session plan cache — the search stage was skipped entirely (and
    ``search_s`` is just the lookup time).

    ``degraded`` is the serving layer's SLO degradation level at
    answer time (0 = full quality; >= 1 means the service scaled the
    spec's effective α down to shed planning/training work under
    overload — see ``repro.serve.slo``).  Always 0 for direct session
    use.

    ``fallback_from`` names the backend the query was *submitted* to
    when device loss forced a replay on the fallback chain
    (``backend`` then names the backend that actually answered);
    None on the healthy path.  The serving layer reads it to feed the
    per-backend circuit breaker.

    ``trace`` is the query's trace id in the session's (or service's)
    ``repro.obs.Tracer`` — look it up with ``tracer.spans(trace_id=
    report.trace)`` or find it in the exported Chrome trace.  None
    when tracing is disabled.
    """

    beta: np.ndarray                 # merged topic-word matrix (K, V)
    spec: QuerySpec
    plans: Tuple[SearchResult, ...]
    n_trained_tokens: int
    n_merged: int
    train_s: float
    merge_s: float
    search_s: float
    materialized: List[MaterializedModel] = field(default_factory=list)
    backend: str = "host"
    merge_device_ms: float = 0.0
    train_device_ms: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_resident_bytes: int = 0
    plan_cached: bool = False
    degraded: int = 0
    fallback_from: Optional[str] = None
    trace: Optional[str] = None

    @property
    def plan(self) -> SearchResult:
        """The (first) component plan — the whole plan for interval σ."""
        return self.plans[0]

    @property
    def model_ids(self) -> Tuple[int, ...]:
        return tuple(sorted(m.model_id for p in self.plans for m in p.plan))

    @property
    def n_reused(self) -> int:
        return sum(len(p.plan) for p in self.plans)

    @property
    def total_s(self) -> float:
        return self.train_s + self.merge_s + self.search_s


@dataclass
class BatchReport:
    """Answer to ``submit_many``: per-query reports + batch-level costs.

    Invariant (regression-tested): ``total_s`` equals what the legacy
    ``execute_batch`` path reported in aggregate —
    ``shared_search_s + shared_train_s + Σ per-query merge_s`` — but
    without corrupting ``reports[0]``'s own timings.
    """

    reports: List[QueryReport]
    opt: BatchResult                 # Alg. 4 plan combination + benefit
    shared_search_s: float
    shared_train_s: float
    materialized: List[MaterializedModel] = field(default_factory=list)
    backend: str = "host"
    merge_device_ms: float = 0.0     # shared bucketed launches (batch total)
    train_device_ms: float = 0.0     # kernel-route shared gap training
    cache_hits: int = 0
    cache_misses: int = 0
    cache_resident_bytes: int = 0
    pad_rows: int = 0                # zero-weight rows across the launches
    plan_cached: bool = False        # Alg. 4 result served from the cache
    fallback_from: Optional[str] = None  # backend lost mid-batch (see above)
    trace: Optional[str] = None      # batch-level trace id (see QueryReport)

    @property
    def merge_s(self) -> float:
        return sum(r.merge_s for r in self.reports)

    @property
    def total_s(self) -> float:
        return self.shared_search_s + self.shared_train_s + self.merge_s

    @property
    def benefit(self) -> float:
        return self.opt.benefit

    def __len__(self) -> int:
        return len(self.reports)

    def __iter__(self) -> Iterator[QueryReport]:
        return iter(self.reports)

    def __getitem__(self, i: int) -> QueryReport:
        return self.reports[i]
