"""Pluggable execution backends for the query hot path.

The Fig. 2 pipeline bottoms out in two data-plane operations: merging
a plan's materialized models (Alg. 1/2 — pure bandwidth) and training
scratch gaps (the VB E-step — pure MXU).  ``HostBackend`` runs both on
host NumPy exactly as the seed repo did and is the parity reference.
``DeviceBackend`` keeps hot model parameters device-resident in an
LRU cache keyed by store model id (count- **and** byte-bounded,
invalidated through the store's change notifications), executes merges
through the fused Pallas ``merge_topics`` kernel — one ``(n, K, V)``
launch per query, and a single *ragged segmented* launch for a
``submit_many`` batch (every query's part rows concatenated CSR-style;
zero pad rows on any batch shape — this retired the power-of-two
bucketed launcher) — and routes scratch-gap training through the
kernel paths: VB through the fused E-step kernel
(``vb_estep(..., use_kernel=True)``), Gibbs through the doc-blocked
CGS sweep (``cgs_fit_blocked`` / ``kernels/gibbs_sweep``).  A freshly
trained persisted gap model is warm-inserted into the LRU
(``note_trained``) so the merge that follows reads it back as a hit.

``ShardedDeviceBackend`` ("device_sharded") lifts the one-device HBM
ceiling: every cached model is resident as a vocab-sharded ``(K, Vp)``
array (each device owns a ``V/ndev`` slice), merges run as
shard_map-launched Pallas kernels on the local slice, and the only
cross-device traffic is the per-topic row normalizer psum — so a model
stack whose total bytes exceed one device's ``max_bytes`` still merges
without host round-trips.  Cache byte accounting is *per device*
(global bytes / shard count), which is the unit the calibrated cost
model prices fetches in.

On CPU hosts the merge/E-step kernels execute in Pallas interpret
mode (the CI correctness path); on TPU they compile to Mosaic.  The
Gibbs route runs its blocked math as vmapped XLA off-TPU (see
``kernels/gibbs_sweep/ops.py``).  Selection flows through
``QuerySpec.backend`` / ``MLegoSession(backend=...)``.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, fields, replace
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from contextlib import contextmanager, nullcontext

from repro.api.trainers import (
    TrainerFn,
    get_merge,
    get_trainer,
    merge_family_name,
)
from repro.configs.lda_default import LDAConfig
from repro.core.errors import DeviceLostError
from repro.core.lda import MaterializedModel
from repro.core.merge import (
    device_merge_params,
    device_norm_offset,
    device_stat_key,
)
from repro.core.store import ModelStore
from repro.data.corpus import Corpus, doc_term_matrix
from repro.distributed.merge_collective import (
    merge_topics_ragged_sharded,
    merge_topics_sharded,
    padded_vocab,
)
from repro.distributed.sharding import MeshEnv, local_mesh_env
from repro.kernels.common import default_interpret
from repro.kernels.merge_topics.ops import (
    merge_topics,
    merge_topics_ragged,
    segment_ids,
)
from repro.obs import profile as obs_profile
from repro.obs import trace as obs
from repro.testing.faults import maybe_fail

BACKEND_NAMES = ("host", "device", "device_sharded")

# Runtime errors the device toolchain raises when an accelerator dies
# mid-launch (OOM, halted device, failed transfer).  Translated to
# ``DeviceLostError`` so callers can quarantine the backend and replay
# on the fallback chain instead of failing the query.
_JAX_RUNTIME_ERRORS = tuple(
    t for t in (getattr(getattr(jax, "errors", None),
                        "JaxRuntimeError", None),
                getattr(jax.lib, "XlaRuntimeError", None))
    if isinstance(t, type))


@dataclass(frozen=True)
class BackendStats:
    """Monotonic counters; diff two snapshots for per-query attribution.

    ``cache_resident_bytes`` is a *gauge* (current device-cache
    residency), not a counter — ``delta`` carries the newer snapshot's
    value through instead of differencing it.
    """

    cache_hits: int = 0
    cache_misses: int = 0
    cache_hit_bytes: int = 0          # bytes read from the device cache
    cache_miss_bytes: int = 0         # bytes transferred host->device
    cache_evictions: int = 0
    cache_invalidations: int = 0
    merges: int = 0
    device_launches: int = 0
    host_fallbacks: int = 0
    merge_device_ms: float = 0.0
    pad_rows: int = 0                 # zero-weight rows in batched launches
    pad_bytes: int = 0                # bytes those zero-weight rows carry
    train_device_ms: float = 0.0      # kernel-route gap-training wall time
    gap_device_trains: int = 0        # gaps trained through a kernel route
    train_uploads: int = 0            # fresh gap models warmed into the LRU
    cache_resident_bytes: int = 0     # gauge: bytes resident right now

    _GAUGES = ("cache_resident_bytes",)

    def delta(self, since: "BackendStats") -> "BackendStats":
        return BackendStats(**{
            f.name: getattr(self, f.name) - (
                0 if f.name in self._GAUGES else getattr(since, f.name))
            for f in fields(self)})

    @property
    def hit_rate(self) -> float:
        seen = self.cache_hits + self.cache_misses
        return self.cache_hits / seen if seen else 0.0


class ExecutionBackend:
    """Interface the session/executor program against."""

    name: str = "?"
    shards: int = 1   # devices each cached model is sliced across

    def __init__(self):
        self.stats = BackendStats()
        self._stats_lock = threading.Lock()
        # Sessions attribute per-query work by diffing two stats
        # snapshots; on a *shared* backend a concurrent session's
        # launch landing inside that window would be mis-attributed
        # (and fed to the calibrated cost model as this query's
        # bytes).  Callers hold this around snapshot -> launch -> diff
        # sections — coarse, but the device serializes launches anyway.
        self.measure_lock = threading.RLock()
        # health: a quarantined backend is suspected of device loss;
        # sessions route around it until a breaker probe re-admits it
        self.quarantined = False
        # opt-in kernel profiling (see repro.obs.profile): wraps
        # launches in jax.profiler annotations and lands HLO-derived
        # flops/bytes on the ambient span.  Costs one compile per new
        # launch shape — keep off on latency-sensitive paths.
        self.profile = False

    # -- health ----------------------------------------------------------
    def quarantine(self) -> None:
        """Mark unhealthy (device lost).  Idempotent."""
        self.quarantined = True

    def unquarantine(self) -> None:
        """Re-admit after a successful health probe."""
        self.quarantined = False

    @contextmanager
    def _device_guard(self):
        """Translate raw runtime crashes into ``DeviceLostError`` so
        the caller knows the *backend* is suspect, not the query."""
        try:
            yield
        except DeviceLostError:
            raise
        except _JAX_RUNTIME_ERRORS as exc:
            raise DeviceLostError(
                f"{self.name} backend lost its device: {exc}",
                backend=self.name) from exc

    # -- lifecycle -------------------------------------------------------
    def bind_store(self, store: ModelStore) -> None:
        """Attach to the session's store (cache invalidation hookup)."""

    @property
    def bound_store(self) -> Optional[ModelStore]:
        """The store this backend caches against; None if stateless.

        Any number of sessions may share one backend **over the same
        store** (the multi-tenant serving layer does exactly that);
        sessions refuse to adopt a backend whose ``bound_store`` is a
        *different* live store — the cache is keyed by model id alone,
        and ids from two stores collide silently."""
        return None

    # -- data plane ------------------------------------------------------
    def merge(self, parts: Sequence[MaterializedModel], kind: str,
              cfg: LDAConfig) -> np.ndarray:
        raise NotImplementedError

    def merge_many(self, part_lists: Sequence[Sequence[MaterializedModel]],
                   kind: str, cfg: LDAConfig) -> List[np.ndarray]:
        return [self.merge(p, kind, cfg) for p in part_lists]

    def trainer(self, kind: str) -> TrainerFn:
        return get_trainer(kind)

    def kernel_route(self, kind: str) -> bool:
        """True when ``trainer(kind)`` runs through a device kernel.

        The executor uses this to attribute a trained gap's wall time
        to ``train_device_ms`` *per query* — replacing the shared
        stats-snapshot diff whose window picked up concurrent
        sessions' launches on a shared backend."""
        return False

    def note_trained(self, model: MaterializedModel) -> None:
        """Hook: a fresh gap model was persisted after training on this
        backend (device backends warm their LRU with it)."""

    # -- bookkeeping -----------------------------------------------------
    def _count(self, **kw) -> None:
        # read-modify-write on the immutable snapshot; locked so two
        # sessions sharing the backend can't lose each other's counts
        with self._stats_lock:
            self.stats = replace(
                self.stats, **{k: getattr(self.stats, k) + v
                               for k, v in kw.items()})


class HostBackend(ExecutionBackend):
    """Today's NumPy semantics — the parity reference for DeviceBackend."""

    name = "host"

    def merge(self, parts, kind, cfg):
        maybe_fail("backend.merge.host")
        for _ in parts:
            maybe_fail("backend.fetch.host")
        self._count(merges=1)
        return get_merge(kind)(list(parts), cfg)


class _DeviceModelCache:
    """LRU of device-resident merge statistics, keyed by store model id.

    Bounded two ways: ``capacity`` caps the entry count and
    ``max_bytes`` (optional) caps the resident parameter bytes — LRU
    entries are evicted until both bounds hold, so one giant model
    can't silently pin the whole HBM budget the way a count bound
    allows.  Volatile models (id −1, never in the store) pass through
    without being cached — there is no id under which an invalidation
    for them could ever arrive.

    Mutation is lock-serialized: one device cache may be shared by
    every session of a multi-tenant service over the same store.

    ``prepare`` maps a host statistic array to its device-resident form
    (default: plain f32 upload); the sharded backend substitutes a
    pad-and-shard upload.  ``bytes_divisor`` converts a resident
    array's *global* byte count into the unit the bounds and counters
    are kept in — per-device bytes for a vocab-sharded cache, so
    ``max_bytes`` bounds what any one device actually holds.
    """

    def __init__(self, capacity: int, max_bytes: Optional[int] = None,
                 *, prepare=None, bytes_divisor: int = 1):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        self.capacity = capacity
        self.max_bytes = max_bytes
        self._prepare = prepare or (lambda a: jnp.asarray(a, jnp.float32))
        self.bytes_divisor = max(1, int(bytes_divisor))
        self._entries: "OrderedDict[int, jax.Array]" = OrderedDict()
        self._lock = threading.RLock()
        self.resident_bytes = 0
        self.hits = self.misses = self.evictions = self.invalidations = 0
        self.hit_bytes = self.miss_bytes = 0
        # residency epoch: bumps whenever the resident *set* changes
        # (insert/evict/invalidate/clear) — the session plan cache keys
        # on it for providers that price fetches by cache state
        self.epoch = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, model_id: int) -> bool:
        return model_id in self._entries

    def _over_budget(self) -> bool:
        return (len(self._entries) > self.capacity
                or (self.max_bytes is not None
                    and self.resident_bytes > self.max_bytes))

    def _nb(self, arr: jax.Array) -> int:
        """Accounting bytes for one entry: per-device, not global."""
        return int(arr.nbytes) // self.bytes_divisor

    def _evict_lru(self) -> None:
        mid, arr = self._entries.popitem(last=False)
        self.resident_bytes -= self._nb(arr)
        self.evictions += 1
        self.epoch += 1
        obs.instant("cache.evict", model_id=mid, bytes=self._nb(arr))

    def _fits_alone(self, arr: jax.Array) -> bool:
        """A model bigger than the whole byte budget must pass through
        uncached — inserting it would evict every resident entry
        before LRU order finally evicted the newcomer itself."""
        return self.max_bytes is None or self._nb(arr) <= self.max_bytes

    def get(self, model: MaterializedModel, stat_key: str) -> jax.Array:
        mid = model.model_id
        with self._lock:
            if mid >= 0 and mid in self._entries:
                self.hits += 1
                self.hit_bytes += self._nb(self._entries[mid])
                self._entries.move_to_end(mid)
                return self._entries[mid]
            self.misses += 1
            with obs.span("device.upload", "backend", model_id=mid):
                arr = self._prepare(model.theta[stat_key])
                obs.set_attrs(bytes=self._nb(arr))
            self.miss_bytes += self._nb(arr)
            if mid >= 0 and self._fits_alone(arr):
                self._entries[mid] = arr
                self.resident_bytes += self._nb(arr)
                self.epoch += 1
                while self._entries and self._over_budget():
                    self._evict_lru()
            return arr

    def put(self, model: MaterializedModel, stat_key: str) -> bool:
        """Warm-insert a model (no hit/miss accounting) — the gap-
        training upload path.  Returns True if it ended up resident
        (an over-budget model passes through uncached)."""
        mid = model.model_id
        with self._lock:
            if mid < 0 or mid in self._entries:
                return mid in self._entries
            with obs.span("device.upload", "backend", model_id=mid,
                          warm=True):
                arr = self._prepare(model.theta[stat_key])
                obs.set_attrs(bytes=self._nb(arr))
            if not self._fits_alone(arr):
                return False
            self._entries[mid] = arr
            self.resident_bytes += self._nb(arr)
            self.epoch += 1
            while self._entries and self._over_budget():
                self._evict_lru()
            return mid in self._entries

    def invalidate(self, model_id: int) -> None:
        with self._lock:
            arr = self._entries.pop(model_id, None)
            if arr is not None:
                self.resident_bytes -= self._nb(arr)
                self.invalidations += 1
                self.epoch += 1

    def clear(self) -> None:
        with self._lock:
            if self._entries:
                self.epoch += 1
            self._entries.clear()
            self.resident_bytes = 0


class DeviceBackend(ExecutionBackend):
    """Device-resident merges + kernel gap training (VB E-step and the
    doc-blocked Gibbs sweep).

    capacity   : max cached models (LRU-evicted beyond it)
    max_bytes  : optional cap on resident parameter bytes (evicts LRU
                 until under; a model larger than the cap passes
                 through uncached)
    interpret  : Pallas interpret override (None = auto: interpret off
                 TPU or when MLEGO_KERNEL_INTERPRET=1)
    kernel_estep : route "vb" gap training through the fused E-step
                 kernel (True by default)
    kernel_gibbs : route "gs" gap training through the doc-blocked CGS
                 sweep (``core.gibbs.cgs_fit_blocked``; True by
                 default).  The blocked sampler is statistically — not
                 bit — equivalent to the host exact scan; HostBackend
                 keeps the exact ``cgs_fit``.
    gibbs_block_docs : documents per sampler block on the gs route
                 (more blocks = shorter sequential chain, slightly
                 staler topic-word counts within a sweep)

    Every other kind falls back to the host trainer registry.  Fresh
    gap models are *warm-inserted* into the LRU (``note_trained``) so
    the merge that follows training hits the cache instead of
    re-uploading Θ — tracked in ``stats.train_uploads``.
    """

    name = "device"

    def __init__(self, capacity: int = 64, *,
                 max_bytes: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 kernel_estep: bool = True,
                 kernel_gibbs: bool = True,
                 gibbs_block_docs: int = 64,
                 profile: bool = False):
        super().__init__()
        self.cache = self._make_cache(capacity, max_bytes)
        self.interpret = interpret
        self.kernel_estep = kernel_estep
        self.kernel_gibbs = kernel_gibbs
        self.gibbs_block_docs = gibbs_block_docs
        self.profile = profile
        self._store: Optional[ModelStore] = None

    def _make_cache(self, capacity: int,
                    max_bytes: Optional[int]) -> _DeviceModelCache:
        return _DeviceModelCache(capacity, max_bytes)

    # -- lifecycle -------------------------------------------------------
    def bind_store(self, store: ModelStore) -> None:
        if store is self._store:
            return
        if self._store is not None:
            self._store.unsubscribe(self._on_store_event)
        self._store = store
        self.cache.clear()
        store.subscribe(self._on_store_event)

    @property
    def bound_store(self) -> Optional[ModelStore]:
        return self._store

    def _on_store_event(self, event: str, model_id: int) -> None:
        # "remove" drops stale device copies; "add" defends against id
        # collisions from a store that was swapped or reloaded in place.
        self.cache.invalidate(model_id)
        self._sync_cache_counters()

    def quarantine(self) -> None:
        # resident copies on a lost device are garbage; drop them so a
        # re-admitted backend re-uploads from the store
        super().quarantine()
        self.cache.clear()
        self._sync_cache_counters()

    def _fetch(self, model, stat_key: str) -> jax.Array:
        maybe_fail(f"backend.fetch.{self.name}")
        return self.cache.get(model, stat_key)

    def _annotate(self, name: str):
        """Profiler annotation for a launch; no-op unless profiling."""
        return obs_profile.annotate(name) if self.profile else nullcontext()

    # -- merge -----------------------------------------------------------
    def merge(self, parts, kind, cfg):
        maybe_fail(f"backend.merge.{self.name}")
        fam = merge_family_name(kind)
        if fam is None:                  # custom merge callable: host only
            self._count(merges=1, host_fallbacks=1)
            return get_merge(kind)(list(parts), cfg)
        stat_key, bias, base, finish = device_merge_params(fam, cfg)
        t0 = time.perf_counter()
        with self._device_guard(), \
                obs.span("kernel.launch", "backend", op="merge_topics",
                         n_parts=len(parts), backend=self.name):
            stats = jnp.stack([self._fetch(m, stat_key) for m in parts])
            w = jnp.ones((len(parts),), jnp.float32)
            with self._annotate("mlego.merge_topics"):
                merged = merge_topics(stats, w, bias=bias, base=base,
                                      interpret=self.interpret)
                merged.block_until_ready()
            ms = (time.perf_counter() - t0) * 1e3
            obs.set_attrs(merge_device_ms=ms)
            if self.profile:
                obs_profile.annotate_span("hlo", obs_profile.hlo_features(
                    "merge_topics", merge_topics, stats, w,
                    bias=bias, base=base, interpret=self.interpret))
        self._sync_cache_counters()
        self._count(merges=1, device_launches=1, merge_device_ms=ms)
        return finish(np.asarray(merged))

    def merge_many(self, part_lists, kind, cfg):
        """§V.C batch merge stage: one ragged segmented launch.

        Every query's part rows concatenate into a single CSR-style
        ``(R, K, V)`` stack merged by the segmented kernel — zero pad
        rows on any batch shape (``stats.pad_rows`` stays 0 by
        construction; the bucketed launcher this replaced padded within
        each power-of-two bucket)."""
        fam = merge_family_name(kind)
        if fam is None:
            # per-list self.merge counts the merges and fallbacks
            return super().merge_many(part_lists, kind, cfg)
        if len(part_lists) == 1:
            return [self.merge(part_lists[0], kind, cfg)]
        maybe_fail(f"backend.merge.{self.name}")
        stat_key, bias, base, finish = device_merge_params(fam, cfg)
        t0 = time.perf_counter()
        with self._device_guard(), \
                obs.span("kernel.launch", "backend",
                         op="merge_topics_ragged",
                         n_plans=len(part_lists), backend=self.name):
            stats_list, weights_list = [], []
            for parts in part_lists:
                stats_list.append(
                    jnp.stack([self._fetch(m, stat_key) for m in parts]))
                weights_list.append(jnp.ones((len(parts),), jnp.float32))
            with self._annotate("mlego.merge_topics_ragged"):
                merged, pad_rows, launches = merge_topics_ragged(
                    stats_list, weights_list, bias=bias, base=base,
                    interpret=self.interpret)
                for row in merged:
                    row.block_until_ready()
            obs.set_attrs(merge_device_ms=(time.perf_counter() - t0) * 1e3,
                          pad_rows=pad_rows)
        ms = (time.perf_counter() - t0) * 1e3
        # a padding row carries one part's worth of (K, V) f32 bytes —
        # the per-byte cost calibration prices it from this
        row_nbytes = int(stats_list[0][0].nbytes)
        self._sync_cache_counters()
        self._count(merges=len(part_lists), device_launches=launches,
                    merge_device_ms=ms, pad_rows=pad_rows,
                    pad_bytes=pad_rows * row_nbytes)
        return [finish(np.asarray(row)) for row in merged]

    def _sync_cache_counters(self) -> None:
        c = self.cache
        with self._stats_lock:
            self.stats = replace(self.stats, cache_hits=c.hits,
                                 cache_misses=c.misses,
                                 cache_hit_bytes=c.hit_bytes,
                                 cache_miss_bytes=c.miss_bytes,
                                 cache_evictions=c.evictions,
                                 cache_invalidations=c.invalidations,
                                 cache_resident_bytes=c.resident_bytes)

    # -- training --------------------------------------------------------
    def trainer(self, kind: str) -> TrainerFn:
        if kind == "vb" and self.kernel_estep:
            return self._train_vb_kernel
        if kind == "gs" and self.kernel_gibbs:
            return self._train_gs_kernel
        return get_trainer(kind)

    def kernel_route(self, kind: str) -> bool:
        return ((kind == "vb" and self.kernel_estep)
                or (kind == "gs" and self.kernel_gibbs))

    def note_trained(self, model: MaterializedModel) -> None:
        fam = merge_family_name(model.kind)
        if fam is None:                  # custom merge: no device form
            return
        if self.cache.put(model, device_stat_key(fam)):
            self._count(train_uploads=1)
        self._sync_cache_counters()

    def _train_vb_kernel(self, corpus: Corpus, cfg: LDAConfig,
                         key) -> Dict[str, np.ndarray]:
        from repro.core.vb import vb_fit
        t0 = time.perf_counter()
        x = doc_term_matrix(corpus)
        with self._annotate("mlego.vb_estep"):
            lam = np.asarray(vb_fit(x, key, cfg, use_kernel=True))
        ms = (time.perf_counter() - t0) * 1e3
        obs.set_attrs(train_device_ms=ms, route="vb_estep")
        self._count(gap_device_trains=1, train_device_ms=ms)
        return {"lam": lam}

    def _train_gs_kernel(self, corpus: Corpus, cfg: LDAConfig, key,
                         global_nkv: Optional[np.ndarray] = None
                         ) -> Dict[str, np.ndarray]:
        from repro.core.gibbs import cgs_fit_blocked
        t0 = time.perf_counter()
        # an explicit interpret override must reach the Pallas body
        # like it does on the merge/E-step routes — use_kernel=None
        # alone would route off-TPU hosts to the jnp reference
        with self._annotate("mlego.gibbs_sweep"):
            nkv = cgs_fit_blocked(corpus.tokens, corpus.doc_ids, cfg, key,
                                  global_nkv=global_nkv,
                                  block_docs=self.gibbs_block_docs,
                                  use_kernel=(None if self.interpret is None
                                              else True),
                                  interpret=self.interpret)
        ms = (time.perf_counter() - t0) * 1e3
        obs.set_attrs(train_device_ms=ms, route="gibbs_blocked")
        self._count(gap_device_trains=1, train_device_ms=ms)
        return {"delta_nkv": nkv}


class ShardedDeviceBackend(DeviceBackend):
    """Vocab-sharded merges: each device owns a ``V/ndev`` slice.

    The cache uploads every model statistic as a ``(K, Vp)`` array
    sharded over the mesh's "model" axis (``Vp`` rounds V up so every
    slice is lane-aligned; pad columns are masked out of the row
    normalizer, so their value never matters).  Merges run through the
    shard_map-launched Pallas collectives in
    ``distributed/merge_collective.py``: every device merges its local
    slice (ragged-segmented for batches — zero pad rows), applies the
    family's finisher numerator offset, and joins a per-topic row-
    normalizer psum — the *only* cross-device collective, (K,) per
    query regardless of V.  Normalization therefore happens on device;
    the host-side finisher is bypassed.

    ``max_bytes`` bounds **per-device** residency (global bytes /
    shards), which is the point: a model stack whose total f32 bytes
    exceed one device's budget still merges, because no device ever
    holds more than its slice.  ``env`` defaults to a (1, ndev) mesh
    over every local device and degrades to the unsharded semantics at
    one device.  Gap training is inherited unchanged (single-device
    kernels); trained models are warm-inserted in sharded form.
    """

    name = "device_sharded"

    def __init__(self, capacity: int = 64, *,
                 max_bytes: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 kernel_estep: bool = True,
                 kernel_gibbs: bool = True,
                 gibbs_block_docs: int = 64,
                 env: Optional[MeshEnv] = None,
                 profile: bool = False):
        self.env = env if env is not None else local_mesh_env()
        self.shards = max(1, self.env.tp_size)
        super().__init__(capacity, max_bytes=max_bytes,
                         interpret=interpret, kernel_estep=kernel_estep,
                         kernel_gibbs=kernel_gibbs,
                         gibbs_block_docs=gibbs_block_docs,
                         profile=profile)

    def _make_cache(self, capacity, max_bytes):
        return _DeviceModelCache(capacity, max_bytes,
                                 prepare=self._prepare_stat,
                                 bytes_divisor=self.shards)

    def _prepare_stat(self, arr) -> jax.Array:
        """Pad V for lane-aligned slices and shard over the vocab axis."""
        x = jnp.asarray(arr, jnp.float32)
        v = x.shape[-1]
        vp = padded_vocab(v, self.shards)
        if vp != v:
            x = jnp.pad(x, ((0, 0), (0, vp - v)))
        return jax.device_put(x, self.env.sharding(P(None, "model")))

    # -- merge -----------------------------------------------------------
    def merge(self, parts, kind, cfg):
        maybe_fail(f"backend.merge.{self.name}")
        fam = merge_family_name(kind)
        if fam is None:                  # custom merge callable: host only
            self._count(merges=1, host_fallbacks=1)
            return get_merge(kind)(list(parts), cfg)
        stat_key, bias, base, _ = device_merge_params(fam, cfg)
        v_true = int(parts[0].theta[stat_key].shape[-1])
        t0 = time.perf_counter()
        with self._device_guard(), \
                obs.span("kernel.launch", "backend",
                         op="merge_topics_sharded", n_parts=len(parts),
                         backend=self.name, shards=self.shards):
            stats = jnp.stack([self._fetch(m, stat_key) for m in parts])
            w = jnp.ones((len(parts),), jnp.float32)
            with self._annotate("mlego.merge_topics_sharded"):
                beta = merge_topics_sharded(
                    stats, w, self.env, bias=bias, base=base,
                    num_offset=device_norm_offset(fam, cfg), v_true=v_true,
                    interpret=default_interpret(self.interpret))
                beta.block_until_ready()
            obs.set_attrs(merge_device_ms=(time.perf_counter() - t0) * 1e3)
        ms = (time.perf_counter() - t0) * 1e3
        self._sync_cache_counters()
        self._count(merges=1, device_launches=1, merge_device_ms=ms)
        with obs.span("allgather", "backend", backend=self.name,
                      bytes=int(beta.nbytes), shards=self.shards):
            host = np.asarray(beta)
        return host[:, :v_true]

    def merge_many(self, part_lists, kind, cfg):
        fam = merge_family_name(kind)
        if fam is None:
            return ExecutionBackend.merge_many(self, part_lists, kind, cfg)
        if len(part_lists) == 1:
            return [self.merge(part_lists[0], kind, cfg)]
        maybe_fail(f"backend.merge.{self.name}")
        stat_key, bias, base, _ = device_merge_params(fam, cfg)
        v_true = int(part_lists[0][0].theta[stat_key].shape[-1])
        counts = [len(parts) for parts in part_lists]
        t0 = time.perf_counter()
        with self._device_guard(), \
                obs.span("kernel.launch", "backend",
                         op="merge_topics_ragged_sharded",
                         n_plans=len(part_lists), backend=self.name,
                         shards=self.shards):
            rows = [self._fetch(m, stat_key)
                    for parts in part_lists for m in parts]
            stats = jnp.stack(rows)
            w = jnp.ones((len(rows),), jnp.float32)
            with self._annotate("mlego.merge_topics_ragged_sharded"):
                beta = merge_topics_ragged_sharded(
                    stats, w, segment_ids(counts), len(counts), self.env,
                    bias=bias, base=base,
                    num_offset=device_norm_offset(fam, cfg), v_true=v_true,
                    interpret=default_interpret(self.interpret))
                beta.block_until_ready()
            obs.set_attrs(merge_device_ms=(time.perf_counter() - t0) * 1e3)
        ms = (time.perf_counter() - t0) * 1e3
        self._sync_cache_counters()
        self._count(merges=len(part_lists), device_launches=1,
                    merge_device_ms=ms)
        with obs.span("allgather", "backend", backend=self.name,
                      bytes=int(beta.nbytes), shards=self.shards):
            host = np.asarray(beta)[:, :, :v_true]
        return [host[i] for i in range(len(counts))]


_FACTORIES = {"host": HostBackend, "device": DeviceBackend,
              "device_sharded": ShardedDeviceBackend}


def make_backend(name: str, **kwargs) -> ExecutionBackend:
    """Construct a backend by name; ``kwargs`` pass to its constructor
    (host ignores ``profile=`` — it has no launches to annotate)."""
    try:
        factory = _FACTORIES[name]
    except KeyError:
        raise ValueError(f"unknown execution backend {name!r}; one of "
                         f"{BACKEND_NAMES}") from None
    if factory is HostBackend:
        kwargs.pop("profile", None)
    return factory(**kwargs)
