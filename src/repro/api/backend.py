"""Pluggable execution backends for the query hot path.

The Fig. 2 pipeline bottoms out in two data-plane operations: merging
a plan's materialized models (Alg. 1/2 — pure bandwidth) and training
scratch gaps (the VB E-step — pure MXU).  ``HostBackend`` runs both on
host NumPy exactly as the seed repo did and is the parity reference.
``DeviceBackend`` keeps hot model parameters device-resident in an
LRU cache keyed by store model id (invalidated through the store's
change notifications), executes merges through the fused Pallas
``merge_topics`` kernel — one padded ``(n, K, V)`` launch per query,
and one ``(b, n', K, V)`` launch for a whole ``submit_many`` batch —
and routes scratch-gap VB training through the fused E-step kernel
(``vb_estep(..., use_kernel=True)``).

On CPU hosts the kernels execute in Pallas interpret mode (the CI
correctness path); on TPU they compile to Mosaic.  Selection flows
through ``QuerySpec.backend`` / ``MLegoSession(backend=...)``.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.trainers import (
    TrainerFn,
    get_merge,
    get_trainer,
    merge_family_name,
)
from repro.configs.lda_default import LDAConfig
from repro.core.lda import MaterializedModel
from repro.core.merge import device_merge_params
from repro.core.store import ModelStore
from repro.data.corpus import Corpus, doc_term_matrix
from repro.kernels.merge_topics.ops import merge_topics, merge_topics_batch

BACKEND_NAMES = ("host", "device")


@dataclass(frozen=True)
class BackendStats:
    """Monotonic counters; diff two snapshots for per-query attribution."""

    cache_hits: int = 0
    cache_misses: int = 0
    cache_evictions: int = 0
    cache_invalidations: int = 0
    merges: int = 0
    device_launches: int = 0
    host_fallbacks: int = 0
    merge_device_ms: float = 0.0

    def delta(self, since: "BackendStats") -> "BackendStats":
        return BackendStats(
            self.cache_hits - since.cache_hits,
            self.cache_misses - since.cache_misses,
            self.cache_evictions - since.cache_evictions,
            self.cache_invalidations - since.cache_invalidations,
            self.merges - since.merges,
            self.device_launches - since.device_launches,
            self.host_fallbacks - since.host_fallbacks,
            self.merge_device_ms - since.merge_device_ms,
        )

    @property
    def hit_rate(self) -> float:
        seen = self.cache_hits + self.cache_misses
        return self.cache_hits / seen if seen else 0.0


class ExecutionBackend:
    """Interface the session/executor program against."""

    name: str = "?"

    def __init__(self):
        self.stats = BackendStats()

    # -- lifecycle -------------------------------------------------------
    def bind_store(self, store: ModelStore) -> None:
        """Attach to the session's store (cache invalidation hookup)."""

    @property
    def bound_store(self) -> Optional[ModelStore]:
        """The store this backend caches against; None if stateless.

        Sessions refuse to adopt a backend whose ``bound_store`` is a
        *different* live store — the cache is keyed by model id alone,
        and ids from two stores collide silently."""
        return None

    # -- data plane ------------------------------------------------------
    def merge(self, parts: Sequence[MaterializedModel], kind: str,
              cfg: LDAConfig) -> np.ndarray:
        raise NotImplementedError

    def merge_many(self, part_lists: Sequence[Sequence[MaterializedModel]],
                   kind: str, cfg: LDAConfig) -> List[np.ndarray]:
        return [self.merge(p, kind, cfg) for p in part_lists]

    def trainer(self, kind: str) -> TrainerFn:
        return get_trainer(kind)

    # -- bookkeeping -----------------------------------------------------
    def _count(self, **kw) -> None:
        self.stats = replace(
            self.stats, **{k: getattr(self.stats, k) + v
                           for k, v in kw.items()})


class HostBackend(ExecutionBackend):
    """Today's NumPy semantics — the parity reference for DeviceBackend."""

    name = "host"

    def merge(self, parts, kind, cfg):
        self._count(merges=1)
        return get_merge(kind)(list(parts), cfg)


class _DeviceModelCache:
    """LRU of device-resident merge statistics, keyed by store model id.

    Volatile models (id −1, never in the store) pass through without
    being cached — there is no id under which an invalidation for them
    could ever arrive.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: "OrderedDict[int, jax.Array]" = OrderedDict()
        self.hits = self.misses = self.evictions = self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, model_id: int) -> bool:
        return model_id in self._entries

    def get(self, model: MaterializedModel, stat_key: str) -> jax.Array:
        mid = model.model_id
        if mid >= 0 and mid in self._entries:
            self.hits += 1
            self._entries.move_to_end(mid)
            return self._entries[mid]
        self.misses += 1
        arr = jnp.asarray(model.theta[stat_key], jnp.float32)
        if mid >= 0:
            self._entries[mid] = arr
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
        return arr

    def invalidate(self, model_id: int) -> None:
        if self._entries.pop(model_id, None) is not None:
            self.invalidations += 1

    def clear(self) -> None:
        self._entries.clear()


class DeviceBackend(ExecutionBackend):
    """Device-resident merges + kernel E-step training.

    capacity   : max cached models (LRU-evicted beyond it)
    interpret  : Pallas interpret override (None = auto: interpret off
                 TPU or when MLEGO_KERNEL_INTERPRET=1)
    kernel_estep : route "vb" gap training through the fused E-step
                 kernel (True by default; the host trainer registry is
                 used for every other kind)
    """

    name = "device"

    def __init__(self, capacity: int = 64, *,
                 interpret: Optional[bool] = None,
                 kernel_estep: bool = True):
        super().__init__()
        self.cache = _DeviceModelCache(capacity)
        self.interpret = interpret
        self.kernel_estep = kernel_estep
        self._store: Optional[ModelStore] = None

    # -- lifecycle -------------------------------------------------------
    def bind_store(self, store: ModelStore) -> None:
        if store is self._store:
            return
        if self._store is not None:
            self._store.unsubscribe(self._on_store_event)
        self._store = store
        self.cache.clear()
        store.subscribe(self._on_store_event)

    @property
    def bound_store(self) -> Optional[ModelStore]:
        return self._store

    def _on_store_event(self, event: str, model_id: int) -> None:
        # "remove" drops stale device copies; "add" defends against id
        # collisions from a store that was swapped or reloaded in place.
        self.cache.invalidate(model_id)
        self._sync_cache_counters()

    # -- merge -----------------------------------------------------------
    def merge(self, parts, kind, cfg):
        fam = merge_family_name(kind)
        if fam is None:                  # custom merge callable: host only
            self._count(merges=1, host_fallbacks=1)
            return get_merge(kind)(list(parts), cfg)
        stat_key, bias, base, finish = device_merge_params(fam, cfg)
        t0 = time.perf_counter()
        stats = jnp.stack([self.cache.get(m, stat_key) for m in parts])
        w = jnp.ones((len(parts),), jnp.float32)
        merged = merge_topics(stats, w, bias=bias, base=base,
                              interpret=self.interpret)
        merged.block_until_ready()
        ms = (time.perf_counter() - t0) * 1e3
        self._sync_cache_counters()
        self._count(merges=1, device_launches=1, merge_device_ms=ms)
        return finish(np.asarray(merged))

    def merge_many(self, part_lists, kind, cfg):
        fam = merge_family_name(kind)
        if fam is None:
            # per-list self.merge counts the merges and fallbacks
            return super().merge_many(part_lists, kind, cfg)
        if len(part_lists) == 1:
            return [self.merge(part_lists[0], kind, cfg)]
        stat_key, bias, base, finish = device_merge_params(fam, cfg)
        t0 = time.perf_counter()
        n_max = max(len(p) for p in part_lists)
        rows, weights = [], []
        for parts in part_lists:
            stack = jnp.stack([self.cache.get(m, stat_key) for m in parts])
            pad = n_max - len(parts)
            if pad:
                # zero-weight rows: 0·(0 − base) contributes nothing
                stack = jnp.pad(stack, ((0, pad), (0, 0), (0, 0)))
            rows.append(stack)
            weights.append([1.0] * len(parts) + [0.0] * pad)
        stats = jnp.stack(rows)                       # (b, n_max, K, V)
        w = jnp.asarray(weights, jnp.float32)         # (b, n_max)
        merged = merge_topics_batch(stats, w, bias=bias, base=base,
                                    interpret=self.interpret)
        merged.block_until_ready()
        ms = (time.perf_counter() - t0) * 1e3
        self._sync_cache_counters()
        self._count(merges=len(part_lists), device_launches=1,
                    merge_device_ms=ms)
        return [finish(np.asarray(row)) for row in merged]

    def _sync_cache_counters(self) -> None:
        c = self.cache
        self.stats = replace(self.stats, cache_hits=c.hits,
                             cache_misses=c.misses,
                             cache_evictions=c.evictions,
                             cache_invalidations=c.invalidations)

    # -- training --------------------------------------------------------
    def trainer(self, kind: str) -> TrainerFn:
        if kind == "vb" and self.kernel_estep:
            return self._train_vb_kernel
        return get_trainer(kind)

    @staticmethod
    def _train_vb_kernel(corpus: Corpus, cfg: LDAConfig,
                         key) -> Dict[str, np.ndarray]:
        from repro.core.vb import vb_fit
        x = doc_term_matrix(corpus)
        return {"lam": np.asarray(vb_fit(x, key, cfg, use_kernel=True))}


_FACTORIES = {"host": HostBackend, "device": DeviceBackend}


def make_backend(name: str) -> ExecutionBackend:
    try:
        return _FACTORIES[name]()
    except KeyError:
        raise ValueError(f"unknown execution backend {name!r}; one of "
                         f"{BACKEND_NAMES}") from None
