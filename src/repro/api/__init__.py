"""MLego public API — typed queries against a session (see README.md).

    from repro.api import MLegoSession, QuerySpec, Interval

    session = MLegoSession(corpus, cfg)
    report  = session.submit(QuerySpec(sigma=Interval(0.0, 500.0),
                                       alpha=0.5))

Everything else in ``repro.core`` is machinery behind this surface;
``repro.core.query.QueryEngine`` is a deprecated shim over it.
"""
from repro.api.backend import (
    BACKEND_NAMES,
    BackendStats,
    DeviceBackend,
    ExecutionBackend,
    HostBackend,
    make_backend,
)
from repro.api.executor import StalePlanError
from repro.api.planner import PlanCache, Planner
from repro.api.reports import BatchReport, QueryReport
from repro.api.session import (
    CALIBRATION_SIDECAR,
    MLegoSession,
    calibration_sidecar,
)
from repro.api.spec import (
    MATERIALIZE_POLICIES,
    PERSIST,
    VOLATILE,
    QuerySpec,
    normalize_sigma,
)
from repro.api.trainers import (
    available_trainers,
    get_trainer,
    register_trainer,
    resolve_kind,
)
from repro.core.cost import (
    CalibratedCostModel,
    Calibration,
    CostModel,
    CostProvider,
)
from repro.core.errors import (
    CorruptModelError,
    DeviceLostError,
    ExecutionError,
    PermanentExecutionError,
    RetryPolicy,
    TransientExecutionError,
)
from repro.core.plan_ir import FetchStep, MergeStep, Plan, TrainGapStep
from repro.core.plans import Interval
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

__all__ = [
    "BACKEND_NAMES",
    "BackendStats",
    "BatchReport",
    "CALIBRATION_SIDECAR",
    "CalibratedCostModel",
    "Calibration",
    "calibration_sidecar",
    "CorruptModelError",
    "CostModel",
    "CostProvider",
    "DeviceBackend",
    "DeviceLostError",
    "ExecutionBackend",
    "ExecutionError",
    "FetchStep",
    "HostBackend",
    "Interval",
    "MergeStep",
    "Plan",
    "PlanCache",
    "Planner",
    "TrainGapStep",
    "make_backend",
    "MATERIALIZE_POLICIES",
    "MetricsRegistry",
    "MLegoSession",
    "PERSIST",
    "PermanentExecutionError",
    "QueryReport",
    "QuerySpec",
    "RetryPolicy",
    "StalePlanError",
    "Tracer",
    "TransientExecutionError",
    "VOLATILE",
    "available_trainers",
    "get_trainer",
    "normalize_sigma",
    "register_trainer",
    "resolve_kind",
]
