"""Pluggable trainer registry — one place that knows how to fit Θ.

The paper's materialized-model tuple ⟨o, N, Θ⟩ is agnostic to the
inference algorithm that produced Θ; only the *merge* (Alg. 1 vs
Alg. 2) and the trainer differ per kind.  The seed repo hard-coded the
two trainer bodies twice each inside ``QueryEngine`` — this registry
collapses them and lets a new model kind plug in without touching the
planner or the session:

    register_trainer("my_kind", my_fit_fn)

A trainer maps a sub-corpus to the mergeable parameter dict:

    fn(corpus: Corpus, cfg: LDAConfig, key: jax PRNG key) -> Dict[str, np.ndarray]

Each kind also carries its *merge family* — how a homogeneous list of
its models combines into a topic matrix β.  Pass ``merge=`` a callable
``(models, cfg) -> β`` or the name of a built-in family (``"vb"``:
Alg. 1 natural-parameter addition over ``theta["lam"]``; ``"gs"``:
Alg. 2 count addition over ``theta["delta_nkv"]``).

Built-ins: ``"vb"`` (variational Bayes, Alg. 1 family) and ``"gs"``
(collapsed Gibbs, Alg. 2 family; alias ``"gibbs"``).  Kinds are
canonicalized through :func:`resolve_kind` so the store tags models
consistently regardless of which alias the caller used.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.lda_default import LDAConfig
from repro.core.gibbs import cgs_fit
from repro.core.lda import (
    MaterializedModel,
    topics_from_gs,
    topics_from_vb,
)
from repro.core.merge import merge_gs, merge_vb
from repro.core.vb import vb_fit
from repro.data.corpus import Corpus, doc_term_matrix

TrainerFn = Callable[[Corpus, LDAConfig, object], Dict[str, np.ndarray]]
MergeFn = Callable[[Sequence[MaterializedModel], LDAConfig], np.ndarray]


def _merge_vb_family(models: Sequence[MaterializedModel],
                     cfg: LDAConfig) -> np.ndarray:
    return topics_from_vb(merge_vb(models, cfg))


def _merge_gs_family(models: Sequence[MaterializedModel],
                     cfg: LDAConfig) -> np.ndarray:
    return topics_from_gs(merge_gs(models, cfg), cfg.eta)


_MERGE_FAMILIES: Dict[str, MergeFn] = {
    "vb": _merge_vb_family,
    "gs": _merge_gs_family,
}

_TRAINERS: Dict[str, TrainerFn] = {}
_MERGES: Dict[str, MergeFn] = {}
_ALIASES: Dict[str, str] = {}


def register_trainer(kind: str, fn: TrainerFn,
                     *, merge: Union[str, MergeFn] = "vb",
                     aliases: Tuple[str, ...] = ()) -> None:
    """Register (or replace) the trainer (and merge family) for a kind."""
    if not kind or not isinstance(kind, str):
        raise ValueError(f"trainer kind must be a non-empty string, got {kind!r}")
    if isinstance(merge, str):
        if merge not in _MERGE_FAMILIES:
            raise ValueError(f"unknown merge family {merge!r}; one of "
                             f"{sorted(_MERGE_FAMILIES)} or a callable")
        merge = _MERGE_FAMILIES[merge]
    for a in aliases:
        if a in _TRAINERS and a != kind:
            raise ValueError(f"alias {a!r} would shadow the registered "
                             f"kind {a!r}")
    _TRAINERS[kind] = fn
    _MERGES[kind] = merge
    _ALIASES.pop(kind, None)     # explicit registration wins over an alias
    for a in aliases:
        _ALIASES[a] = kind


def resolve_kind(kind: str) -> str:
    """Canonical kind name (follows aliases); raises on unknown kinds."""
    kind = _ALIASES.get(kind, kind)
    if kind not in _TRAINERS:
        raise ValueError(
            f"unknown model kind {kind!r}; registered: "
            f"{sorted(_TRAINERS)} (aliases: {sorted(_ALIASES)}). "
            "Use repro.api.register_trainer to add one.")
    return kind


def get_trainer(kind: str) -> TrainerFn:
    return _TRAINERS[resolve_kind(kind)]


def get_merge(kind: str) -> MergeFn:
    return _MERGES[resolve_kind(kind)]


def merge_family_name(kind: str) -> Optional[str]:
    """Built-in merge family this kind uses ("vb" / "gs"), or None.

    Kinds registered with a custom merge *callable* return None — they
    have no known device form and must merge on the host."""
    fn = _MERGES[resolve_kind(kind)]
    for name, fam in _MERGE_FAMILIES.items():
        if fn is fam:
            return name
    return None


def available_trainers() -> Tuple[str, ...]:
    return tuple(sorted(_TRAINERS))


# --- built-ins -------------------------------------------------------------

def _train_vb(corpus: Corpus, cfg: LDAConfig, key) -> Dict[str, np.ndarray]:
    x = doc_term_matrix(corpus)
    return {"lam": np.asarray(vb_fit(x, key, cfg))}


def _train_gibbs(corpus: Corpus, cfg: LDAConfig, key,
                 global_nkv: Optional[np.ndarray] = None
                 ) -> Dict[str, np.ndarray]:
    # global_nkv is the DSGS Eq. 8 prior — the store's merged counts,
    # threaded in by the executor so a gap trains against the reuse
    # capital's topic structure instead of a zero prior
    return {"delta_nkv": cgs_fit(corpus.tokens, corpus.doc_ids, cfg, key,
                                 global_nkv=global_nkv)}


register_trainer("vb", _train_vb, merge="vb")
register_trainer("gs", _train_gibbs, merge="gs", aliases=("gibbs",))
