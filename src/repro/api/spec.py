"""Typed analytic-query specification — the paper's Def. 1 made concrete.

Def. 1 defines an analytic query as the five-tuple q = {F, α, D, σ, M}:

  F : the analysis function (LDA here) — fixed by the session's
      ``LDAConfig`` + the trainer ``kind`` (see ``repro.api.trainers``)
  α : the accuracy/latency preference in [0, 1] (Eq. 2 weight)
  D : the dataset — owned by the session (``MLegoSession.corpus``)
  σ : the range predicate over the ordered dimension attribute —
      a single ``Interval`` or a **union of intervals**
  M : whether the answer's fresh gap models are materialized back into
      the store — the ``materialize`` policy (``persist``/``volatile``)

``QuerySpec`` carries the per-query members (σ, α, trainer kind,
plan-search method, materialization policy, execution backend); the
session carries F and D.  Specs are frozen, validated at construction,
and normalize σ into a sorted tuple of disjoint intervals (overlapping
or touching member intervals are coalesced), so everything downstream
can assume a clean predicate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional, Tuple, Union

from repro.api.backend import BACKEND_NAMES
from repro.core.plans import Interval
from repro.core.search import SEARCHERS

PERSIST = "persist"
VOLATILE = "volatile"
MATERIALIZE_POLICIES = (PERSIST, VOLATILE)

Sigma = Union[Interval, Iterable[Interval]]


def normalize_sigma(sigma: Sigma) -> Tuple[Interval, ...]:
    """σ -> sorted tuple of disjoint, positive-length intervals.

    Accepts a single ``Interval`` or any iterable of them; coalesces
    overlapping *and* touching members (they select the same documents
    as their union).  Raises ``ValueError`` on empty predicates.
    """
    ivs = [sigma] if isinstance(sigma, Interval) else list(sigma)
    if not ivs:
        raise ValueError("predicate sigma selects no range (empty union)")
    for iv in ivs:
        if not isinstance(iv, Interval):
            raise TypeError(f"sigma members must be Interval, got {type(iv)}")
        if iv.length <= 0:
            raise ValueError(f"sigma member {iv} has zero length")
    out = []
    for iv in sorted(ivs):
        if out and iv.lo <= out[-1].hi:
            out[-1] = Interval(out[-1].lo, max(out[-1].hi, iv.hi))
        else:
            out.append(iv)
    return tuple(out)


@dataclass(frozen=True)
class QuerySpec:
    """One analytic query (Def. 1's per-query members, typed + validated).

    sigma       : predicate σ — Interval or union of Intervals
                  (normalized to a disjoint sorted tuple)
    alpha       : α ∈ [0, 1] — 0 = fastest, 1 = most accurate (Eq. 2)
    kind        : trainer/backend kind ("vb", "gs"/"gibbs", or any
                  registered kind); canonicalized through the registry.
                  None (the default) means "use the session's kind".
    method      : plan-search algorithm ("nai" | "gra" | "psoa" |
                  "psoa++")
    materialize : M — "persist" grows the store with fresh gap models,
                  "volatile" answers without touching the store
    backend     : execution backend for merge + gap training —
                  "host" (NumPy) or "device" (Pallas kernels with a
                  device-resident model cache).  None (the default)
                  means "use the session's backend".
    """

    sigma: Tuple[Interval, ...]
    alpha: float = 0.0
    kind: Optional[str] = None
    method: str = "psoa++"
    materialize: str = PERSIST
    backend: Optional[str] = None

    def __post_init__(self):
        from repro.api.trainers import resolve_kind  # late: registry may grow
        object.__setattr__(self, "sigma", normalize_sigma(self.sigma))
        if not 0.0 <= self.alpha <= 1.0:
            raise ValueError(f"alpha must be in [0, 1], got {self.alpha}")
        if self.kind is not None:
            object.__setattr__(self, "kind", resolve_kind(self.kind))
        if self.method not in SEARCHERS:
            raise ValueError(f"unknown plan-search method {self.method!r}; "
                             f"one of {sorted(SEARCHERS)}")
        if self.materialize not in MATERIALIZE_POLICIES:
            raise ValueError(f"materialize must be one of "
                             f"{MATERIALIZE_POLICIES}, got {self.materialize!r}")
        if self.backend is not None and self.backend not in BACKEND_NAMES:
            raise ValueError(f"unknown execution backend {self.backend!r}; "
                             f"one of {BACKEND_NAMES} or None (session's)")

    # --- convenience ----------------------------------------------------
    @property
    def is_union(self) -> bool:
        return len(self.sigma) > 1

    @property
    def span(self) -> Interval:
        """Bounding interval of the predicate (hull of the union)."""
        return Interval(self.sigma[0].lo, self.sigma[-1].hi)

    @property
    def persist(self) -> bool:
        return self.materialize == PERSIST
