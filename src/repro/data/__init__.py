from repro.data.corpus import (
    Corpus,
    DataIndex,
    make_corpus,
    doc_term_matrix,
    train_test_split,
)

__all__ = [
    "Corpus",
    "DataIndex",
    "make_corpus",
    "doc_term_matrix",
    "train_test_split",
]
