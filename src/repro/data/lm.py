"""LM data pipeline: deterministic, cursor-addressable synthetic batches.

Batches are a pure function of (seed, cursor) so a restarted trainer
resumes the exact stream — the checkpoint stores only the integer
cursor.  Modality frontends are STUBS per the assignment: the VLM cell
receives precomputed patch embeddings, the audio cell precomputed mel
frame embeddings (both synthesized here with the same determinism).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def encoder_frames(cfg: ArchConfig) -> int:
    """Stub mel-frontend frame count, padded for the ring mesh."""
    return _round_up(cfg.encoder_seq, 256)


def make_batch(cfg: ArchConfig, batch: int, seq: int, seed: int,
               cursor: int) -> Dict[str, jnp.ndarray]:
    """One training batch for (arch, B, S) at stream position ``cursor``."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), cursor)
    ks = jax.random.split(key, 3)
    v = cfg.vocab_size
    tokens = jax.random.randint(ks[0], (batch, seq), 0, v, jnp.int32)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((batch, 1), jnp.int32)], axis=1)
    out = {"tokens": tokens, "labels": labels}
    if cfg.family == "vlm" and cfg.n_patches:
        p = min(cfg.n_patches, seq)
        out["patch_embeds"] = (
            jax.random.normal(ks[1], (batch, p, cfg.d_model), jnp.float32)
            * 0.02)
        # patch positions carry no next-token target
        out["labels"] = out["labels"].at[:, :p].set(-1)
    if cfg.is_encoder_decoder:
        f = encoder_frames(cfg)
        out["frames"] = (
            jax.random.normal(ks[2], (batch, f, cfg.d_model), jnp.float32)
            * 0.02)
    return out


def batch_stream(cfg: ArchConfig, batch: int, seq: int, *, seed: int = 0,
                 start_cursor: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    cursor = start_cursor
    while True:
        yield make_batch(cfg, batch, seq, seed, cursor)
        cursor += 1
