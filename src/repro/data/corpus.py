"""Corpus synthesis + range indexing for MLego analytic queries.

Documents are sampled from the LDA generative model itself, so held-out
log-predictive-probability (lpp) is a meaningful accuracy signal for the
merge-vs-scratch comparisons.  Each document carries an ordered
dimension attribute (``attr`` — think id / timestamp / geohash bucket)
that the analytic-query predicates range over, mirroring the paper's
Random (id-range) and OLAP (hierarchy-range) workloads.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Corpus:
    """A bag-of-words corpus with an ordered OLAP attribute per doc.

    tokens     : int32 (total_tokens,)  word id of every token
    doc_ids    : int32 (total_tokens,)  owning document of every token
    doc_offsets: int64 (n_docs + 1,)    CSR offsets into ``tokens``
    attr       : float64 (n_docs,)      sorted ascending dimension attribute
    vocab_size : V
    """

    tokens: np.ndarray
    doc_ids: np.ndarray
    doc_offsets: np.ndarray
    attr: np.ndarray
    vocab_size: int

    @property
    def n_docs(self) -> int:
        return len(self.doc_offsets) - 1

    @property
    def n_tokens(self) -> int:
        return int(self.doc_offsets[-1])

    def doc_lengths(self) -> np.ndarray:
        return np.diff(self.doc_offsets)

    # --- range selection ---------------------------------------------------
    def doc_slice(self, lo: float, hi: float) -> Tuple[int, int]:
        """[d0, d1) of documents whose attr lies in [lo, hi)."""
        d0 = bisect.bisect_left(self.attr.tolist(), lo)
        d1 = bisect.bisect_left(self.attr.tolist(), hi)
        return d0, d1

    def subset(self, lo: float, hi: float) -> "Corpus":
        d0, d1 = self.doc_slice(lo, hi)
        t0, t1 = int(self.doc_offsets[d0]), int(self.doc_offsets[d1])
        return Corpus(
            tokens=self.tokens[t0:t1],
            doc_ids=self.doc_ids[t0:t1] - d0,
            doc_offsets=self.doc_offsets[d0 : d1 + 1] - self.doc_offsets[d0],
            attr=self.attr[d0:d1],
            vocab_size=self.vocab_size,
        )


class DataIndex:
    """O(log n) doc/token counting over attribute ranges (prefix sums)."""

    def __init__(self, corpus: Corpus):
        self._attr = corpus.attr
        self._tok_prefix = corpus.doc_offsets  # already a token prefix sum

    def count(self, lo: float, hi: float) -> Tuple[int, int]:
        """(#docs, #tokens) with attr in [lo, hi)."""
        d0 = np.searchsorted(self._attr, lo, side="left")
        d1 = np.searchsorted(self._attr, hi, side="left")
        return int(d1 - d0), int(self._tok_prefix[d1] - self._tok_prefix[d0])

    def tokens_in(self, lo: float, hi: float) -> int:
        return self.count(lo, hi)[1]

    def docs_in(self, lo: float, hi: float) -> int:
        return self.count(lo, hi)[0]


def make_corpus(
    n_docs: int,
    vocab_size: int,
    n_topics: int,
    *,
    mean_doc_len: int = 64,
    alpha: float = 0.1,
    eta: float = 0.05,
    attr_max: Optional[float] = None,
    seed: int = 0,
) -> Tuple[Corpus, np.ndarray]:
    """Sample a corpus from the LDA generative model.

    Returns (corpus, true_beta) where true_beta is (K, V) row-stochastic.
    """
    rng = np.random.default_rng(seed)
    beta = rng.dirichlet(np.full(vocab_size, eta), size=n_topics)  # (K, V)
    lengths = np.maximum(rng.poisson(mean_doc_len, size=n_docs), 4)
    offsets = np.zeros(n_docs + 1, np.int64)
    offsets[1:] = np.cumsum(lengths)
    total = int(offsets[-1])
    tokens = np.empty(total, np.int32)
    doc_ids = np.empty(total, np.int32)
    theta = rng.dirichlet(np.full(n_topics, alpha), size=n_docs)  # (D, K)
    for d in range(n_docs):
        z = rng.choice(n_topics, size=lengths[d], p=theta[d])
        # sample words per topic in bulk
        for k in np.unique(z):
            sel = z == k
            tokens[offsets[d] : offsets[d + 1]][sel] = rng.choice(
                vocab_size, size=int(sel.sum()), p=beta[k]
            )
        doc_ids[offsets[d] : offsets[d + 1]] = d
    attr_max = attr_max if attr_max is not None else float(n_docs)
    attr = np.sort(rng.uniform(0.0, attr_max, size=n_docs))
    corpus = Corpus(
        tokens=tokens,
        doc_ids=doc_ids,
        doc_offsets=offsets,
        attr=attr,
        vocab_size=vocab_size,
    )
    return corpus, beta


def concat_corpora(a: Corpus, b: Corpus) -> Corpus:
    """Append corpus ``b``'s documents after ``a``'s (streaming growth).

    The result is a valid ``Corpus`` only if the combined ``attr``
    stays sorted, i.e. ``b`` is *newer* than ``a`` (append-only
    ingestion) — enforced here because every range structure
    (``doc_slice``, ``DataIndex``) depends on attr order.
    """
    if a.vocab_size != b.vocab_size:
        raise ValueError(f"vocab mismatch: {a.vocab_size} vs {b.vocab_size}")
    if b.n_docs == 0:
        return a
    if a.n_docs == 0:
        return b
    if float(b.attr[0]) < float(a.attr[-1]):
        raise ValueError(
            f"append-only: incoming batch starts at attr {b.attr[0]} "
            f"below the existing frontier {a.attr[-1]}")
    offsets = np.zeros(a.n_docs + b.n_docs + 1, np.int64)
    offsets[: a.n_docs + 1] = a.doc_offsets
    offsets[a.n_docs + 1 :] = b.doc_offsets[1:] + a.n_tokens
    return Corpus(
        tokens=np.concatenate([a.tokens, b.tokens]),
        doc_ids=np.concatenate([a.doc_ids,
                                b.doc_ids + np.int32(a.n_docs)]),
        doc_offsets=offsets,
        attr=np.concatenate([a.attr, b.attr]),
        vocab_size=a.vocab_size,
    )


def doc_term_matrix(corpus: Corpus, d0: int = 0, d1: Optional[int] = None) -> np.ndarray:
    """Dense (D, V) float32 doc-term count matrix for docs [d0, d1)."""
    d1 = corpus.n_docs if d1 is None else d1
    n = d1 - d0
    x = np.zeros((n, corpus.vocab_size), np.float32)
    t0, t1 = int(corpus.doc_offsets[d0]), int(corpus.doc_offsets[d1])
    np.add.at(x, (corpus.doc_ids[t0:t1] - d0, corpus.tokens[t0:t1]), 1.0)
    return x


def train_test_split(corpus: Corpus, test_frac: float = 0.1, seed: int = 0):
    """Split *documents* into train/test corpora (attr order preserved)."""
    rng = np.random.default_rng(seed)
    n = corpus.n_docs
    test_mask = rng.uniform(size=n) < test_frac
    return _take(corpus, ~test_mask), _take(corpus, test_mask)


def _take(corpus: Corpus, mask: np.ndarray) -> Corpus:
    doc_idx = np.nonzero(mask)[0]
    lengths = corpus.doc_lengths()[doc_idx]
    offsets = np.zeros(len(doc_idx) + 1, np.int64)
    offsets[1:] = np.cumsum(lengths)
    tokens = np.concatenate(
        [
            corpus.tokens[corpus.doc_offsets[d] : corpus.doc_offsets[d + 1]]
            for d in doc_idx
        ]
    ) if len(doc_idx) else np.empty(0, np.int32)
    doc_ids = np.repeat(np.arange(len(doc_idx), dtype=np.int32), lengths)
    return Corpus(
        tokens=tokens.astype(np.int32),
        doc_ids=doc_ids,
        doc_offsets=offsets,
        attr=corpus.attr[doc_idx],
        vocab_size=corpus.vocab_size,
    )
