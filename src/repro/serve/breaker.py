"""Per-backend circuit breaker for the serve worker pools.

Classic three-state machine, error-rate windowed:

- **closed** — traffic flows; every outcome lands in a sliding window
  of the last ``window`` results.  When the window holds at least
  ``min_samples`` outcomes and the failure fraction reaches
  ``failure_threshold`` (or any outcome is a ``DeviceLostError``-class
  hard failure), the breaker *opens*.
- **open** — ``allow()`` answers False (the service reroutes the group
  to the fallback pool instead of shedding) until ``cooldown_s`` has
  elapsed, measured on the injected clock.
- **half-open** — after cooldown, up to ``half_open_probes`` calls are
  admitted as probes.  Any probe failure re-opens (and restarts the
  cooldown); ``half_open_probes`` consecutive successes close the
  breaker and clear the window.

The breaker itself is policy-free about *what* a failure is — the
service records outcomes; ``record_failure(hard=True)`` marks the
device-loss case that must trip immediately regardless of window
state.  ``on_transition(old, new)`` fires after the lock is released
so the owner can mirror state into backend quarantine flags without
deadlock risk.  All methods are thread-safe; ``snapshot()`` returns
the frozen ``BreakerSnapshot`` that ``ServiceReport.breaker`` carries.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, List, Optional, Tuple

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


@dataclass(frozen=True)
class BreakerPolicy:
    """Tuning knobs; defaults suit the serve bench's open-loop traces."""

    window: int = 20              # sliding outcome window (closed state)
    failure_threshold: float = 0.5  # open at >= this failure fraction
    min_samples: int = 5          # ... once the window holds this many
    cooldown_s: float = 1.0       # open -> half-open delay
    half_open_probes: int = 2     # consecutive successes to close

    def __post_init__(self) -> None:
        if self.window < 1 or self.min_samples < 1:
            raise ValueError("window and min_samples must be >= 1")
        if not 0.0 < self.failure_threshold <= 1.0:
            raise ValueError("failure_threshold must be in (0, 1]")
        if self.half_open_probes < 1:
            raise ValueError("half_open_probes must be >= 1")


@dataclass(frozen=True)
class BreakerSnapshot:
    """Point-in-time view for ``ServiceReport.breaker``."""

    state: str = CLOSED
    failures: int = 0             # window failure count (closed state)
    window: int = 0               # window occupancy
    error_rate: float = 0.0
    opens: int = 0                # lifetime open transitions
    reroutes: int = 0             # calls denied while open
    half_open_probes: int = 0     # probes admitted in current half-open
    since_s: float = 0.0          # seconds in current state
    transitions: int = 0          # lifetime state transitions (any edge)


class CircuitBreaker:
    """One breaker per backend identity (see ``MLegoService``)."""

    def __init__(self, policy: Optional[BreakerPolicy] = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition: Optional[
                     Callable[[str, str], None]] = None):
        self.policy = policy or BreakerPolicy()
        self._clock = clock
        self._on_transition = on_transition
        self._lock = threading.Lock()
        self._state = CLOSED
        self._since = self._clock()
        self._outcomes: Deque[bool] = deque(maxlen=self.policy.window)
        self._probes_inflight = 0
        self._probe_successes = 0
        self._transitions: List[Tuple[str, str]] = []  # pending hook args
        self.opens = 0
        self.reroutes = 0
        self.transitions = 0

    # -- internals (lock held) ------------------------------------------

    def _transition(self, new: str) -> None:
        old = self._state
        if old == new:
            return
        self._state = new
        self._since = self._clock()
        self.transitions += 1
        if new == OPEN:
            self.opens += 1
        if new == HALF_OPEN:
            self._probes_inflight = 0
            self._probe_successes = 0
        if new == CLOSED:
            self._outcomes.clear()
        if self._on_transition is not None:
            self._transitions.append((old, new))

    def _drain_hooks_locked(self) -> List[Tuple[str, str]]:
        pending, self._transitions = self._transitions, []
        return pending

    def _fire(self, pending: List[Tuple[str, str]]) -> None:
        for old, new in pending:
            self._on_transition(old, new)  # type: ignore[misc]

    def _window_failure_rate(self) -> float:
        if not self._outcomes:
            return 0.0
        return sum(1 for ok in self._outcomes if not ok) \
            / len(self._outcomes)

    def _maybe_half_open_locked(self) -> None:
        if self._state == OPEN and \
                self._clock() - self._since >= self.policy.cooldown_s:
            self._transition(HALF_OPEN)

    # -- public API ------------------------------------------------------

    @property
    def state(self) -> str:
        with self._lock:
            self._maybe_half_open_locked()
            state = self._state
            pending = self._drain_hooks_locked()
        self._fire(pending)
        return state

    def allow(self) -> bool:
        """May a call proceed on this backend right now?"""
        with self._lock:
            self._maybe_half_open_locked()
            if self._state == CLOSED:
                verdict = True
            elif self._state == HALF_OPEN and \
                    self._probes_inflight < self.policy.half_open_probes:
                self._probes_inflight += 1
                verdict = True
            else:
                self.reroutes += 1
                verdict = False
            pending = self._drain_hooks_locked()
        self._fire(pending)
        return verdict

    def record_success(self) -> None:
        with self._lock:
            if self._state == HALF_OPEN:
                self._probe_successes += 1
                if self._probe_successes >= self.policy.half_open_probes:
                    self._transition(CLOSED)
            elif self._state == CLOSED:
                self._outcomes.append(True)
            pending = self._drain_hooks_locked()
        self._fire(pending)

    def record_failure(self, *, hard: bool = False) -> None:
        """``hard=True`` (device loss) trips immediately from any state."""
        with self._lock:
            if hard or self._state == HALF_OPEN:
                self._transition(OPEN)
            elif self._state == CLOSED:
                self._outcomes.append(False)
                if len(self._outcomes) >= self.policy.min_samples and \
                        self._window_failure_rate() \
                        >= self.policy.failure_threshold:
                    self._transition(OPEN)
            pending = self._drain_hooks_locked()
        self._fire(pending)

    def force_open(self) -> None:
        with self._lock:
            self._transition(OPEN)
            pending = self._drain_hooks_locked()
        self._fire(pending)

    def snapshot(self) -> BreakerSnapshot:
        with self._lock:
            self._maybe_half_open_locked()
            snap = BreakerSnapshot(
                state=self._state,
                failures=sum(1 for ok in self._outcomes if not ok),
                window=len(self._outcomes),
                error_rate=self._window_failure_rate(),
                opens=self.opens,
                reroutes=self.reroutes,
                half_open_probes=self._probes_inflight,
                since_s=max(0.0, self._clock() - self._since),
                transitions=self.transitions)
            pending = self._drain_hooks_locked()
        self._fire(pending)
        return snap


__all__ = ["BreakerPolicy", "BreakerSnapshot", "CircuitBreaker",
           "CLOSED", "HALF_OPEN", "OPEN"]
