"""Coalescing queue — the mechanism that turns concurrent interactive
users into Alg. 4 batches, now with admission control.

Independent analysts submitting within a few milliseconds of each
other would each pay a full plan search + gap training + merge launch.
The paper's batch optimizer exists precisely because those queries
share structure; ``CoalescingQueue.drain`` is where the sharing is
*harvested* at serve time: the worker blocks for one pending query,
then keeps collecting arrivals for a configurable time window (or
until a width cap), and hands the whole bundle back so the service can
fuse compatible specs into one ``submit_many`` call.

The window is a latency/throughput dial: every query waits at most
``window_s`` beyond its own execution time, and in exchange a burst of
n compatible queries rides one joint plan search, trains every shared
gap segment once, and merges in size-bucketed batched launches.
``window_s=0`` degenerates to FIFO serial service (drain returns
whatever is already queued, never waits for more).

Admission control (the production-hardening layer):

  * ``max_queue`` bounds the number of pending queries.  A ``put``
    into a full queue either **displaces** the youngest strictly-
    lower-priority pending query (its future fails with ``ShedError``)
    or, when nothing pending is lower priority, raises ``ShedError``
    at the submitter — the front door rejects instead of queueing
    unboundedly.
  * Items carry ``SubmitOptions`` (deadline, priority, max queue
    wait).  The queue orders drains by priority (FIFO within one
    priority); deadline/queue-wait expiry is enforced by the service
    at execution start, where the clock actually matters.
  * **Mid-queue aging**: an entry that has waited past half its
    ``max_queue_wait_s`` is treated one priority level higher by both
    drain ordering and displacement-victim selection
    (``PendingQuery.effective_priority``) — long-waiting work climbs
    toward the front instead of starving until its overwait shed.
  * ``steal()`` is the work-stealing drain: non-blocking, no
    coalescing window — an idle worker of another pool takes only
    what is already pending so it can never hold foreign work open.

Windowed drains are serialized per queue (one collector at a time):
with several workers on one pool, a burst still coalesces into one
batch instead of being split among concurrently-draining workers —
workers pipeline (one drains the next batch while another executes
the previous) rather than compete.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.api.spec import QuerySpec


class ServiceClosedError(RuntimeError):
    """The service (or its queue) is closed to new queries."""


class ShedError(RuntimeError):
    """Rejected by admission control: the bounded queue was full, the
    query was displaced by a higher-priority arrival, or it waited in
    the queue past its ``max_queue_wait_s``."""


class DeadlineExceededError(RuntimeError):
    """The query's ``deadline_s`` elapsed before execution started."""


@dataclass(frozen=True)
class SubmitOptions:
    """Typed admission options for one submitted query.

    deadline_s       : answer-by budget measured from enqueue; a query
                       whose deadline passes before its group starts
                       executing fails with ``DeadlineExceededError``
                       (work it can no longer use is never done)
    priority         : higher drains first; under a full bounded queue
                       a higher-priority arrival displaces the
                       youngest strictly-lower-priority pending query
    max_queue_wait_s : cap on time spent *queued* (deadline minus
                       execution): exceeded ⇒ ``ShedError`` — the
                       load-shedding knob for open-loop traffic
    """

    deadline_s: Optional[float] = None
    priority: int = 0
    max_queue_wait_s: Optional[float] = None

    def __post_init__(self) -> None:
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be > 0, got {self.deadline_s}")
        if self.max_queue_wait_s is not None and self.max_queue_wait_s < 0:
            raise ValueError(f"max_queue_wait_s must be >= 0, got "
                             f"{self.max_queue_wait_s}")


@dataclass
class PendingQuery:
    """One enqueued spec awaiting execution.

    ``trace_id``/``root_span_id`` are minted by the service front door
    (``repro.obs.trace``) so the per-query root span survives the
    thread hop: the submitter enqueues, a pool worker executes, and
    everything the worker records parents onto the pre-allocated root.
    """

    spec: QuerySpec
    tenant: str
    options: SubmitOptions = field(default_factory=SubmitOptions)
    future: "Future" = field(default_factory=Future)
    enqueued_at: float = field(default_factory=time.perf_counter)
    seq: int = -1                    # assigned by the queue (FIFO tiebreak)
    trace_id: Optional[str] = None
    root_span_id: Optional[str] = None

    @property
    def deadline_at(self) -> Optional[float]:
        if self.options.deadline_s is None:
            return None
        return self.enqueued_at + self.options.deadline_s

    def expired(self, now: float) -> bool:
        d = self.deadline_at
        return d is not None and now > d

    def overwaited(self, now: float) -> bool:
        w = self.options.max_queue_wait_s
        return w is not None and (now - self.enqueued_at) > w

    def effective_priority(self, now: float) -> int:
        """Mid-queue aging: an entry that has waited past *half* its
        ``max_queue_wait_s`` gets a one-level priority bump — drain
        order and displacement both see the aged value, so a query
        about to shed on overwait outranks a fresh arrival of its
        nominal priority instead of starving behind it.  Entries
        without a wait cap never age (they cannot overwait-shed)."""
        w = self.options.max_queue_wait_s
        if w is not None and (now - self.enqueued_at) > 0.5 * w:
            return self.options.priority + 1
        return self.options.priority


def _shed_future(future: "Future", exc: Exception) -> None:
    """Fail a still-pending future, tolerating a racing client cancel
    (an already-cancelled future simply stays cancelled)."""
    try:
        future.set_exception(exc)
    except Exception:
        pass


class CoalescingQueue:
    """Thread-safe priority queue with windowed batch drains.

    window_s  : how long a drain keeps collecting after its first item
                (0 = take only what is already queued)
    max_width : hard cap on one drain's size — bounds both the fused
                batch's device footprint and the worst-case head-of-
                line wait a giant burst can impose
    max_queue : bound on pending items (None = unbounded, the pre-
                hardening behavior); see module docstring for the
                full-queue displacement/rejection rule
    on_shed   : callback invoked with each *displaced* item after its
                future has been failed (the service counts sheds per
                tenant through this)
    """

    def __init__(self, window_s: float = 0.005, max_width: int = 16,
                 max_queue: Optional[int] = None,
                 on_shed: Optional[Callable[[PendingQuery], None]] = None):
        if window_s < 0:
            raise ValueError(f"window_s must be >= 0, got {window_s}")
        if max_width < 1:
            raise ValueError(f"max_width must be >= 1, got {max_width}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.window_s = window_s
        self.max_width = max_width
        self.max_queue = max_queue
        self.on_shed = on_shed
        self.shed = 0                       # displaced-item count
        self._items: List[PendingQuery] = []
        self._cond = threading.Condition()
        # one windowed collector at a time (see module docstring)
        self._drain_lock = threading.Lock()
        self._seq = 0
        self._closed = False

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Refuse new work; queued items remain drainable.  Atomic
        against ``put`` (same lock), so callers may safely
        drain-then-join after this returns."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def put(self, item: PendingQuery) -> None:
        victim: Optional[PendingQuery] = None
        with self._cond:
            if self._closed:
                raise ServiceClosedError("queue is closed to new queries")
            if self.max_queue is not None \
                    and len(self._items) >= self.max_queue:
                # displace the *youngest strictly-lower-priority*
                # pending item — late low-priority work yields to an
                # urgent arrival; among equals, first come first served
                # (the arrival is the one rejected)
                # aged entries displace as their *effective* priority —
                # a query nearing its overwait shed is not a valid
                # victim for a merely-equal fresh arrival
                now = time.perf_counter()
                candidates = [
                    it for it in self._items
                    if it.effective_priority(now) < item.options.priority]
                if not candidates:
                    raise ShedError(
                        f"queue full ({self.max_queue} pending) and no "
                        f"lower-priority query to displace")
                victim = min(candidates,
                             key=lambda it: (it.effective_priority(now),
                                             -it.seq))
                self._items.remove(victim)
                self.shed += 1
            item.seq = self._seq
            self._seq += 1
            self._items.append(item)
            self._cond.notify()
        if victim is not None:
            # outside the lock: the future callback / on_shed may run
            # arbitrary client code
            _shed_future(victim.future, ShedError(
                "displaced from a full queue by a higher-priority query"))
            if self.on_shed is not None:
                self.on_shed(victim)

    def _pop_best_locked(self) -> PendingQuery:
        now = time.perf_counter()
        best = min(self._items,
                   key=lambda it: (-it.effective_priority(now), it.seq))
        self._items.remove(best)
        return best

    def drain(self, timeout: float = 0.05) -> List[PendingQuery]:
        """One coalescing round.

        Blocks up to ``timeout`` for a first pending query ([] if none
        arrives — the worker's idle poll), then keeps collecting until
        the window closes or ``max_width`` is reached.  The window is
        anchored at the *first* item's drain, not at each arrival, so
        a steady trickle cannot hold a batch open forever.  Items come
        out priority-first (FIFO within a priority).
        """
        with self._drain_lock:
            end = time.perf_counter() + max(timeout, 0.0)
            with self._cond:
                while not self._items:
                    remaining = end - time.perf_counter()
                    if remaining <= 0 or self._closed:
                        # closed+empty: nothing will ever arrive
                        if not self._items:
                            return []
                        break
                    self._cond.wait(remaining)
                batch = [self._pop_best_locked()]
                wend = time.perf_counter() + self.window_s
                while len(batch) < self.max_width:
                    if self._items:
                        batch.append(self._pop_best_locked())
                        continue
                    remaining = wend - time.perf_counter()
                    if remaining <= 0 or self._closed:
                        break
                    self._cond.wait(remaining)
                return batch

    def steal(self, max_width: Optional[int] = None) -> List[PendingQuery]:
        """Work-stealing drain: non-blocking, windowless — take up to
        ``max_width`` items that are *already* pending.  Returns []
        immediately when another worker is mid-drain (the thief must
        not race the home collector for a coalescing batch)."""
        if not self._drain_lock.acquire(blocking=False):
            return []
        try:
            with self._cond:
                cap = max_width if max_width is not None else self.max_width
                batch: List[PendingQuery] = []
                while self._items and len(batch) < cap:
                    batch.append(self._pop_best_locked())
                return batch
        finally:
            self._drain_lock.release()


__all__ = [
    "CoalescingQueue",
    "DeadlineExceededError",
    "PendingQuery",
    "ServiceClosedError",
    "ShedError",
    "SubmitOptions",
]
