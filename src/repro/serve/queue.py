"""Coalescing queue — the mechanism that turns concurrent interactive
users into Alg. 4 batches.

Independent analysts submitting within a few milliseconds of each
other would each pay a full plan search + gap training + merge launch.
The paper's batch optimizer exists precisely because those queries
share structure; ``CoalescingQueue.drain`` is where the sharing is
*harvested* at serve time: the worker blocks for one pending query,
then keeps collecting arrivals for a configurable time window (or
until a width cap), and hands the whole bundle back so the service can
fuse compatible specs into one ``submit_many`` call.

The window is a latency/throughput dial: every query waits at most
``window_s`` beyond its own execution time, and in exchange a burst of
n compatible queries rides one joint plan search, trains every shared
gap segment once, and merges in size-bucketed batched launches.
``window_s=0`` degenerates to FIFO serial service (drain returns
whatever is already queued, never waits for more).
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import List, Optional

from repro.api.spec import QuerySpec


@dataclass
class PendingQuery:
    """One enqueued spec awaiting execution."""

    spec: QuerySpec
    tenant: str
    future: "Future" = field(default_factory=Future)
    enqueued_at: float = field(default_factory=time.perf_counter)


class CoalescingQueue:
    """Thread-safe FIFO with windowed batch drains.

    window_s  : how long a drain keeps collecting after its first item
                (0 = take only what is already queued)
    max_width : hard cap on one drain's size — bounds both the fused
                batch's device footprint and the worst-case head-of-
                line wait a giant burst can impose
    """

    def __init__(self, window_s: float = 0.005, max_width: int = 16):
        if window_s < 0:
            raise ValueError(f"window_s must be >= 0, got {window_s}")
        if max_width < 1:
            raise ValueError(f"max_width must be >= 1, got {max_width}")
        self.window_s = window_s
        self.max_width = max_width
        self._q: "_queue.Queue[PendingQuery]" = _queue.Queue()
        self._closed = False
        # put's closed-check and enqueue must be atomic against
        # close(): otherwise a submitter preempted between them lands
        # an item in a queue whose worker already drained and exited,
        # hanging that future forever
        self._close_lock = threading.Lock()

    def __len__(self) -> int:
        return self._q.qsize()

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Refuse new work; queued items remain drainable.  Blocks
        until every in-flight ``put`` that already passed its closed
        check has enqueued, so callers may safely drain-then-join
        after this returns."""
        with self._close_lock:
            self._closed = True

    def put(self, item: PendingQuery) -> None:
        with self._close_lock:
            if self._closed:
                raise RuntimeError("queue is closed to new queries")
            self._q.put(item)

    def drain(self, timeout: float = 0.05) -> List[PendingQuery]:
        """One coalescing round.

        Blocks up to ``timeout`` for a first pending query ([] if none
        arrives — the worker's idle poll), then keeps collecting until
        the window closes or ``max_width`` is reached.  The window is
        anchored at the *first* item's drain, not at each arrival, so
        a steady trickle cannot hold a batch open forever.
        """
        try:
            first = self._q.get(timeout=timeout) if timeout > 0 \
                else self._q.get_nowait()
        except _queue.Empty:
            return []
        batch = [first]
        deadline = time.perf_counter() + self.window_s
        while len(batch) < self.max_width:
            remaining = deadline - time.perf_counter()
            try:
                batch.append(self._q.get(timeout=remaining)
                             if remaining > 0 else self._q.get_nowait())
            except _queue.Empty:
                break
        return batch


__all__ = ["CoalescingQueue", "PendingQuery"]
