"""Service-level telemetry — what a multi-tenant operator watches.

``QueryReport``/``BatchReport`` answer *one* query or batch;
``ServiceReport`` answers "how is the service doing": per-tenant queue
waits, coalesce widths and admission outcomes (``TenantStats``),
shared-cache traffic (the cross-session plan cache and the device
model LRU), per-backend latency windows and degradation levels
(``BackendSLO``), the coalescing queues' fusion efficiency and current
depths, tenant-lifecycle churn, and — when streaming ingestion and/or
speculation are attached — the pipeline's freshness/compaction
counters (``IngestReport``) and the speculative trainer's hit ledger
(``SpeculationReport``).  Snapshots are plain frozen dataclasses —
``MLegoService.report()`` reads the tenant/group counters under the
service stats lock (mutually consistent), while the shared-structure
counters (plan cache, backend stats, calibration size) are
point-in-time reads of independently-locked structures: each is valid,
but a query completing mid-snapshot can land between them.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Tuple

from repro.api.backend import BackendStats
from repro.ingest.pipeline import IngestReport
from repro.ingest.speculate import QueryLogEntry, SpeculationReport
from repro.serve.breaker import BreakerSnapshot
from repro.serve.slo import BackendSLO


@dataclass(frozen=True)
class TenantStats:
    """One tenant's view of the service.

    queue_wait_s sums the time each of the tenant's queries sat in the
    coalescing queue before its group started executing (the price of
    the coalescing window); width_sum sums the widths of the groups
    its queries rode in, so ``mean_width`` > 1 means this tenant's
    traffic actually fused with other queries.  ``shed`` and
    ``deadline_rejected`` count queries admission control refused
    (they are *not* in ``queries``, which counts answered/failed
    executions); ``degraded_queries`` counts answers produced under a
    non-zero SLO degradation level; ``evictions`` counts idle-TTL
    session evictions (the session revives on next use with its RNG
    stream intact, so this is lifecycle telemetry, not data loss).
    """

    tenant: str
    queries: int = 0
    errors: int = 0
    queue_wait_s: float = 0.0
    max_queue_wait_s: float = 0.0
    coalesced_queries: int = 0      # answered inside a width>1 group
    width_sum: int = 0
    max_width: int = 0
    plan_cached_queries: int = 0    # answered off the shared plan cache
    shed: int = 0                   # rejected: queue full / waited too long
    deadline_rejected: int = 0      # rejected: deadline_s elapsed queued
    degraded_queries: int = 0       # answered at degradation level > 0
    evictions: int = 0              # idle-TTL session evictions

    @property
    def mean_queue_wait_s(self) -> float:
        return self.queue_wait_s / self.queries if self.queries else 0.0

    @property
    def mean_width(self) -> float:
        return self.width_sum / self.queries if self.queries else 0.0

    def absorb(self, *, wait_s: float, width: int, plan_cached: bool,
               error: bool = False, degraded: bool = False) -> "TenantStats":
        """One answered (or failed) query folded in; returns the new
        frozen snapshot."""
        return replace(
            self,
            queries=self.queries + 1,
            errors=self.errors + (1 if error else 0),
            queue_wait_s=self.queue_wait_s + wait_s,
            max_queue_wait_s=max(self.max_queue_wait_s, wait_s),
            coalesced_queries=self.coalesced_queries + (1 if width > 1 else 0),
            width_sum=self.width_sum + width,
            max_width=max(self.max_width, width),
            plan_cached_queries=self.plan_cached_queries
            + (1 if plan_cached else 0),
            degraded_queries=self.degraded_queries + (1 if degraded else 0))

    def bump(self, **deltas: int) -> "TenantStats":
        """Counter increments (shed / deadline_rejected / evictions)."""
        return replace(self, **{k: getattr(self, k) + v
                                for k, v in deltas.items()})


@dataclass(frozen=True)
class ServiceReport:
    """Point-in-time snapshot of the whole service.

    ``groups``/``coalesced_groups`` count drained execution groups
    (a group is one ``submit_many`` launch when its width > 1);
    ``plan_cache_hits``/``misses`` read the *shared* plan cache, so
    they include hits one tenant earned from another tenant's
    searches; ``backend`` is the shared execution backend's cumulative
    counters (device-cache traffic across every session).

    Hardening telemetry: ``shed``/``deadline_rejected`` are service-
    wide admission rejections, ``bisect_retries`` the fused groups
    re-split after a failed ``submit_many`` (each split halves the
    group — O(log n) per malformed spec), ``queue_depth`` the current
    pending count per worker pool, ``slo`` each backend's sliding latency
    window and active degradation level, ``tenant_evictions`` the
    idle-TTL lifecycle churn and ``active_sessions`` the tenants
    currently resident.

    Fault-tolerance telemetry: ``breaker`` is each backend's circuit-
    breaker snapshot (state, windowed error rate, lifetime opens),
    ``breaker_reroutes`` counts queries routed to a fallback pool
    because their backend's breaker was open (they were answered, not
    shed), and ``retries`` is the shared ``RetryPolicy``'s per-site
    retry ledger (e.g. ``{"backend.merge.device": 3}`` means three
    transient merge faults were absorbed invisibly to clients).
    """

    tenants: Dict[str, TenantStats] = field(default_factory=dict)
    queries: int = 0
    errors: int = 0
    groups: int = 0
    coalesced_groups: int = 0
    max_coalesce_width: int = 0
    width_sum: int = 0
    plan_cache_hits: int = 0
    plan_cache_misses: int = 0
    plan_cache_entries: int = 0
    backend: BackendStats = field(default_factory=BackendStats)
    calibration_samples: int = 0
    store_bytes: int = 0
    shed: int = 0
    deadline_rejected: int = 0
    bisect_retries: int = 0
    degraded_queries: int = 0
    tenant_evictions: int = 0
    active_sessions: int = 0
    queue_depth: Dict[str, int] = field(default_factory=dict)
    slo: Dict[str, BackendSLO] = field(default_factory=dict)
    breaker: Dict[str, BreakerSnapshot] = field(default_factory=dict)
    breaker_reroutes: int = 0
    retries: Dict[str, int] = field(default_factory=dict)
    # JSON snapshot of the service metrics registry (same objects the
    # Prometheus exposition renders, so the two cannot drift); see
    # ``repro.obs.metrics.MetricsRegistry.snapshot``
    metrics: Optional[Dict[str, Any]] = None
    # None unless the corresponding subsystem is attached
    ingest: Optional[IngestReport] = None
    speculation: Optional[SpeculationReport] = None

    @property
    def mean_coalesce_width(self) -> float:
        """Mean width over *groups* (1.0 = nothing ever fused)."""
        return self.width_sum / self.groups if self.groups else 0.0

    @property
    def coalesce_rate(self) -> float:
        """Fraction of queries answered inside a width>1 group."""
        if not self.queries:
            return 0.0
        return sum(t.coalesced_queries for t in self.tenants.values()) \
            / self.queries

    @property
    def submitted(self) -> int:
        """Everything that passed the front door: answered + failed +
        admission-rejected."""
        return self.queries + self.shed + self.deadline_rejected

    @property
    def shed_rate(self) -> float:
        """Fraction of submitted queries admission control refused."""
        total = self.submitted
        return (self.shed + self.deadline_rejected) / total if total else 0.0

    @property
    def degraded_frac(self) -> float:
        """Fraction of answered queries produced at level > 0."""
        return self.degraded_queries / self.queries if self.queries else 0.0

    def tenant(self, name: str) -> TenantStats:
        return self.tenants.get(name, TenantStats(tenant=name))


__all__ = ["BackendSLO", "BreakerSnapshot", "IngestReport",
           "QueryLogEntry", "ServiceReport", "SpeculationReport",
           "TenantStats"]
