"""SLO feedback — sliding latency percentiles driving plan degradation.

The paper's merge approximation is a quality/latency dial: α trades
plan accuracy (Eq. 2's loss term) against time.  Under overload the
dial should turn itself: the service tracks a sliding window of
client-observed latencies (enqueue → answer) per execution backend,
and when the window's p95 blows past the configured SLO it *degrades*
new queries — first by scaling their α toward the fast end, then by
restricting to plan-cache-only / α=0 plans and pausing speculative
training, so capacity is spent answering queries rather than
polishing them.  The degradation level applied to every answered
query lands on ``QueryReport.degraded`` (0 = full quality).

``LatencyTracker`` is the measurement half: a bounded deque of recent
latencies with percentile reads.  ``SLOPolicy`` is the decision half:
pure (p95, sample count) → level, so tests can pin it without traffic.

``SLOPolicy.level`` duck-types its tracker argument — anything with
``len()`` and ``.p95`` qualifies.  The service now feeds it a
``repro.obs.metrics.HistogramView`` over the shared
``mlego_serve_latency_seconds`` histogram's sliding window (one
``observe()`` feeds both the Prometheus exposition buckets and this
control loop), keeping ``LatencyTracker`` as the standalone
implementation for callers without a registry.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Deque, Tuple


class LatencyTracker:
    """Sliding window of observed latencies (seconds), thread-safe.

    The window is bounded by count, not time: under overload (the only
    regime where the SLO loop matters) samples arrive fast and the
    window spans recent seconds; at idle a stale window merely keeps
    the last known level until fresh traffic updates it.
    """

    def __init__(self, window: int = 256):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self._samples: Deque[float] = deque(maxlen=window)
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._samples)

    def observe(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(float(seconds))

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile of the current window (0.0 when
        empty — callers gate on ``len`` via the policy's min_samples)."""
        with self._lock:
            if not self._samples:
                return 0.0
            data = sorted(self._samples)
        rank = min(int(p / 100.0 * len(data)), len(data) - 1)
        return data[rank]

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p95(self) -> float:
        return self.percentile(95.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)


@dataclass(frozen=True)
class SLOPolicy:
    """Degradation decision: window p95 vs the target, in levels.

    p95_slo_s    : the latency objective the operator promised
    min_samples  : below this window size the level is always 0 (no
                   degradation off a cold or trivial window)
    degrade_at   : level 1 when p95 > degrade_at × SLO  (α halved)
    heavy_at     : level 2 when p95 > heavy_at × SLO    (α → 0 unless
                   the original-α plan is already cached; speculation
                   paused)
    severe_at    : level 3 when p95 > severe_at × SLO   (as level 2 —
                   reserved headroom for harsher measures; reported
                   distinctly so operators see how deep overload runs)
    """

    p95_slo_s: float
    min_samples: int = 8
    degrade_at: float = 1.0
    heavy_at: float = 2.0
    severe_at: float = 4.0
    # α multiplier per level; beyond the tuple, the last entry applies
    alpha_factors: Tuple[float, ...] = (1.0, 0.5, 0.0, 0.0)
    pause_speculation_at: int = 2

    def __post_init__(self) -> None:
        if self.p95_slo_s <= 0:
            raise ValueError(f"p95_slo_s must be > 0, got {self.p95_slo_s}")
        if not (self.degrade_at <= self.heavy_at <= self.severe_at):
            raise ValueError("degradation thresholds must be ordered: "
                             "degrade_at <= heavy_at <= severe_at")

    def level(self, tracker: LatencyTracker) -> int:
        if len(tracker) < self.min_samples:
            return 0
        ratio = tracker.p95 / self.p95_slo_s
        if ratio > self.severe_at:
            return 3
        if ratio > self.heavy_at:
            return 2
        if ratio > self.degrade_at:
            return 1
        return 0

    def alpha_factor(self, level: int) -> float:
        if level <= 0:
            return 1.0
        idx = min(level, len(self.alpha_factors) - 1)
        return self.alpha_factors[idx]


@dataclass(frozen=True)
class BackendSLO:
    """One backend's latency window, as ``ServiceReport`` snapshots it."""

    p50_s: float = 0.0
    p95_s: float = 0.0
    p99_s: float = 0.0
    samples: int = 0
    level: int = 0


__all__ = ["BackendSLO", "LatencyTracker", "SLOPolicy"]
