"""MLego serving layer — multi-tenant queries over one shared store.

    from repro.serve import MLegoService
    from repro.api import Interval, QuerySpec

    svc = MLegoService(corpus, cfg, backend="device", max_queue=256,
                       slo_p95_s=0.25, tenant_ttl_s=600.0)
    fut = svc.submit(QuerySpec(sigma=Interval(0.0, 500.0)), tenant="ana",
                     deadline_s=1.0, priority=1)
    report = fut.result()

One ``ModelStore``, one execution backend per *name* (one device model
LRU), one cross-session ``PlanCache``, one calibration log — shared by
every tenant.  Each backend name gets its own worker pool (host and
device traffic never serialize against each other; idle workers steal
across pools), concurrent specs coalesce into Alg. 4 batches inside a
configurable time/size window, bounded queues shed load with typed
``ShedError``/``DeadlineExceededError`` rejections, a sliding-latency
SLO loop degrades plan quality (effective α) under overload, and idle
tenant sessions are evicted on a TTL and revived with their RNG stream
intact.  ``attach_ingest``/``attach_speculator`` add streaming
ingestion and workload-driven gap pre-training (``repro.ingest``).
Per-backend circuit breakers (``repro.serve.breaker``) quarantine a
backend whose error window trips and reroute its traffic down the
fallback chain until a half-open probe re-admits it.  See
``repro.api`` README's "Serving layer", "Streaming ingestion &
speculation" and "Failure semantics" sections.
"""
from repro.serve.breaker import (
    CLOSED,
    HALF_OPEN,
    OPEN,
    BreakerPolicy,
    BreakerSnapshot,
    CircuitBreaker,
)
from repro.serve.queue import (
    CoalescingQueue,
    DeadlineExceededError,
    PendingQuery,
    ServiceClosedError,
    ShedError,
    SubmitOptions,
)
from repro.serve.reports import (
    BackendSLO,
    IngestReport,
    QueryLogEntry,
    ServiceReport,
    SpeculationReport,
    TenantStats,
)
from repro.serve.service import DEFAULT_TENANT, MLegoService
from repro.serve.slo import LatencyTracker, SLOPolicy

__all__ = [
    "BackendSLO",
    "BreakerPolicy",
    "BreakerSnapshot",
    "CLOSED",
    "CircuitBreaker",
    "CoalescingQueue",
    "DEFAULT_TENANT",
    "DeadlineExceededError",
    "HALF_OPEN",
    "OPEN",
    "IngestReport",
    "LatencyTracker",
    "MLegoService",
    "PendingQuery",
    "QueryLogEntry",
    "SLOPolicy",
    "ServiceClosedError",
    "ServiceReport",
    "ShedError",
    "SpeculationReport",
    "SubmitOptions",
    "TenantStats",
]
