"""MLego serving layer — multi-tenant queries over one shared store.

    from repro.serve import MLegoService
    from repro.api import Interval, QuerySpec

    svc = MLegoService(corpus, cfg, backend="device")
    fut = svc.submit(QuerySpec(sigma=Interval(0.0, 500.0)), tenant="ana")
    report = fut.result()

One ``ModelStore``, one execution backend (one device model LRU), one
cross-session ``PlanCache``, one calibration log — shared by every
tenant; concurrent specs coalesce into Alg. 4 batches inside a
configurable time/size window.  ``attach_ingest``/``attach_speculator``
add streaming ingestion and workload-driven gap pre-training
(``repro.ingest``).  See ``repro.api`` README's "Serving layer" and
"Streaming ingestion & speculation" sections.
"""
from repro.serve.queue import CoalescingQueue, PendingQuery
from repro.serve.reports import (
    IngestReport,
    QueryLogEntry,
    ServiceReport,
    SpeculationReport,
    TenantStats,
)
from repro.serve.service import DEFAULT_TENANT, MLegoService

__all__ = [
    "CoalescingQueue",
    "DEFAULT_TENANT",
    "IngestReport",
    "MLegoService",
    "PendingQuery",
    "QueryLogEntry",
    "ServiceReport",
    "SpeculationReport",
    "TenantStats",
]
