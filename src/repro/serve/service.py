"""``MLegoService`` — the multi-tenant front door over one shared store.

``MLegoSession`` is a single-caller object: its plan cache, device
model LRU, and calibration log are private, so every concurrent
analyst over the same materialized capital rebuilds all three.  The
service owns exactly one of each — one ``ModelStore``, one execution
backend per *name* (one device LRU), one store-homed ``PlanCache``,
one cost provider (one calibration log) — and hands every tenant a
session wired to the shared set:

    svc = MLegoService(corpus, cfg, backend="device", window_s=0.005,
                       max_queue=256, slo_p95_s=0.25, tenant_ttl_s=600.0)
    svc.train_range(0.0, 500.0)                   # shared capital
    fut = svc.submit(QuerySpec(sigma=Interval(0.0, 1000.0)),
                     tenant="ana", deadline_s=1.0, priority=1)
    report = fut.result()                         # a QueryReport

``submit`` is asynchronous and keyword-only past the spec: specs land
on a per-backend **coalescing queue** and that backend's **worker
pool** drains it in time/size windows — host and device traffic never
serialize against each other, and a pool's extra workers steal pending
items from other pools when their own queue is idle.  Specs that
drained together and are compatible — same trainer kind, same
execution backend; α may differ, the session's α-split machinery
handles it — are fused into one ``submit_many`` call, so independent
interactive users ride Alg. 4's joint planning (shared gap segments
trained once) and the ragged segmented merge launch instead of
issuing n serial single-query merges.  A group whose fused execution
fails is **bisected**: each half retries fused, recursively, so one
malformed spec is isolated in O(log n) retries while its healthy
window neighbors keep their shared-segment training — not the n
serial re-executions a query-by-query fallback would pay
(``ServiceReport.bisect_retries`` counts the splits).

Production hardening:

  * **Admission control** — ``max_queue`` bounds each pool's queue
    (full ⇒ ``ShedError`` at the submitter, or displacement of the
    youngest lower-priority pending query); ``deadline_s`` /
    ``max_queue_wait_s`` expire queued queries with typed
    ``DeadlineExceededError`` / ``ShedError`` *before* execution burns
    capacity on answers nobody is waiting for.
  * **SLO feedback** — a sliding p50/p95/p99 latency window per
    backend (``slo_p95_s`` or a full ``SLOPolicy``) degrades new
    queries under overload: effective α is scaled down (level 1), then
    forced to the fast end unless the original-α plan is already
    cached, with speculative training paused (level ≥ 2).  The level
    is recorded on every ``QueryReport.degraded``.
  * **Tenant lifecycle** — ``tenant_ttl_s`` evicts idle tenant
    sessions (their stats survive); a revived tenant continues its
    *exact* RNG stream (the session key is stashed at eviction), so
    results are reproducible across eviction boundaries.

Cross-session reuse is the point: tenant B's repeated query over a
plan tenant A already searched reports ``plan_cached=True``, and its
merge reads A's device-resident model parameters as cache hits.
Per-tenant queue waits, coalesce widths and admission outcomes land on
``ServiceReport`` (``svc.report()``).

The service is also the host for the streaming subsystems
(``repro.ingest``): ``attach_ingest`` wires an ``IngestPipeline`` to
the shared store — grown corpus snapshots re-home every tenant session
*before* slice models land, so a query over freshly ingested documents
is answered with no manual store mutation — and ``attach_speculator``
starts a ``SpeculativeTrainer`` over the service's query log (every
answered query is logged with its σ/kind/α and arrival time).  Both
are drained and joined by ``close()``.  Answered plans are checked
against the speculator's trained set, so speculative hits surface on
the report.
"""
from __future__ import annotations

import threading
import time
import warnings
import zlib
from collections import deque
from concurrent.futures import Future
from dataclasses import replace as _dc_replace
from typing import Deque, Dict, List, Optional, Tuple, Union

from repro.api.backend import ExecutionBackend, make_backend
from repro.api.planner import PlanCache
from repro.api.session import MLegoSession
from repro.api.spec import QuerySpec
from repro.api.trainers import resolve_kind
from repro.configs.lda_default import LDAConfig
from repro.core.cost import CostProvider
from repro.core.errors import (DeviceLostError, ExecutionError, RetryPolicy)
from repro.core.lda import MaterializedModel
from repro.core.store import ModelStore
from repro.data.corpus import Corpus
from repro.serve.breaker import (OPEN, BreakerPolicy, CircuitBreaker)
from repro.testing.faults import maybe_fail
from repro.ingest.compaction import CompactionPolicy, Compactor
from repro.ingest.pipeline import IngestPipeline
from repro.ingest.speculate import QueryLogEntry, SpeculativeTrainer
from repro.serve.queue import (
    CoalescingQueue,
    DeadlineExceededError,
    PendingQuery,
    ServiceClosedError,
    ShedError,
    SubmitOptions,
)
from repro.obs.metrics import HistogramView, MetricsRegistry
from repro.obs.trace import Tracer
from repro.serve.reports import BackendSLO, ServiceReport, TenantStats
from repro.serve.slo import SLOPolicy

_BREAKER_STATE_CODE = {"closed": 0, "half_open": 1, "open": 2}

DEFAULT_TENANT = "default"


def _resolve(future: "Future", result) -> None:
    """Set a result, tolerating futures a client already finalized —
    the worker must never die over one future's state."""
    try:
        future.set_result(result)
    except Exception:
        pass


def _reject(future: "Future", exc: BaseException) -> None:
    try:
        future.set_exception(
            exc if isinstance(exc, Exception) else RuntimeError(repr(exc)))
    except Exception:
        pass


class _Pool:
    """One backend *instance*'s worker pool: a coalescing queue plus
    its drain threads.  Worker 0 is the *home* worker (drains only this
    queue — a stall in another pool can never capture it); workers
    1..n-1 steal from sibling pools when this queue is idle.  ``name``
    is the display label (the backend's name, ``#k``-suffixed when two
    distinct instances share one)."""

    def __init__(self, name: str, queue: CoalescingQueue):
        self.name = name
        self.queue = queue
        self.threads: List[threading.Thread] = []


class MLegoService:
    """One shared store, many tenants, per-backend worker pools.

    corpus/cfg       : the Def. 1 D and F every tenant shares
    store            : shared ``ModelStore`` (fresh one if omitted)
    kind             : default trainer kind for specs that name none
    backend          : the *shared* execution backend ("host"/"device"
                       or an instance) — one device LRU for everyone
    cost             : shared cost provider ("analytic"/"calibrated"/
                       instance); a calibrated provider accumulates one
                       calibration log across all tenants
    calibration_path : sidecar to warm-start from and to merge-save
                       into on ``close()``
    window_s         : coalescing window — max extra latency a query
                       pays to let neighbors fuse with it
    max_width        : cap on one coalesced group's size
    seed             : base RNG seed; each tenant's session derives a
                       stable per-tenant stream from it
    workers_per_pool : drain threads per backend pool (>= 1; worker 0
                       never steals, the rest do)
    pool_per_backend : False collapses every backend onto one pool/one
                       queue (the pre-hardening single-loop topology —
                       kept as a baseline and migration path)
    max_queue        : bound on each pool's pending queries (None =
                       unbounded); see ``repro.serve.queue`` for the
                       full-queue displacement/rejection rule
    slo_p95_s        : p95 latency objective per backend — enables the
                       SLO degradation loop (or pass ``slo=`` a full
                       ``SLOPolicy`` for custom thresholds)
    tenant_ttl_s     : idle TTL for tenant sessions (None = immortal);
                       evicted tenants revive on next use with their
                       RNG stream intact
    """

    def __init__(self, corpus: Corpus, cfg: LDAConfig, *,
                 store: Optional[ModelStore] = None,
                 kind: str = "vb",
                 backend: Union[str, ExecutionBackend] = "host",
                 cost: Union[CostProvider, str, None] = None,
                 calibration_path: Optional[str] = None,
                 window_s: float = 0.005, max_width: int = 16,
                 plan_cache_entries: int = 1024,
                 seed: int = 0, poll_s: float = 0.02,
                 query_log_entries: int = 512,
                 workers_per_pool: int = 2,
                 pool_per_backend: bool = True,
                 max_queue: Optional[int] = None,
                 slo_p95_s: Optional[float] = None,
                 slo: Optional[SLOPolicy] = None,
                 slo_window: int = 256,
                 tenant_ttl_s: Optional[float] = None,
                 breaker: Optional[BreakerPolicy] = None,
                 retry: Optional[RetryPolicy] = None,
                 tracer: Optional[Tracer] = None,
                 registry: Optional[MetricsRegistry] = None,
                 profile: bool = False):
        if workers_per_pool < 1:
            raise ValueError(
                f"workers_per_pool must be >= 1, got {workers_per_pool}")
        if tenant_ttl_s is not None and tenant_ttl_s < 0:
            raise ValueError(
                f"tenant_ttl_s must be >= 0, got {tenant_ttl_s}")
        self.corpus = corpus
        self.cfg = cfg
        self.store = store if store is not None else ModelStore()
        self.kind = resolve_kind(kind)
        self._profile = profile
        self.backend = make_backend(backend, profile=profile) \
            if isinstance(backend, str) else backend
        self.plan_cache = PlanCache(max_entries=plan_cache_entries)
        self.cost = MLegoSession._make_cost(cost, cfg, calibration_path)
        self.calibration_path = calibration_path
        self._seed = seed
        self._poll_s = poll_s
        self._window_s = window_s
        self._max_width = max_width
        self._max_queue = max_queue
        self.workers_per_pool = workers_per_pool
        self.pool_per_backend = pool_per_backend
        self.tenant_ttl_s = tenant_ttl_s
        if slo is not None:
            self._slo_policy: Optional[SLOPolicy] = slo
        else:
            self._slo_policy = SLOPolicy(p95_slo_s=slo_p95_s) \
                if slo_p95_s is not None else None
        self._slo_window = slo_window
        # observability: one tracer (shared with every tenant session,
        # so worker-thread spans land in one exportable buffer) and one
        # metrics registry (the single source of truth for the
        # service's counters — ``report()`` reads the same objects the
        # Prometheus exposition renders)
        self.tracer = tracer if tracer is not None else Tracer(
            capacity=65536)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._build_metrics()
        # one retry policy shared by every tenant session, so the
        # report's per-site retry counters aggregate service-wide
        self.retry = retry if retry is not None else RetryPolicy()
        # per-backend-identity circuit breakers (lazily built, like
        # pools); the transition hook mirrors breaker state into the
        # backend quarantine flag so sessions' fallback chains and the
        # service's reroutes agree on who is healthy
        self._breaker_policy = breaker if breaker is not None \
            else BreakerPolicy()
        self._breakers: Dict[int, CircuitBreaker] = {}
        self._breaker_names: Dict[int, str] = {}
        self._breaker_lock = threading.Lock()

        self._sessions: Dict[str, MLegoSession] = {}
        self._session_lock = threading.RLock()
        # tenant lifecycle: last-use stamps, stashed RNG keys of
        # evicted sessions (stream continuity on revival), in-flight
        # query counts (a tenant with queued/executing work is never
        # evicted — its session object is being used right now)
        self._last_seen: Dict[str, float] = {}
        self._evicted_keys: Dict[str, object] = {}
        self._inflight: Dict[str, int] = {}
        self._last_sweep = time.monotonic()
        # corpus snapshot epoch: revived/new sessions inherit it so a
        # plan cached before ingestion growth (epoch-0 keys) can never
        # be served to a session created after the growth
        self._data_epoch = 0
        # shared per-name backends for specs naming a non-default
        # backend — one device LRU per backend *name*, not per tenant
        self._extra_backends: Dict[str, ExecutionBackend] = {}

        # rolling per-tenant query log — the speculator's ore
        self._query_log: Deque[QueryLogEntry] = deque(
            maxlen=query_log_entries)
        self._ingest: Optional[IngestPipeline] = None
        self._speculator: Optional[SpeculativeTrainer] = None

        self._stats_lock = threading.Lock()
        self._tenants: Dict[str, TenantStats] = {}
        # width aggregates stay plain ints under the stats lock (they
        # pair with the TenantStats updates); everything countable
        # lives natively in the metrics registry (see _build_metrics)
        self._width_sum = self._max_coalesce_width = 0

        self._closed = False
        self._stop = threading.Event()
        # keyed by backend instance identity (or "*" single-loop)
        self._pools: Dict[object, _Pool] = {}
        self._pool_lock = threading.Lock()
        self._pool_for(self.backend)            # default pool, eagerly

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    def _build_metrics(self) -> None:
        """Register the service's metric families.

        *Native* counters are the service's only copy of the number —
        ``report()`` reads them back, so the Prometheus exposition and
        the ``ServiceReport`` can never disagree.  Structures with
        their own locking discipline (``BackendStats``, breakers, the
        retry ledger, caches) stay the writers and are *mirrored* into
        the registry by a pre-scrape callback reading the same live
        sources ``report()`` reads.
        """
        reg = self.registry
        c, g, h = reg.counter, reg.gauge, reg.histogram
        self._m_queries = c("mlego_queries_total",
                            "Answered or failed query executions")
        self._m_errors = c("mlego_query_errors_total",
                           "Query executions that raised")
        self._m_groups = c("mlego_groups_total",
                           "Drained execution groups")
        self._m_coalesced = c("mlego_coalesced_groups_total",
                              "Groups of width > 1 (fused submit_many)")
        self._m_shed = c("mlego_shed_total",
                         "Queries refused: full queue, displacement, "
                         "or overwait")
        self._m_deadline = c("mlego_deadline_rejected_total",
                             "Queries expired in queue past deadline_s")
        self._m_degraded = c("mlego_degraded_queries_total",
                             "Answers produced under SLO degradation",
                             labelnames=("level",))
        self._m_evictions = c("mlego_tenant_evictions_total",
                              "Idle-TTL tenant session evictions")
        self._m_bisect = c("mlego_bisect_retries_total",
                           "Fused groups split after a failed batch")
        self._m_reroutes = c("mlego_breaker_reroutes_total",
                             "Queries routed to a fallback pool by an "
                             "open breaker")
        self._m_transitions = c("mlego_breaker_transitions_total",
                                "Breaker state transitions",
                                labelnames=("backend", "to"))
        self._m_latency = h("mlego_serve_latency_seconds",
                            "Client-observed latency (enqueue to answer)",
                            labelnames=("backend",),
                            window=self._slo_window)
        # mirrored families (synced by _sync_mirrors at scrape time)
        self._m_queue_depth = g("mlego_queue_depth",
                                "Pending queries per worker pool",
                                labelnames=("pool",))
        self._m_plan_hits = c("mlego_plan_cache_hits_total",
                              "Shared plan cache hits")
        self._m_plan_misses = c("mlego_plan_cache_misses_total",
                                "Shared plan cache misses")
        self._m_plan_entries = g("mlego_plan_cache_entries",
                                 "Shared plan cache residency")
        self._m_store_bytes = g("mlego_store_bytes",
                                "Materialized model store size")
        self._m_cal_samples = g("mlego_calibration_samples",
                                "Cost-calibration log size")
        self._m_cal_refits = c("mlego_calibration_refits_total",
                               "Cost-model refit generations")
        self._m_active = g("mlego_active_sessions",
                           "Tenant sessions currently resident")
        self._m_retries = c("mlego_retries_total",
                            "Transient-failure retries per site",
                            labelnames=("site",))
        self._m_hit_bytes = c("mlego_cache_hit_bytes_total",
                              "Bytes read from the device model cache",
                              labelnames=("backend",))
        self._m_miss_bytes = c("mlego_cache_miss_bytes_total",
                               "Bytes uploaded host-to-device on cache "
                               "misses", labelnames=("backend",))
        self._m_cache_evict = c("mlego_cache_evictions_total",
                                "Device model cache LRU evictions",
                                labelnames=("backend",))
        self._m_pad_rows = c("mlego_pad_rows_total",
                             "Zero-weight rows in batched merge launches",
                             labelnames=("backend",))
        self._m_resident = g("mlego_cache_resident_bytes",
                             "Device model cache residency",
                             labelnames=("backend",))
        self._m_breaker_state = g("mlego_breaker_state",
                                  "Breaker state (0 closed, 1 half-open, "
                                  "2 open)", labelnames=("backend",))
        self._m_breaker_opens = c("mlego_breaker_opens_total",
                                  "Lifetime breaker open transitions",
                                  labelnames=("backend",))
        self._m_width_sum = c("mlego_coalesce_width_sum_total",
                              "Sum of executed group widths")
        self._m_max_width = g("mlego_max_coalesce_width",
                              "Widest group executed so far")
        reg.add_callback(self._sync_mirrors)

    def _sync_mirrors(self) -> None:
        """Pre-scrape sync: copy externally-owned counters into their
        registry mirrors.  Reads exactly the live structures
        ``report()`` reads, so a quiesced service exposes identical
        numbers on both surfaces."""
        for p in self._pools_snapshot():
            self._m_queue_depth.set(len(p.queue), pool=p.name)
        self._m_plan_hits.set_floor(self.plan_cache.hits)
        self._m_plan_misses.set_floor(self.plan_cache.misses)
        self._m_plan_entries.set(len(self.plan_cache))
        self._m_store_bytes.set(self.store.nbytes())
        cal = getattr(self.cost, "calibration", None)
        self._m_cal_samples.set(len(cal) if cal is not None else 0)
        self._m_cal_refits.set_floor(getattr(self.cost, "version", 0))
        with self._session_lock:
            self._m_active.set(len(self._sessions))
            backends = dict(self._extra_backends)
        backends.setdefault(self.backend.name, self.backend)
        for site, n in self.retry.snapshot().items():
            self._m_retries.set_floor(n, site=site)
        for name, b in backends.items():
            st = b.stats
            self._m_hit_bytes.set_floor(st.cache_hit_bytes, backend=name)
            self._m_miss_bytes.set_floor(st.cache_miss_bytes, backend=name)
            self._m_cache_evict.set_floor(st.cache_evictions, backend=name)
            self._m_pad_rows.set_floor(st.pad_rows, backend=name)
            self._m_resident.set(st.cache_resident_bytes, backend=name)
        with self._breaker_lock:
            blist = [(self._breaker_names[k], cb)
                     for k, cb in self._breakers.items()]
        for name, cb in blist:
            snap = cb.snapshot()
            self._m_breaker_state.set(
                _BREAKER_STATE_CODE.get(snap.state, -1), backend=name)
            self._m_breaker_opens.set_floor(snap.opens, backend=name)
        with self._stats_lock:
            self._m_width_sum.set_floor(self._width_sum)
            self._m_max_width.set(self._max_coalesce_width)

    def metrics_text(self) -> str:
        """Prometheus text exposition (format 0.0.4) of the registry —
        the scrape endpoint's payload."""
        return self.registry.exposition()

    def export_trace(self, path: str) -> None:
        """Write the tracer's ring buffer as Chrome trace-event JSON
        (loads in Perfetto / ``chrome://tracing``)."""
        self.tracer.export_chrome(path)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def __enter__(self) -> "MLegoService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Stop accepting queries, stop speculation, drain the ingest
        builder (the open partial slice is built — append-only means it
        can never grow again), drain everything pending, join every
        pool's workers, and (for a calibrated provider with a sidecar
        path) merge-save the shared calibration log."""
        if self._speculator is not None:
            self._speculator.close()
        if self._ingest is not None:
            self._ingest.close()
        first = not self._closed
        self._closed = True
        with self._pool_lock:
            pools = list(self._pools.values())
        for p in pools:
            p.queue.close()
        self._stop.set()
        for p in pools:
            for t in p.threads:
                if t.is_alive():
                    t.join()
        if first and self.calibration_path is not None \
                and getattr(self.cost, "calibration", None) is not None:
            self.save_calibration()

    # ------------------------------------------------------------------
    # worker pools
    # ------------------------------------------------------------------
    def _pool_for(self, backend: ExecutionBackend) -> _Pool:
        """The worker pool owning this backend *instance*'s traffic
        (one shared pool when ``pool_per_backend=False``), created
        lazily — a service that never sees device specs never starts
        device workers.  Keyed by instance identity, not ``.name``:
        two distinct backends that happen to share a name (a custom
        instance passed at construction alongside a factory-made
        sibling) must never share a queue, or one's stall would
        head-of-line block the other's traffic."""
        key: object = id(backend) if self.pool_per_backend else "*"
        with self._pool_lock:
            pool = self._pools.get(key)
            if pool is None:
                if self._closed:
                    raise ServiceClosedError("service is closed")
                name = backend.name if self.pool_per_backend else "*"
                taken = {p.name for p in self._pools.values()}
                if name in taken:
                    dups = sum(1 for p in self._pools.values()
                               if p.name.split("#")[0] == name)
                    name = f"{name}#{dups + 1}"
                pool = _Pool(name, CoalescingQueue(
                    window_s=self._window_s, max_width=self._max_width,
                    max_queue=self._max_queue, on_shed=self._note_displaced))
                self._pools[key] = pool
                for i in range(self.workers_per_pool):
                    t = threading.Thread(
                        target=self._run,
                        args=(pool, i > 0 and self.pool_per_backend),
                        name=f"mlego-serve-{name}-{i}", daemon=True)
                    pool.threads.append(t)
                    t.start()
            return pool

    def _pools_snapshot(self) -> List[_Pool]:
        with self._pool_lock:
            return list(self._pools.values())

    # ------------------------------------------------------------------
    # tenants
    # ------------------------------------------------------------------
    def _tenant_seed(self, tenant: str) -> int:
        # stable across runs and processes (no hash randomization)
        return (self._seed + zlib.crc32(tenant.encode("utf-8"))) & 0x7FFFFFFF

    def session(self, tenant: str = DEFAULT_TENANT) -> MLegoSession:
        """The tenant's session — lazily built, permanently wired to
        the shared store/backend/plan-cache/cost provider.  Usable
        directly for synchronous work (capital building, debugging);
        interactive traffic should go through ``submit``.  A tenant
        evicted by the idle TTL revives here with its stashed RNG key,
        so its result stream continues exactly where eviction cut it.
        """
        with self._session_lock:
            sess = self._sessions.get(tenant)
            if sess is None:
                sess = MLegoSession(
                    self.corpus, self.cfg, store=self.store,
                    cost=self.cost, kind=self.kind,
                    seed=self._tenant_seed(tenant),
                    backend=self.backend, plan_cache=self.plan_cache,
                    retry=self.retry, tracer=self.tracer)
                # the breaker feed rides the session's outcome hook, so
                # *direct* session use (tenants bypassing the front
                # door) counts toward backend health exactly like
                # worker-pool traffic
                sess.on_outcome = self._session_outcome
                for b in self._extra_backends.values():
                    sess.adopt_backend(b)
                saved = self._evicted_keys.pop(tenant, None)
                if saved is not None:
                    # RNG-stream continuity across eviction: the fresh
                    # session resumes the evicted session's key
                    with sess._key_lock:
                        sess._key = saved
                sess._data_epoch = self._data_epoch
                self._sessions[tenant] = sess
            self._last_seen[tenant] = time.monotonic()
            return sess

    def tenants(self) -> Tuple[str, ...]:
        with self._session_lock:
            return tuple(sorted(self._sessions))

    def evict_idle(self, idle_s: Optional[float] = None) -> int:
        """Evict tenant sessions idle longer than ``idle_s`` (defaults
        to the service's ``tenant_ttl_s``); returns the count.  A
        tenant with queued or executing work is skipped.  The evicted
        session's RNG key is stashed so revival continues its stream;
        its ``TenantStats`` survive (eviction is lifecycle, not data
        loss)."""
        ttl = idle_s if idle_s is not None else self.tenant_ttl_s
        if ttl is None:
            raise ValueError("no TTL: pass idle_s= or construct the "
                             "service with tenant_ttl_s=")
        now = time.monotonic()
        evicted = 0
        with self._session_lock:
            for tenant in list(self._sessions):
                if now - self._last_seen.get(tenant, now) < ttl:
                    continue
                with self._stats_lock:
                    busy = self._inflight.get(tenant, 0) > 0
                if busy:
                    continue
                sess = self._sessions.pop(tenant)
                with sess._key_lock:
                    self._evicted_keys[tenant] = sess._key
                self._last_seen.pop(tenant, None)
                evicted += 1
                self._m_evictions.inc()
                with self._stats_lock:
                    ts = self._tenants.get(tenant,
                                           TenantStats(tenant=tenant))
                    self._tenants[tenant] = ts.bump(evictions=1)
        return evicted

    def _maybe_evict(self) -> None:
        """Throttled idle-loop TTL sweep (any pool's idle worker)."""
        ttl = self.tenant_ttl_s
        if ttl is None:
            return
        now = time.monotonic()
        if now - self._last_sweep < max(ttl / 4.0, self._poll_s):
            return
        self._last_sweep = now
        self.evict_idle()

    def _shared_backend(self, name: str) -> ExecutionBackend:
        """The service-wide backend for ``name`` — the default instance
        when the name matches, else one shared per-name instance
        adopted into every tenant session.  Without this, a spec naming
        a non-default backend would silently get a *private* per-
        session instance (one device LRU per tenant — no cross-tenant
        reuse, invisible to the service report)."""
        if name == self.backend.name:
            return self.backend
        with self._session_lock:
            b = self._extra_backends.get(name)
            if b is None:
                b = make_backend(name, profile=self._profile)
                b.bind_store(self.store)
                self._extra_backends[name] = b
                for sess in self._sessions.values():
                    sess.adopt_backend(b)
            return b

    # ------------------------------------------------------------------
    # circuit breakers
    # ------------------------------------------------------------------
    def _instance_for(self, name: str) -> ExecutionBackend:
        """The service-wide backend instance behind ``name``."""
        if name == self.backend.name:
            return self.backend
        return self._shared_backend(name)

    def _breaker_for(self, backend: ExecutionBackend) -> CircuitBreaker:
        """This backend instance's breaker, lazily built.  The
        transition hook quarantines the backend on → open (sessions'
        fallback chains then skip it) and un-quarantines on any other
        transition (half-open probes and re-closure re-admit it)."""
        with self._breaker_lock:
            cb = self._breakers.get(id(backend))
            if cb is None:
                name = backend.name
                taken = set(self._breaker_names.values())
                if name in taken:
                    dups = sum(1 for v in self._breaker_names.values()
                               if v.split("#")[0] == name)
                    name = f"{name}#{dups + 1}"

                def _mirror(old: str, new: str,
                            _b: ExecutionBackend = backend,
                            _name: str = name) -> None:
                    if new == OPEN:
                        _b.quarantine()
                    else:
                        _b.unquarantine()
                    self._m_transitions.inc(backend=_name, to=new)
                    now = time.perf_counter()
                    self.tracer.record(
                        "breaker.transition", "serve", now, now,
                        trace_id=self.tracer.new_trace_id(),
                        attrs={"backend": _name, "from": old, "to": new})
                cb = CircuitBreaker(self._breaker_policy,
                                    on_transition=_mirror)
                self._breakers[id(backend)] = cb
                self._breaker_names[id(backend)] = name
            return cb

    def _reroute_target(self, name: str) -> Optional[str]:
        """First backend down the fallback chain whose breaker admits
        traffic (None when the whole chain is open)."""
        nxt = MLegoSession._FALLBACK.get(name)
        while nxt is not None:
            if self._breaker_for(self._instance_for(nxt)).allow():
                return nxt
            nxt = MLegoSession._FALLBACK.get(nxt)
        return None

    def _note_outcome(self, answered_by: Optional[str],
                      fallback_from: Optional[str]) -> None:
        """Feed the breakers from one answered query/batch: a report
        carrying ``fallback_from`` means that backend was lost mid-
        query (the session absorbed the ``DeviceLostError`` and
        replayed downstream) — a hard failure for its breaker — while
        the answering backend records a success."""
        if fallback_from is not None:
            self._breaker_for(self._instance_for(fallback_from)) \
                .record_failure(hard=True)
        if answered_by is not None:
            self._breaker_for(self._instance_for(answered_by)) \
                .record_success()

    def _session_outcome(self, answered_by: str,
                         fallback_from: Optional[str],
                         error: Optional[BaseException]) -> None:
        """Tenant sessions' outcome hook — the *single* breaker feed.

        Fires inside ``MLegoSession.submit``/``submit_many`` whether
        the call came from a worker pool or from a tenant holding the
        session directly, so direct use can no longer bypass backend
        health accounting (the worker paths deliberately do not feed
        the breakers themselves — that would double-count)."""
        if error is not None:
            self._note_error(error, answered_by)
        else:
            self._note_outcome(answered_by, fallback_from)

    def _note_error(self, exc: BaseException, backend_name: str) -> None:
        """Feed the breakers from one failed query.  Only typed
        execution-infrastructure errors count — a spec error (empty
        predicate, bad α) says nothing about backend health."""
        if isinstance(exc, DeviceLostError):
            name = exc.backend or backend_name
            self._breaker_for(self._instance_for(name)) \
                .record_failure(hard=True)
        elif isinstance(exc, ExecutionError):
            self._breaker_for(self._instance_for(backend_name)) \
                .record_failure()

    # ------------------------------------------------------------------
    # front door
    # ------------------------------------------------------------------
    def submit(self, spec: QuerySpec, *args,
               tenant: str = DEFAULT_TENANT,
               deadline_s: Optional[float] = None,
               priority: int = 0,
               max_queue_wait_s: Optional[float] = None,
               options: Optional[SubmitOptions] = None) -> "Future":
        """Enqueue one query; resolves to its ``QueryReport``.

        Everything past ``spec`` is keyword-only: ``tenant`` names the
        submitting tenant, ``deadline_s``/``priority``/
        ``max_queue_wait_s`` are the admission-control options (or pass
        a prebuilt ``SubmitOptions`` via ``options=`` — explicit
        keywords win).  Raises ``ServiceClosedError`` after ``close()``
        and ``ShedError`` when the bounded queue is full with nothing
        lower-priority to displace.  The future raises what the query
        raised (e.g. ``ValueError`` for an empty predicate, or
        ``DeadlineExceededError``/``ShedError`` when admission control
        expired it in the queue) — never its coalescing neighbors'
        errors.
        """
        if args:
            # one-release shim for the PR 5 positional-tenant call site
            if len(args) > 1:
                raise TypeError(
                    f"submit() takes one positional argument (spec); "
                    f"pass tenant= and admission options as keywords")
            warnings.warn(
                "positional tenant in MLegoService.submit is deprecated; "
                "use submit(spec, tenant=...)",
                DeprecationWarning, stacklevel=2)
            tenant = args[0]
        if self._closed:
            raise ServiceClosedError("service is closed")
        if options is None:
            opts = SubmitOptions(deadline_s=deadline_s, priority=priority,
                                 max_queue_wait_s=max_queue_wait_s)
        else:
            opts = options
            if (deadline_s is not None or priority != 0
                    or max_queue_wait_s is not None):
                opts = SubmitOptions(
                    deadline_s=deadline_s if deadline_s is not None
                    else options.deadline_s,
                    priority=priority if priority != 0
                    else options.priority,
                    max_queue_wait_s=max_queue_wait_s
                    if max_queue_wait_s is not None
                    else options.max_queue_wait_s)
        self.session(tenant)           # construct early: fail fast here
        inst = self.backend
        if spec.backend is not None:
            # route named backends to the shared per-name instance
            # before the worker executes (registers into every session)
            inst = self._shared_backend(spec.backend)
        # the trace root is minted here, on the submitting thread; the
        # pool worker records spans onto the pre-allocated ids, so the
        # per-query tree survives the thread hop (and coalescing)
        item = PendingQuery(spec=spec, tenant=tenant, options=opts,
                            trace_id=self.tracer.new_trace_id(),
                            root_span_id=self.tracer.new_span_id())
        pool = self._pool_for(inst)
        try:
            pool.queue.put(item)
        except ShedError:
            self._m_shed.inc()
            with self._stats_lock:
                ts = self._tenants.get(tenant, TenantStats(tenant=tenant))
                self._tenants[tenant] = ts.bump(shed=1)
            raise
        return item.future

    def _note_displaced(self, victim: PendingQuery) -> None:
        """Queue callback: a pending query was displaced by a higher-
        priority arrival (its future already failed with ShedError)."""
        self._m_shed.inc()
        with self._stats_lock:
            ts = self._tenants.get(victim.tenant,
                                   TenantStats(tenant=victim.tenant))
            self._tenants[victim.tenant] = ts.bump(shed=1)

    def train_range(self, lo: float, hi: float,
                    kind: Optional[str] = None,
                    tenant: str = DEFAULT_TENANT
                    ) -> Optional[MaterializedModel]:
        """Synchronous capital building into the shared store."""
        return self.session(tenant).train_range(lo, hi, kind)

    def save_calibration(self, path: Optional[str] = None) -> str:
        path = path or self.calibration_path
        if path is None:
            raise ValueError("no calibration path: pass one here or set "
                             "calibration_path= on the service")
        cal = getattr(self.cost, "calibration", None)
        if cal is None:
            raise ValueError("service cost provider is not calibrated; "
                             "nothing to persist")
        cal.save(path)                  # merge-on-save (concurrent-safe)
        return path

    # ------------------------------------------------------------------
    # SLO feedback
    # ------------------------------------------------------------------
    def _tracker(self, backend_name: str) -> HistogramView:
        """One backend's latency window, as a sliding-window view over
        the shared ``mlego_serve_latency_seconds`` histogram — the SLO
        control loop and the Prometheus exposition read one structure,
        fed by one ``observe()`` per answered query."""
        return self._m_latency.view(backend=backend_name)

    def _degrade_level(self, backend_name: str) -> int:
        if self._slo_policy is None:
            return 0
        return self._slo_policy.level(self._tracker(backend_name))

    def _degrade_spec(self, spec: QuerySpec, level: int,
                      sess: MLegoSession) -> QuerySpec:
        """The SLO loop's dial: under load, turn α toward the fast end
        — *unless* the original-α plan is already cached (serving a
        cached plan costs no search, and degrading it would force
        one)."""
        if level <= 0 or spec.alpha <= 0.0:
            return spec
        factor = self._slo_policy.alpha_factor(level)
        if factor >= 1.0:
            return spec
        if sess.plan_cached_for(spec):
            return spec
        return _dc_replace(spec, alpha=spec.alpha * factor)

    def _apply_slo_side_effects(self, level: int) -> None:
        sp = self._speculator
        if sp is not None and self._slo_policy is not None:
            sp.set_paused(level >= self._slo_policy.pause_speculation_at)

    # ------------------------------------------------------------------
    # worker loop
    # ------------------------------------------------------------------
    def _run(self, pool: _Pool, steal_ok: bool) -> None:
        while True:
            batch = pool.queue.drain(timeout=self._poll_s)
            if not batch and steal_ok and not self._stop.is_set():
                for other in self._pools_snapshot():
                    if other is pool:
                        continue
                    batch = other.queue.steal()
                    if batch:
                        break
            if batch:
                try:
                    self._execute(batch)
                except BaseException as exc:     # noqa: BLE001
                    # the worker must survive anything — a dead worker
                    # silently strands every queued and future query.
                    # Fail the batch's unresolved futures instead.
                    for it in batch:
                        _reject(it.future, exc)
                continue
            self._maybe_evict()
            if self._stop.is_set() and len(pool.queue) == 0:
                return

    def _group_key(self, spec: QuerySpec) -> Tuple[str, str]:
        # submit_many's batch-wide contracts: one trainer kind, one
        # execution backend.  α may vary inside a group — the session
        # auto-splits mixed-α batches into per-α Alg. 4 sub-batches.
        # spec.kind is already canonical (QuerySpec resolves aliases
        # like "gibbs" at construction), as is self.kind, so aliased
        # spellings of one kind land in one group.
        return (spec.kind or self.kind,
                spec.backend or self.backend.name)

    def _execute(self, batch: List[PendingQuery]) -> None:
        # named injection site for the chaos harness: a fault here
        # lands in the worker's catch-all, which must fail the batch's
        # futures and keep the thread alive (asserted in tests)
        maybe_fail("serve.worker")
        groups: Dict[Tuple[str, str], List[PendingQuery]] = {}
        for item in batch:
            groups.setdefault(self._group_key(item.spec), []).append(item)
        for (kind, backend_name), items in groups.items():
            self._execute_group(items, backend_name)

    def _admit(self, items: List[PendingQuery]) -> List[PendingQuery]:
        """Execution-start admission: expire deadlines and over-waited
        queries *before* burning capacity on them, and transition the
        survivors' futures PENDING → RUNNING exactly once (a future the
        client cancelled while queued is dropped here and can no longer
        be cancelled mid-execution, so set_result below can never race
        a cancellation into InvalidStateError)."""
        now = time.perf_counter()
        ready: List[PendingQuery] = []
        for it in items:
            if it.expired(now):
                if it.future.set_running_or_notify_cancel():
                    _reject(it.future, DeadlineExceededError(
                        f"deadline_s={it.options.deadline_s} elapsed "
                        f"before execution started"))
                    self._record_rejection(it, deadline=True)
            elif it.overwaited(now):
                if it.future.set_running_or_notify_cancel():
                    _reject(it.future, ShedError(
                        f"queued {now - it.enqueued_at:.3f}s, past "
                        f"max_queue_wait_s={it.options.max_queue_wait_s}"))
                    self._record_rejection(it, deadline=False)
            elif it.future.set_running_or_notify_cancel():
                ready.append(it)
        return ready

    def _record_rejection(self, item: PendingQuery, *,
                          deadline: bool) -> None:
        if deadline:
            self._m_deadline.inc()
        else:
            self._m_shed.inc()
        if item.trace_id and item.root_span_id:
            now = time.perf_counter()
            self.tracer.record(
                "serve.query", "serve", item.enqueued_at, now,
                trace_id=item.trace_id, span_id=item.root_span_id,
                attrs={"tenant": item.tenant,
                       "outcome": "deadline" if deadline else "shed"})
        with self._stats_lock:
            ts = self._tenants.get(item.tenant,
                                   TenantStats(tenant=item.tenant))
            self._tenants[item.tenant] = ts.bump(
                **({"deadline_rejected": 1} if deadline else {"shed": 1}))

    def _execute_group(self, items: List[PendingQuery],
                       backend_name: str) -> None:
        if not self._breaker_for(self._instance_for(backend_name)).allow():
            # breaker open: route the still-pending group to the
            # fallback pool instead of shedding — degraded answers
            # beat no answers.  With the whole chain open we fall
            # through and try the original backend anyway (strictly
            # no worse than rejecting).
            fb = self._reroute_target(backend_name)
            if fb is not None:
                pool = self._pool_for(self._instance_for(fb))
                self._m_reroutes.inc(len(items))
                for it in items:
                    it.spec = _dc_replace(it.spec, backend=fb)
                    try:
                        pool.queue.put(it)
                    except (ShedError, ServiceClosedError) as exc:
                        if it.future.set_running_or_notify_cancel():
                            _reject(it.future, exc)
                            self._record_rejection(it, deadline=False)
                return
        items = self._admit(items)
        width = len(items)
        if width == 0:
            return
        level = self._degrade_level(backend_name)
        self._apply_slo_side_effects(level)
        with self._stats_lock:
            for it in items:
                self._inflight[it.tenant] = \
                    self._inflight.get(it.tenant, 0) + 1
        try:
            if width == 1:
                self._execute_serial(items, level)
                return
            # queue wait is measured to the group's own execution start
            # — a group stuck behind its batch-mates' execution is
            # still waiting, and the operator should see that time
            t0 = time.perf_counter()
            # every shared structure (store, plan cache, device LRU,
            # calibration) is common to all tenants, so any member's
            # session may host the execution; each shared gap segment
            # is trained on the stream of the first tenant (in sorted
            # order) covering it, so a tenant's results are
            # reproducible however its queries coalesced — group
            # membership and arrival order can't leak into another
            # tenant's RNG stream
            items.sort(key=lambda it: it.tenant)
            self._execute_fused(items, level, t0)
        finally:
            with self._stats_lock:
                for it in items:
                    n = self._inflight.get(it.tenant, 1) - 1
                    if n <= 0:
                        self._inflight.pop(it.tenant, None)
                    else:
                        self._inflight[it.tenant] = n

    def _execute_fused(self, items: List[PendingQuery], level: int,
                       t0: float) -> None:
        """Fused execution with bisecting failure isolation.

        A failed ``submit_many`` splits the group in half and retries
        each half fused, recursing down to width 1 (which runs through
        the serial path and surfaces the error on exactly the failing
        spec's future).  One malformed spec therefore costs O(log n)
        extra launches while every all-healthy half keeps its Alg. 4
        shared-segment training — the retired query-by-query fallback
        forfeited joint planning for the entire window."""
        width = len(items)
        if width == 1:
            self._execute_serial(items, level)
            return
        sessions = [self.session(it.tenant) for it in items]
        specs = [self._degrade_spec(it.spec, level, sessions[0])
                 for it in items]
        # one *group* span wraps the fused execution (its own trace);
        # each member query then gets a ``serve.execute`` child in its
        # *own* trace covering the same interval and cross-linked to
        # the group, so a coalesced query's trace id survives fusion
        t_ex0 = time.perf_counter()
        try:
            with self.tracer.span(
                    "serve.fuse", "serve",
                    attrs={"width": width,
                           "traces": ",".join(
                               it.trace_id or "?" for it in items)}) as gsp:
                br = sessions[0].submit_many(
                    specs, next_keys=[s._next_key for s in sessions])
        except Exception:
            mid = width // 2
            self._m_bisect.inc()
            self._execute_fused(items[:mid], level, t0)
            self._execute_fused(items[mid:], level, t0)
            return
        t_ex1 = time.perf_counter()
        # breaker feed: already fired per report via the session's
        # outcome hook inside submit_many — nothing to do here
        self._m_groups.inc()
        self._m_coalesced.inc()
        with self._stats_lock:
            self._width_sum += width
            self._max_coalesce_width = max(self._max_coalesce_width,
                                           width)
        group_trace = gsp.trace_id if gsp is not None else ""
        for it, rep in zip(items, br.reports):
            rep.degraded = level
            if it.trace_id:
                rep.trace = it.trace_id
                self.tracer.record(
                    "serve.execute", "serve", t_ex0, t_ex1,
                    trace_id=it.trace_id, parent_id=it.root_span_id,
                    attrs={"fused": True, "width": width,
                           "group_trace": group_trace,
                           "backend": br.backend or ""})
            self._record(it, t0, width, br.plan_cached,
                         model_ids=rep.model_ids, degraded=level)
            _resolve(it.future, rep)

    def _execute_serial(self, items: List[PendingQuery],
                        level: int = 0) -> None:
        """Width-1 groups and the failed-batch isolation retry.  The
        futures are already RUNNING (gated in ``_admit``)."""
        for it in items:
            t0 = time.perf_counter()     # this query's own start
            self._m_groups.inc()
            with self._stats_lock:
                self._width_sum += 1
                self._max_coalesce_width = max(self._max_coalesce_width, 1)
            sess = self.session(it.tenant)
            # breaker feed: the session's outcome hook fires inside
            # submit (success and failure), so the worker records only
            # stats/spans here
            try:
                with self.tracer.span(
                        "serve.execute", "serve",
                        trace_id=it.trace_id, parent_id=it.root_span_id,
                        attrs={"tenant": it.tenant, "fused": False}):
                    rep = sess.submit(
                        self._degrade_spec(it.spec, level, sess))
            except Exception as exc:
                self._record(it, t0, 1, False, error=True)
                _reject(it.future, exc)
            else:
                rep.degraded = level
                if it.trace_id:
                    rep.trace = it.trace_id
                self._record(it, t0, 1, rep.plan_cached,
                             model_ids=rep.model_ids, degraded=level)
                _resolve(it.future, rep)

    def _record(self, item: PendingQuery, t0: float, width: int,
                plan_cached: bool, error: bool = False,
                model_ids: Tuple[int, ...] = (),
                degraded: int = 0) -> None:
        now = time.perf_counter()
        wait = max(t0 - item.enqueued_at, 0.0)
        backend_name = item.spec.backend or self.backend.name
        self._m_queries.inc()
        if error:
            self._m_errors.inc()
        if degraded > 0 and not error:
            self._m_degraded.inc(level=str(degraded))
        if item.trace_id and item.root_span_id:
            # the per-query root and its queue-wait child are recorded
            # here, where both endpoints are known — they started on
            # the submitting thread, ended on this worker
            self.tracer.record(
                "queue.wait", "serve", item.enqueued_at, t0,
                trace_id=item.trace_id, parent_id=item.root_span_id,
                attrs={"pool": backend_name})
            self.tracer.record(
                "serve.query", "serve", item.enqueued_at, now,
                trace_id=item.trace_id, span_id=item.root_span_id,
                attrs={"tenant": item.tenant, "width": width,
                       "backend": backend_name, "error": error,
                       "degraded": degraded})
        with self._stats_lock:
            ts = self._tenants.get(item.tenant,
                                   TenantStats(tenant=item.tenant))
            self._tenants[item.tenant] = ts.absorb(
                wait_s=wait, width=width, plan_cached=plan_cached,
                error=error, degraded=degraded > 0 and not error)
        self._last_seen[item.tenant] = time.monotonic()
        if not error:
            # client-observed latency (enqueue → answer) feeds both the
            # SLO window and the exposition histogram of the backend
            # that served the query — one observe, one structure
            self._m_latency.observe(now - item.enqueued_at,
                                    backend=backend_name)
            spec = item.spec
            self._query_log.append(QueryLogEntry(
                tenant=item.tenant,
                sigma=tuple((s.lo, s.hi) for s in spec.sigma),
                kind=spec.kind or self.kind,
                alpha=spec.alpha, backend=spec.backend,
                t=time.monotonic()))
            spec_trainer = self._speculator
            if spec_trainer is not None and model_ids \
                    and spec_trainer.trained_ids.intersection(model_ids):
                spec_trainer.note_hit()

    def query_log(self) -> Tuple[QueryLogEntry, ...]:
        """Snapshot of the rolling answered-query log (speculator
        input; deque appends are thread-safe, tuple() snapshots)."""
        return tuple(self._query_log)

    # ------------------------------------------------------------------
    # streaming ingestion & speculation
    # ------------------------------------------------------------------
    def _install_corpus(self, corpus: Corpus) -> None:
        """Re-home every tenant session on a grown snapshot — called by
        the ingest pipeline *before* slice models land, so the planner
        can never cover a range whose tokens the index doesn't count."""
        with self._session_lock:
            self.corpus = corpus
            self._data_epoch += 1
            for sess in self._sessions.values():
                sess.extend_corpus(corpus)

    def attach_ingest(self, *, slice_width: float,
                      kind: Optional[str] = None,
                      compaction: Optional[CompactionPolicy] = None,
                      start: Optional[float] = None) -> IngestPipeline:
        """Wire streaming ingestion to this service (once).

        Returns the ``IngestPipeline``; feed it through ``ingest`` (or
        ``pipeline.append``).  With a ``CompactionPolicy`` the builder
        drives compaction/eviction after every built slice, keeping
        the managed kind's capital under the policy's byte budget.
        """
        if self._ingest is not None:
            raise RuntimeError("ingest pipeline already attached")
        if self._closed:
            raise ServiceClosedError("service is closed")
        kind = resolve_kind(kind or self.kind)
        compactor = Compactor(self.store, self.cfg, compaction,
                              kind=kind) if compaction is not None else None
        self._ingest = IngestPipeline(
            self.corpus, self.store, self.cfg,
            slice_width=slice_width, kind=kind, backend=self.backend,
            start=start, seed=self._tenant_seed("__ingest__"),
            on_corpus=self._install_corpus, compactor=compactor)
        return self._ingest

    def ingest(self, batch: Corpus) -> None:
        """Append one document batch to the attached pipeline."""
        if self._ingest is None:
            raise RuntimeError("no ingest pipeline: call attach_ingest "
                               "first")
        self._ingest.append(batch)

    def attach_speculator(self, *, window_s: float = 30.0,
                          min_count: int = 2, margin: float = 1.0,
                          poll_s: float = 0.05,
                          start: bool = True) -> SpeculativeTrainer:
        """Start workload-driven gap pre-training over the query log
        (once).  ``start=False`` skips the background thread — call
        ``scan_once`` manually (tests, benchmarks).  Under SLO
        degradation level ≥ ``pause_speculation_at`` the trainer is
        paused: overload capacity goes to answering, not pre-training.
        """
        if self._speculator is not None:
            raise RuntimeError("speculative trainer already attached")
        if self._closed:
            raise ServiceClosedError("service is closed")
        self._speculator = SpeculativeTrainer(
            self, window_s=window_s, min_count=min_count, margin=margin,
            poll_s=poll_s, start=start)
        return self._speculator

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------
    def report(self) -> ServiceReport:
        cal = getattr(self.cost, "calibration", None)
        # per-backend SLO views off the shared latency histogram (one
        # entry per backend that has ever observed a sample)
        slo = {}
        for key in self._m_latency.series():
            name = key[0]
            tr = self._tracker(name)
            slo[name] = BackendSLO(
                p50_s=tr.p50, p95_s=tr.p95, p99_s=tr.p99,
                samples=len(tr),
                level=self._slo_policy.level(tr)
                if self._slo_policy is not None else 0)
        depth = {p.name: len(p.queue) for p in self._pools_snapshot()}
        with self._breaker_lock:
            blist = [(self._breaker_names[k], cb)
                     for k, cb in self._breakers.items()]
        # snapshot outside _breaker_lock: a cooled-down open breaker
        # transitions to half-open on observation, which fires the
        # quarantine-mirror hook
        breaker = {name: cb.snapshot() for name, cb in blist}
        with self._session_lock:
            active = len(self._sessions)
        # the JSON metrics snapshot reads the same registry objects the
        # counters below come from (running the mirror callbacks), so
        # exposition and report agree on a quiesced service
        metrics = self.registry.snapshot()
        with self._stats_lock:
            return ServiceReport(
                tenants=dict(self._tenants),
                queries=int(self._m_queries.total()),
                errors=int(self._m_errors.total()),
                groups=int(self._m_groups.total()),
                coalesced_groups=int(self._m_coalesced.total()),
                max_coalesce_width=self._max_coalesce_width,
                width_sum=self._width_sum,
                plan_cache_hits=self.plan_cache.hits,
                plan_cache_misses=self.plan_cache.misses,
                plan_cache_entries=len(self.plan_cache),
                backend=self.backend.stats,
                calibration_samples=len(cal) if cal is not None else 0,
                store_bytes=self.store.nbytes(),
                shed=int(self._m_shed.total()),
                deadline_rejected=int(self._m_deadline.total()),
                bisect_retries=int(self._m_bisect.total()),
                degraded_queries=int(self._m_degraded.total()),
                tenant_evictions=int(self._m_evictions.total()),
                active_sessions=active,
                queue_depth=depth,
                slo=slo,
                breaker=breaker,
                breaker_reroutes=int(self._m_reroutes.total()),
                retries=self.retry.snapshot(),
                metrics=metrics,
                ingest=self._ingest.report()
                if self._ingest is not None else None,
                speculation=self._speculator.report()
                if self._speculator is not None else None)


__all__ = ["DEFAULT_TENANT", "MLegoService"]
