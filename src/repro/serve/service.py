"""``MLegoService`` — the multi-tenant front door over one shared store.

``MLegoSession`` is a single-caller object: its plan cache, device
model LRU, and calibration log are private, so every concurrent
analyst over the same materialized capital rebuilds all three.  The
service owns exactly one of each — one ``ModelStore``, one execution
backend (one device LRU), one store-homed ``PlanCache``, one cost
provider (one calibration log) — and hands every tenant a session
wired to the shared set:

    svc = MLegoService(corpus, cfg, backend="device", window_s=0.005)
    svc.train_range(0.0, 500.0)                   # shared capital
    fut = svc.submit(QuerySpec(sigma=Interval(0.0, 1000.0)), tenant="ana")
    report = fut.result()                         # a QueryReport

``submit`` is asynchronous: specs land on a **coalescing queue** and a
worker loop drains it in time/size windows.  Specs that drained
together and are compatible — same trainer kind, same execution
backend; α may differ, the session's α-split machinery handles it —
are fused into one ``submit_many`` call, so independent interactive
users ride Alg. 4's joint planning (shared gap segments trained once)
and the size-bucketed batched merge launches instead of issuing n
serial single-query merges.  A group whose fused execution fails is
retried query-by-query, so one malformed spec cannot poison its
coalescing window's neighbors.

Cross-session reuse is the point: tenant B's repeated query over a
plan tenant A already searched reports ``plan_cached=True``, and its
merge reads A's device-resident model parameters as cache hits.
Per-tenant queue waits and coalesce widths land on ``ServiceReport``
(``svc.report()``).

The service is also the host for the streaming subsystems
(``repro.ingest``): ``attach_ingest`` wires an ``IngestPipeline`` to
the shared store — grown corpus snapshots re-home every tenant session
*before* slice models land, so a query over freshly ingested documents
is answered with no manual store mutation — and ``attach_speculator``
starts a ``SpeculativeTrainer`` over the service's query log (every
answered query is logged with its σ/kind/α and arrival time).  Both
are drained and joined by ``close()``.  Answered plans are checked
against the speculator's trained set, so speculative hits surface on
the report.
"""
from __future__ import annotations

import threading
import time
import zlib
from collections import deque
from concurrent.futures import Future
from typing import Deque, Dict, List, Optional, Tuple, Union

from repro.api.backend import ExecutionBackend, make_backend
from repro.api.planner import PlanCache
from repro.api.session import MLegoSession
from repro.api.spec import QuerySpec
from repro.api.trainers import resolve_kind
from repro.configs.lda_default import LDAConfig
from repro.core.cost import CostProvider
from repro.core.lda import MaterializedModel
from repro.core.store import ModelStore
from repro.data.corpus import Corpus
from repro.ingest.compaction import CompactionPolicy, Compactor
from repro.ingest.pipeline import IngestPipeline
from repro.ingest.speculate import QueryLogEntry, SpeculativeTrainer
from repro.serve.queue import CoalescingQueue, PendingQuery
from repro.serve.reports import ServiceReport, TenantStats

DEFAULT_TENANT = "default"


def _resolve(future: "Future", result) -> None:
    """Set a result, tolerating futures a client already finalized —
    the worker must never die over one future's state."""
    try:
        future.set_result(result)
    except Exception:
        pass


def _reject(future: "Future", exc: BaseException) -> None:
    try:
        future.set_exception(
            exc if isinstance(exc, Exception) else RuntimeError(repr(exc)))
    except Exception:
        pass


class MLegoService:
    """One shared store, many tenants, one coalescing worker loop.

    corpus/cfg       : the Def. 1 D and F every tenant shares
    store            : shared ``ModelStore`` (fresh one if omitted)
    kind             : default trainer kind for specs that name none
    backend          : the *shared* execution backend ("host"/"device"
                       or an instance) — one device LRU for everyone
    cost             : shared cost provider ("analytic"/"calibrated"/
                       instance); a calibrated provider accumulates one
                       calibration log across all tenants
    calibration_path : sidecar to warm-start from and to merge-save
                       into on ``close()``
    window_s         : coalescing window — max extra latency a query
                       pays to let neighbors fuse with it
    max_width        : cap on one coalesced group's size
    seed             : base RNG seed; each tenant's session derives a
                       stable per-tenant stream from it
    """

    def __init__(self, corpus: Corpus, cfg: LDAConfig, *,
                 store: Optional[ModelStore] = None,
                 kind: str = "vb",
                 backend: Union[str, ExecutionBackend] = "host",
                 cost: Union[CostProvider, str, None] = None,
                 calibration_path: Optional[str] = None,
                 window_s: float = 0.005, max_width: int = 16,
                 plan_cache_entries: int = 1024,
                 seed: int = 0, poll_s: float = 0.02,
                 query_log_entries: int = 512):
        self.corpus = corpus
        self.cfg = cfg
        self.store = store if store is not None else ModelStore()
        self.kind = resolve_kind(kind)
        self.backend = make_backend(backend) if isinstance(backend, str) \
            else backend
        self.plan_cache = PlanCache(max_entries=plan_cache_entries)
        self.cost = MLegoSession._make_cost(cost, cfg, calibration_path)
        self.calibration_path = calibration_path
        self._seed = seed
        self._poll_s = poll_s

        self._sessions: Dict[str, MLegoSession] = {}
        self._session_lock = threading.RLock()
        # shared per-name backends for specs naming a non-default
        # backend — one device LRU per backend *name*, not per tenant
        self._extra_backends: Dict[str, ExecutionBackend] = {}

        # rolling per-tenant query log — the speculator's ore
        self._query_log: Deque[QueryLogEntry] = deque(
            maxlen=query_log_entries)
        self._ingest: Optional[IngestPipeline] = None
        self._speculator: Optional[SpeculativeTrainer] = None

        self._stats_lock = threading.Lock()
        self._tenants: Dict[str, TenantStats] = {}
        self._queries = self._errors = 0
        self._groups = self._coalesced_groups = 0
        self._width_sum = self._max_width = 0

        self._queue = CoalescingQueue(window_s=window_s,
                                      max_width=max_width)
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._run,
                                        name="mlego-service-worker",
                                        daemon=True)
        self._worker.start()

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def __enter__(self) -> "MLegoService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def closed(self) -> bool:
        return self._queue.closed

    def close(self) -> None:
        """Stop accepting queries, stop speculation, drain the ingest
        builder (the open partial slice is built — append-only means it
        can never grow again), drain everything pending, join the
        worker, and (for a calibrated provider with a sidecar path)
        merge-save the shared calibration log."""
        if self._speculator is not None:
            self._speculator.close()
        if self._ingest is not None:
            self._ingest.close()
        if self._queue.closed:
            if self._worker.is_alive():
                self._worker.join()
            return
        self._queue.close()
        self._stop.set()
        self._worker.join()
        if self.calibration_path is not None \
                and getattr(self.cost, "calibration", None) is not None:
            self.save_calibration()

    # ------------------------------------------------------------------
    # tenants
    # ------------------------------------------------------------------
    def _tenant_seed(self, tenant: str) -> int:
        # stable across runs and processes (no hash randomization)
        return (self._seed + zlib.crc32(tenant.encode("utf-8"))) & 0x7FFFFFFF

    def session(self, tenant: str = DEFAULT_TENANT) -> MLegoSession:
        """The tenant's session — lazily built, permanently wired to
        the shared store/backend/plan-cache/cost provider.  Usable
        directly for synchronous work (capital building, debugging);
        interactive traffic should go through ``submit``."""
        with self._session_lock:
            sess = self._sessions.get(tenant)
            if sess is None:
                sess = MLegoSession(
                    self.corpus, self.cfg, store=self.store,
                    cost=self.cost, kind=self.kind,
                    seed=self._tenant_seed(tenant),
                    backend=self.backend, plan_cache=self.plan_cache)
                for b in self._extra_backends.values():
                    sess.adopt_backend(b)
                self._sessions[tenant] = sess
            return sess

    def tenants(self) -> Tuple[str, ...]:
        with self._session_lock:
            return tuple(sorted(self._sessions))

    def _shared_backend(self, name: str) -> ExecutionBackend:
        """The service-wide backend for ``name`` — the default instance
        when the name matches, else one shared per-name instance
        adopted into every tenant session.  Without this, a spec naming
        a non-default backend would silently get a *private* per-
        session instance (one device LRU per tenant — no cross-tenant
        reuse, invisible to the service report)."""
        if name == self.backend.name:
            return self.backend
        with self._session_lock:
            b = self._extra_backends.get(name)
            if b is None:
                b = make_backend(name)
                b.bind_store(self.store)
                self._extra_backends[name] = b
                for sess in self._sessions.values():
                    sess.adopt_backend(b)
            return b

    # ------------------------------------------------------------------
    # front door
    # ------------------------------------------------------------------
    def submit(self, spec: QuerySpec,
               tenant: str = DEFAULT_TENANT) -> "Future":
        """Enqueue one query; resolves to its ``QueryReport``.

        The future raises what the query raised (e.g. ``ValueError``
        for an empty predicate) — never its coalescing neighbors'
        errors."""
        if self._queue.closed:
            raise RuntimeError("service is closed")
        self.session(tenant)           # construct early: fail fast here
        if spec.backend is not None:
            # route named backends to the shared per-name instance
            # before the worker executes (registers into every session)
            self._shared_backend(spec.backend)
        item = PendingQuery(spec=spec, tenant=tenant)
        self._queue.put(item)
        return item.future

    def train_range(self, lo: float, hi: float,
                    kind: Optional[str] = None,
                    tenant: str = DEFAULT_TENANT
                    ) -> Optional[MaterializedModel]:
        """Synchronous capital building into the shared store."""
        return self.session(tenant).train_range(lo, hi, kind)

    def save_calibration(self, path: Optional[str] = None) -> str:
        path = path or self.calibration_path
        if path is None:
            raise ValueError("no calibration path: pass one here or set "
                             "calibration_path= on the service")
        cal = getattr(self.cost, "calibration", None)
        if cal is None:
            raise ValueError("service cost provider is not calibrated; "
                             "nothing to persist")
        cal.save(path)                  # merge-on-save (concurrent-safe)
        return path

    # ------------------------------------------------------------------
    # worker loop
    # ------------------------------------------------------------------
    def _run(self) -> None:
        while True:
            batch = self._queue.drain(timeout=self._poll_s)
            if batch:
                try:
                    self._execute(batch)
                except BaseException as exc:     # noqa: BLE001
                    # the worker must survive anything — a dead worker
                    # silently strands every queued and future query.
                    # Fail the batch's unresolved futures instead.
                    for it in batch:
                        _reject(it.future, exc)
            elif self._stop.is_set() and len(self._queue) == 0:
                return

    def _group_key(self, spec: QuerySpec) -> Tuple[str, str]:
        # submit_many's batch-wide contracts: one trainer kind, one
        # execution backend.  α may vary inside a group — the session
        # auto-splits mixed-α batches into per-α Alg. 4 sub-batches.
        # spec.kind is already canonical (QuerySpec resolves aliases
        # like "gibbs" at construction), as is self.kind, so aliased
        # spellings of one kind land in one group.
        return (spec.kind or self.kind,
                spec.backend or self.backend.name)

    def _execute(self, batch: List[PendingQuery]) -> None:
        groups: Dict[Tuple[str, str], List[PendingQuery]] = {}
        for item in batch:
            groups.setdefault(self._group_key(item.spec), []).append(item)
        for items in groups.values():
            self._execute_group(items)

    def _execute_group(self, items: List[PendingQuery]) -> None:
        # transition every future PENDING -> RUNNING exactly once; a
        # future the client cancelled while queued is dropped here (and
        # can no longer be cancelled mid-execution), so set_result
        # below can never race a cancellation into InvalidStateError
        items = [it for it in items
                 if it.future.set_running_or_notify_cancel()]
        width = len(items)
        if width == 0:
            return
        if width == 1:
            self._execute_serial(items)
            return
        # queue wait is measured to the group's own execution start —
        # a group stuck behind its batch-mates' execution is still
        # waiting, and the operator should see that head-of-line time
        t0 = time.perf_counter()
        # every shared structure (store, plan cache, device LRU,
        # calibration) is common to all tenants, so any member's
        # session may host the execution; each shared gap segment is
        # trained on the stream of the first tenant (in sorted order)
        # covering it, so a tenant's results are reproducible however
        # its queries coalesced — group membership and arrival order
        # can't leak into another tenant's RNG stream
        items.sort(key=lambda it: it.tenant)
        sessions = [self.session(it.tenant) for it in items]
        try:
            br = sessions[0].submit_many(
                [it.spec for it in items],
                next_keys=[s._next_key for s in sessions])
        except Exception:
            # isolate the offender: re-run the group query-by-query so
            # only the failing spec's future carries the error
            self._execute_serial(items)
            return
        with self._stats_lock:
            self._groups += 1
            self._coalesced_groups += 1
            self._width_sum += width
            self._max_width = max(self._max_width, width)
        for it, rep in zip(items, br.reports):
            self._record(it, t0, width, br.plan_cached,
                         model_ids=rep.model_ids)
            _resolve(it.future, rep)

    def _execute_serial(self, items: List[PendingQuery]) -> None:
        """Width-1 groups and the failed-batch isolation retry.  The
        futures are already RUNNING (gated in ``_execute_group``)."""
        for it in items:
            t0 = time.perf_counter()     # this query's own start
            with self._stats_lock:
                self._groups += 1
                self._width_sum += 1
                self._max_width = max(self._max_width, 1)
            try:
                rep = self.session(it.tenant).submit(it.spec)
            except Exception as exc:
                self._record(it, t0, 1, False, error=True)
                _reject(it.future, exc)
            else:
                self._record(it, t0, 1, rep.plan_cached,
                             model_ids=rep.model_ids)
                _resolve(it.future, rep)

    def _record(self, item: PendingQuery, t0: float, width: int,
                plan_cached: bool, error: bool = False,
                model_ids: Tuple[int, ...] = ()) -> None:
        wait = max(t0 - item.enqueued_at, 0.0)
        with self._stats_lock:
            self._queries += 1
            if error:
                self._errors += 1
            ts = self._tenants.get(item.tenant,
                                   TenantStats(tenant=item.tenant))
            self._tenants[item.tenant] = ts.absorb(
                wait_s=wait, width=width, plan_cached=plan_cached,
                error=error)
        if not error:
            spec = item.spec
            self._query_log.append(QueryLogEntry(
                tenant=item.tenant,
                sigma=tuple((s.lo, s.hi) for s in spec.sigma),
                kind=spec.kind or self.kind,
                alpha=spec.alpha, backend=spec.backend,
                t=time.monotonic()))
            spec_trainer = self._speculator
            if spec_trainer is not None and model_ids \
                    and spec_trainer.trained_ids.intersection(model_ids):
                spec_trainer.note_hit()

    def query_log(self) -> Tuple[QueryLogEntry, ...]:
        """Snapshot of the rolling answered-query log (speculator
        input; deque appends are thread-safe, tuple() snapshots)."""
        return tuple(self._query_log)

    # ------------------------------------------------------------------
    # streaming ingestion & speculation
    # ------------------------------------------------------------------
    def _install_corpus(self, corpus: Corpus) -> None:
        """Re-home every tenant session on a grown snapshot — called by
        the ingest pipeline *before* slice models land, so the planner
        can never cover a range whose tokens the index doesn't count."""
        with self._session_lock:
            self.corpus = corpus
            for sess in self._sessions.values():
                sess.extend_corpus(corpus)

    def attach_ingest(self, *, slice_width: float,
                      kind: Optional[str] = None,
                      compaction: Optional[CompactionPolicy] = None,
                      start: Optional[float] = None) -> IngestPipeline:
        """Wire streaming ingestion to this service (once).

        Returns the ``IngestPipeline``; feed it through ``ingest`` (or
        ``pipeline.append``).  With a ``CompactionPolicy`` the builder
        drives compaction/eviction after every built slice, keeping
        the managed kind's capital under the policy's byte budget.
        """
        if self._ingest is not None:
            raise RuntimeError("ingest pipeline already attached")
        if self._queue.closed:
            raise RuntimeError("service is closed")
        kind = resolve_kind(kind or self.kind)
        compactor = Compactor(self.store, self.cfg, compaction,
                              kind=kind) if compaction is not None else None
        self._ingest = IngestPipeline(
            self.corpus, self.store, self.cfg,
            slice_width=slice_width, kind=kind, backend=self.backend,
            start=start, seed=self._tenant_seed("__ingest__"),
            on_corpus=self._install_corpus, compactor=compactor)
        return self._ingest

    def ingest(self, batch: Corpus) -> None:
        """Append one document batch to the attached pipeline."""
        if self._ingest is None:
            raise RuntimeError("no ingest pipeline: call attach_ingest "
                               "first")
        self._ingest.append(batch)

    def attach_speculator(self, *, window_s: float = 30.0,
                          min_count: int = 2, margin: float = 1.0,
                          poll_s: float = 0.05,
                          start: bool = True) -> SpeculativeTrainer:
        """Start workload-driven gap pre-training over the query log
        (once).  ``start=False`` skips the background thread — call
        ``scan_once`` manually (tests, benchmarks)."""
        if self._speculator is not None:
            raise RuntimeError("speculative trainer already attached")
        if self._queue.closed:
            raise RuntimeError("service is closed")
        self._speculator = SpeculativeTrainer(
            self, window_s=window_s, min_count=min_count, margin=margin,
            poll_s=poll_s, start=start)
        return self._speculator

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------
    def report(self) -> ServiceReport:
        cal = getattr(self.cost, "calibration", None)
        with self._stats_lock:
            return ServiceReport(
                tenants=dict(self._tenants),
                queries=self._queries,
                errors=self._errors,
                groups=self._groups,
                coalesced_groups=self._coalesced_groups,
                max_coalesce_width=self._max_width,
                width_sum=self._width_sum,
                plan_cache_hits=self.plan_cache.hits,
                plan_cache_misses=self.plan_cache.misses,
                plan_cache_entries=len(self.plan_cache),
                backend=self.backend.stats,
                calibration_samples=len(cal) if cal is not None else 0,
                store_bytes=self.store.nbytes(),
                ingest=self._ingest.report()
                if self._ingest is not None else None,
                speculation=self._speculator.report()
                if self._speculator is not None else None)


__all__ = ["DEFAULT_TENANT", "MLegoService"]
