"""Pipeline parallelism — GPipe-style microbatching over a mesh axis.

``pipeline_apply`` runs a layer stack split into S stages over the
``stage`` mesh axis.  Microbatches stream through the stages with a
``ppermute`` ring: at step t, stage s processes microbatch (t - s) and
passes activations to stage s+1.  The schedule is the classic GPipe
fill-drain; bubble fraction (S-1)/(S-1+M) — reported by
``pipeline_bubble`` so the launcher can size M.

On the production mesh the stage axis maps onto "pod" (2 stages x 16x16
within-pod meshes); tests validate the schedule at small scale against
the unpipelined reference.  This is a beyond-paper distribution feature
(the paper's workload is embarrassingly mergeable and needs no PP) —
it exists for the large assigned LM cells.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import MeshEnv


def pipeline_bubble(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / max(n_stages - 1 + n_micro, 1)


def pipeline_apply(layer_fn: Callable, stage_params, x, *, env: MeshEnv,
                   axis: str, n_micro: int):
    """Run ``layer_fn(params_stage, x_micro)`` through S pipeline stages.

    stage_params: pytree with a leading stage axis, sharded over ``axis``.
    x:            (B, ...) batch, split into n_micro microbatches.
    Returns y with the same shape as x after all stages.

    Implementation: shard_map over ``axis``; each rank holds its stage's
    params (leading axis 1).  The rotating buffer carries one microbatch
    per rank; after S + M - 1 ticks every microbatch has visited every
    stage in order.  Output microbatch m is collected on the last stage
    at tick m + S - 1, then all-gathered back to batch layout.
    """
    s = env.mesh.shape[axis]
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro

    def body(params_local, x_all):
        r = jax.lax.axis_index(axis)
        p_local = jax.tree.map(lambda a: a[0], params_local)
        micros = x_all.reshape((n_micro, mb) + x_all.shape[1:])
        n_ticks = n_micro + s - 1
        perm = [(i, (i + 1) % s) for i in range(s)]
        buf = jnp.zeros_like(micros[0])
        out = jnp.zeros_like(micros)

        def tick(carry, t):
            buf, out = carry
            # stage 0 ingests microbatch t (if any left)
            feed = micros[jnp.clip(t, 0, n_micro - 1)]
            buf = jnp.where((r == 0) & (t < n_micro), feed, buf)
            # every stage processes its current microbatch
            y = layer_fn(p_local, buf)
            # micro index this rank just finished: t - r
            mi = t - r
            # last stage banks its finished microbatch
            done = (r == s - 1) & (mi >= 0) & (mi < n_micro)
            out = jax.lax.cond(
                done,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(mi, 0, n_micro - 1), 0),
                lambda o: o,
                out)
            # pass activations downstream
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, out), None

        (buf, out), _ = jax.lax.scan(tick, (buf, out), jnp.arange(n_ticks))
        # collect the final outputs from the last stage to every rank
        out = jax.lax.psum(jnp.where(r == s - 1, out, jnp.zeros_like(out)),
                           axis)
        return out.reshape((b,) + x_all.shape[1:])

    return jax.shard_map(
        body, mesh=env.mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x)
