"""Model merging as a mesh collective — the paper's Alg. 1/2 on TPU.

The key TPU mapping (DESIGN.md §2): merging exponential-family
sufficient statistics is a *reduction*, so merging per-device partition
models IS an all-reduce:

    MVB:  λ*   = η + psum(λ_dev − η)        over (pod, data)
    MGS:  N*kv = psum(decay^s · ΔN_kv_dev)  over (pod, data)

Cross-pod merging is the same psum including the "pod" axis — no
parameter server, no torch.distributed emulation.  The vocab axis of
the (K, V) statistics stays sharded over "model" throughout; only the
partition (document) axis is reduced.

``staleness`` implements the DSGS decay (Eq. 9) as a straggler policy:
a device that contributes a stale delta (s > 0) has it decayed before
the reduction — bounded-staleness asynchrony expressed synchronously.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import MeshEnv


def merge_vb_collective(lam_local, eta: float, env: MeshEnv,
                        weight: Optional[jnp.ndarray] = None):
    """λ_local: (K, V_shard) per-device VB posterior; returns merged λ.

    Call inside shard_map over (dp..., model).  ``weight`` rescales this
    device's contribution (paper's doc-count weighting).
    """
    delta = lam_local - eta
    if weight is not None:
        delta = delta * weight
    return eta + jax.lax.psum(delta, env.dp_axes)


def merge_gs_collective(delta_nkv, env: MeshEnv,
                        decay: float = 1.0,
                        staleness: Optional[jnp.ndarray] = None):
    """ΔN_kv: (K, V_shard) per-device CGS delta; returns merged N_kv."""
    d = delta_nkv
    if staleness is not None:
        d = d * (decay ** staleness.astype(jnp.float32))
    return jax.lax.psum(d, env.dp_axes)


def merge_stats(stats_per_device, env: MeshEnv, kind: str = "vb",
                eta: float = 0.01):
    """Host-callable wrapper: shard stats (device, K, V) over dp, merge.

    Used by tests and the elastic repartitioner; the training loops call
    the collective forms directly inside their shard_map bodies.
    """
    dp = env.dp_axes
    tp = env.tp_axis

    def body(s):
        # s: (n_local, K, V_shard) — each rank owns a slice of the model
        # list; the local reduction composes with the cross-rank psum
        # because Alg. 1/2 merges are associative.
        if kind == "vb":
            delta = (s - eta).sum(0)
            return (eta + jax.lax.psum(delta, dp))[None]
        return jax.lax.psum(s.sum(0), dp)[None]

    if env.dp_size == 1:
        merged = stats_per_device.sum(0)
        return (eta + (merged - eta * stats_per_device.shape[0])
                if kind == "vb" else merged)
    out = jax.shard_map(
        body, mesh=env.mesh,
        in_specs=P(dp, None, tp),
        out_specs=P(dp, None, tp),
        check_vma=False,
    )(stats_per_device)
    return out[0]
