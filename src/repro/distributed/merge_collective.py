"""Model merging as a mesh collective — the paper's Alg. 1/2 on TPU.

The key TPU mapping (DESIGN.md §2): merging exponential-family
sufficient statistics is a *reduction*, so merging per-device partition
models IS an all-reduce:

    MVB:  λ*   = η + psum(λ_dev − η)        over (pod, data)
    MGS:  N*kv = psum(decay^s · ΔN_kv_dev)  over (pod, data)

Cross-pod merging is the same psum including the "pod" axis — no
parameter server, no torch.distributed emulation.  The vocab axis of
the (K, V) statistics stays sharded over "model" throughout; only the
partition (document) axis is reduced.

``staleness`` implements the DSGS decay (Eq. 9) as a straggler policy:
a device that contributes a stale delta (s > 0) has it decayed before
the reduction — bounded-staleness asynchrony expressed synchronously.

``merge_topics_sharded`` / ``merge_topics_ragged_sharded`` are the
*query-path* collectives behind ``ShardedDeviceBackend``: the model
list rides fully on every query but each device owns only a ``V/ndev``
vocab slice of every stack, merges its slice locally with the fused
Pallas kernel inside shard_map, and the only cross-device traffic is
the per-topic row normalizer — a (K,)-per-query psum instead of the
(K, V) gather a replicated merge would need.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map
from repro.distributed.sharding import MeshEnv
from repro.testing.faults import maybe_fail
from repro.kernels.merge_topics.merge_topics import (
    merge_topics_pallas,
    merge_topics_ragged_pallas,
)


def merge_vb_collective(lam_local, eta: float, env: MeshEnv,
                        weight: Optional[jnp.ndarray] = None):
    """λ_local: (K, V_shard) per-device VB posterior; returns merged λ.

    Call inside shard_map over (dp..., model).  ``weight`` rescales this
    device's contribution (paper's doc-count weighting).
    """
    delta = lam_local - eta
    if weight is not None:
        delta = delta * weight
    return eta + jax.lax.psum(delta, env.dp_axes)


def merge_gs_collective(delta_nkv, env: MeshEnv,
                        decay: float = 1.0,
                        staleness: Optional[jnp.ndarray] = None):
    """ΔN_kv: (K, V_shard) per-device CGS delta; returns merged N_kv."""
    d = delta_nkv
    if staleness is not None:
        d = d * (decay ** staleness.astype(jnp.float32))
    return jax.lax.psum(d, env.dp_axes)


def merge_stats(stats_per_device, env: MeshEnv, kind: str = "vb",
                eta: float = 0.01):
    """Host-callable wrapper: shard stats (device, K, V) over dp, merge.

    Used by tests and the elastic repartitioner; the training loops call
    the collective forms directly inside their shard_map bodies.
    """
    dp = env.dp_axes
    tp = env.tp_axis

    def body(s):
        # s: (n_local, K, V_shard) — each rank owns a slice of the model
        # list; the local reduction composes with the cross-rank psum
        # because Alg. 1/2 merges are associative.
        if kind == "vb":
            delta = (s - eta).sum(0)
            return (eta + jax.lax.psum(delta, dp))[None]
        return jax.lax.psum(s.sum(0), dp)[None]

    if env.dp_size == 1:
        merged = stats_per_device.sum(0)
        return (eta + (merged - eta * stats_per_device.shape[0])
                if kind == "vb" else merged)
    out = shard_map(
        body, mesh=env.mesh,
        in_specs=P(dp, None, tp),
        out_specs=P(dp, None, tp),
    )(stats_per_device)
    return out[0]


# ---------------------------------------------------------------------------
# vocab-sharded query merges (tentpole: each device owns a V/ndev slice)
# ---------------------------------------------------------------------------

def padded_vocab(v: int, shards: int) -> int:
    """V rounded up so every device's slice is f32-lane-aligned (128)."""
    tile = shards * 128
    return ((v + tile - 1) // tile) * tile


def _masked_numerator(merged, num_offset: float, v_true: int, axis: str):
    """merged slice -> finisher numerator with pad columns zeroed.

    Pad columns carry ``bias`` out of the kernel (they were padded with
    ``base``, so the weighted sum cancels); adding ``num_offset`` makes
    them nonzero for both families — mask them before they can pollute
    the row normalizer.
    """
    vs = merged.shape[-1]
    col = (jax.lax.axis_index(axis) * vs
           + jax.lax.broadcasted_iota(jnp.int32, merged.shape,
                                      merged.ndim - 1))
    return jnp.where(col < v_true, merged + num_offset, 0.0)


def merge_topics_sharded(stats, weights, env: MeshEnv, *,
                         bias: float, base: float, num_offset: float,
                         v_true: int, interpret: bool = False):
    """One query's merge with the vocab axis sharded over ``env.tp_axis``.

    stats: (n, K, Vp) with Vp = padded_vocab(V, tp_size) — V-padded with
    ``base`` so pad columns cancel in the reduction; weights: (n,).
    Each device merges its (n, K, Vp/ndev) slice through the fused
    Pallas kernel, applies the family's finisher numerator offset, and
    normalizes rows against a psum'd (K,) normalizer — returns the
    topic matrix β as a (K, Vp) array still sharded over the vocab
    axis (slice ``[:, :v_true]`` after np.asarray gathers it).
    """
    maybe_fail("collective.merge")
    tp = env.tp_axis
    n, k, _ = stats.shape
    kp = ((k + 7) // 8) * 8

    def body(s, w):
        if kp != k:
            s = jnp.pad(s, ((0, 0), (0, kp - k), (0, 0)),
                        constant_values=base)
        merged = merge_topics_pallas(s, w, bias, base,
                                     interpret=interpret)[:k]
        num = _masked_numerator(merged, num_offset, v_true, tp)
        norm = jax.lax.psum(num.sum(axis=-1), tp)        # (K,) only
        return num / norm[:, None]

    return shard_map(
        body, mesh=env.mesh,
        in_specs=(P(None, None, tp), P()),
        out_specs=P(None, tp),
    )(stats, weights)


def merge_topics_ragged_sharded(stats, weights, seg_ids,
                                num_segments: int, env: MeshEnv, *,
                                bias: float, base: float,
                                num_offset: float, v_true: int,
                                interpret: bool = False):
    """Ragged batch of vocab-sharded merges: one launch per device.

    stats: (R, K, Vp) — every query's part rows concatenated (CSR),
    ``seg_ids`` (R,) int32 non-decreasing.  Same collective shape as
    :func:`merge_topics_sharded` but the normalizer psum carries
    (num_segments, K) — still independent of V.  Returns β stacked
    (num_segments, K, Vp), vocab-sharded.
    """
    maybe_fail("collective.merge")
    tp = env.tp_axis
    n_rows, k, _ = stats.shape
    kp = ((k + 7) // 8) * 8

    def body(seg, s, w):
        if kp != k:
            s = jnp.pad(s, ((0, 0), (0, kp - k), (0, 0)),
                        constant_values=base)
        merged = merge_topics_ragged_pallas(
            s, w, seg, num_segments, bias, base,
            interpret=interpret)[:, :k]
        num = _masked_numerator(merged, num_offset, v_true, tp)
        norm = jax.lax.psum(num.sum(axis=-1), tp)        # (b, K)
        return num / norm[:, :, None]

    return shard_map(
        body, mesh=env.mesh,
        in_specs=(P(), P(None, None, tp), P()),
        out_specs=P(None, None, tp),
    )(seg_ids, stats, weights)
