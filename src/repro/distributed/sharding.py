"""Mesh environment + sharding-rule inference.

The production mesh is fixed by the launch spec:
  single pod : (data=16, model=16)            axes ("data", "model")
  multi pod  : (pod=2, data=16, model=16)     axes ("pod", "data", "model")

Parallelism mapping (train profile):
  - batch           -> ("pod", "data")   (DP)
  - weights         -> 2-D FSDP over ("data", "model") where divisible
  - sequence        -> "model" (SP); attention runs as ring flash
                       attention over the seq-sharded KV (shard_map)
  - experts         -> "model" (EP) with all_to_all dispatch
  - optimizer state -> sharded identically to params (ZeRO-3-like)

Serve profile:
  - batch  -> ("pod", "data")
  - weights-> "model" resident (Megatron TP slices); MoE experts 2-D
  - KV cache seq dim -> "model" (split-K flash decode + psum combine)
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compat import make_mesh


@dataclass(frozen=True)
class MeshEnv:
    mesh: Mesh
    profile: str = "train"  # "train" | "serve"

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.axis_names)

    @property
    def tp_axis(self) -> Optional[str]:
        return "model" if "model" in self.axis_names else None

    def size(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, (tuple, list)):
            out = 1
            for a in axis:
                out *= self.size(a)
            return out
        return self.mesh.shape[axis]

    @property
    def dp_size(self) -> int:
        return self.size(self.dp_axes)

    @property
    def tp_size(self) -> int:
        return self.size(self.tp_axis)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


_LOCAL = threading.local()


def get_env() -> Optional[MeshEnv]:
    return getattr(_LOCAL, "env", None)


@contextlib.contextmanager
def set_env(env: MeshEnv):
    prev = get_env()
    _LOCAL.env = env
    try:
        yield env
    finally:
        _LOCAL.env = prev


def single_device_env(profile: str = "train") -> MeshEnv:
    """A (1, 1) mesh over the single local device — used by smoke tests."""
    return MeshEnv(mesh=make_mesh((1, 1), ("data", "model")),
                   profile=profile)


def local_mesh_env(profile: str = "serve",
                   max_devices: Optional[int] = None) -> MeshEnv:
    """A (1, ndev) mesh over every local device, "model" as the TP axis.

    This is the vocab-sharded merge topology: the whole model list is
    replicated over the (trivial) data axis and each device owns a
    ``V/ndev`` vocab slice.  ``max_devices`` caps the shard count (e.g.
    to keep V/ndev tile-aligned on small vocabularies); at one device
    this degrades to :func:`single_device_env`.
    """
    ndev = jax.local_device_count()
    if max_devices is not None:
        ndev = max(1, min(ndev, max_devices))
    return MeshEnv(mesh=make_mesh((1, ndev), ("data", "model")),
                   profile=profile)


# ---------------------------------------------------------------------------
# activation constraints
# ---------------------------------------------------------------------------

def _divisible(dim: int, env: MeshEnv, axis) -> bool:
    return dim % env.size(axis) == 0


def constrain(x, *logical: Optional[str]):
    """with_sharding_constraint by logical axis names.

    Logical names: 'dp' (batch), 'sp' (sequence over model), 'tp'
    (feature over model), None (replicated).  Silently degrades to
    replication when the dimension is not divisible.
    """
    env = get_env()
    if env is None:
        return x
    assert x.ndim == len(logical), (x.shape, logical)
    entries = []
    for dim, name in zip(x.shape, logical):
        if name == "dp" and _divisible(dim, env, env.dp_axes):
            entries.append(env.dp_axes)
        elif name in ("sp", "tp") and env.tp_axis and _divisible(dim, env, env.tp_axis):
            entries.append(env.tp_axis)
        else:
            entries.append(None)
    return jax.lax.with_sharding_constraint(x, env.sharding(P(*entries)))


def gather_for_compute(param_tree):
    """ZeRO-3 compute-time unsharding of one layer's weights.

    Master weights rest fully sharded (2-D FSDP).  Left alone, GSPMD
    resolves a dot whose weight contraction dim is `data`-sharded by
    PARTIAL-SUMMING THE ACTIVATIONS — an all-reduce of (B, S, F) per
    dot, ~512 GB/chip/step on the llava train cell.  Constraining the
    layer's weight slices to replicated inside the scan body makes the
    partitioner all-gather the (bf16, layer-sized) weights instead and
    keeps every activation collective off the critical path.  Expert
    weights are exempt (they stay sharded under EP + the MoE module's
    own explicit gathers); 1-D leaves are already replicated.
    """
    env = get_env()
    if env is None or env.mesh.size == 1:
        return param_tree
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_tree)
    out = []
    for path, leaf in flat:
        path_str = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path).lower()
        if (getattr(leaf, "ndim", 0) >= 2 and "expert" not in path_str
                and "router" not in path_str):
            spec = P(*([None] * leaf.ndim))
            leaf = jax.lax.with_sharding_constraint(
                leaf, env.sharding(spec))
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------

def _spec_for(path: str, shape: Tuple[int, ...], env: MeshEnv) -> P:
    """Infer a PartitionSpec for one parameter from its path + shape."""
    names = [None] * len(shape)
    dp, tp = env.dp_axes, env.tp_axis
    serve = env.profile == "serve"

    def try_assign(i: int, axis) -> bool:
        if axis and names[i] is None and shape[i] % env.size(axis) == 0:
            names[i] = axis
            return True
        return False

    is_stacked = "stack" in path  # leading layer axis — never sharded
    lead = 1 if is_stacked else 0
    body = list(range(lead, len(shape)))

    if "embed" in path or "unembed" in path:
        # (V, D): vocab over model, feature over data (train) / model only (serve)
        if len(body) == 2:
            try_assign(body[0], tp)
            if not serve:
                try_assign(body[1], dp if len(dp) == 1 else dp[-1])
            return P(*names)

    if "expert" in path and len(body) >= 3:
        # (E, d, f): experts over model (EP), d_ff over data (F-TP) —
        # gate/up shard axis 2, down axis 1.  Train and serve share the
        # layout: expert weights are never gathered; the down-proj
        # partial sums psum over `data` instead (models/moe.py).
        has_data = "data" in env.axis_names
        try_assign(body[0], tp)
        if has_data:
            if "down" in path:
                try_assign(body[1], "data")
            else:
                try_assign(body[2], "data")
        return P(*names)

    if len(body) == 2:
        a, b = body
        if serve:
            # Megatron TP: shard the non-d_model dim over model
            if "w_down" in path or "proj_in" in path or "wo" in path:
                try_assign(a, tp)  # row-parallel: contraction dim sharded
            else:
                try_assign(b, tp)
        else:
            # 2-D FSDP
            try_assign(a, "data" if "data" in env.axis_names else None)
            try_assign(b, tp)
        return P(*names)

    # 1-D (norm scales, biases) and anything else: replicated
    return P(*names)


def infer_param_specs(param_tree, env: MeshEnv):
    """Build a PartitionSpec pytree parallel to ``param_tree``.

    ``param_tree`` may hold arrays or ShapeDtypeStructs.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_tree)
    specs = []
    for path, leaf in flat:
        path_str = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        specs.append(_spec_for(path_str, tuple(leaf.shape), env))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(param_tree), specs)


def param_shardings(param_tree, env: MeshEnv):
    specs = infer_param_specs(param_tree, env)
    return jax.tree.map(lambda s: env.sharding(s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batch / cache sharding rules
# ---------------------------------------------------------------------------

def batch_specs(batch_tree, env: MeshEnv, *, seq_sharded: bool = True):
    """Input batches: dim 0 = batch over DP, dim 1 = sequence over model
    (when divisible).  Frame/patch embeds follow the same rule."""
    def spec(leaf):
        names = [None] * len(leaf.shape)
        if leaf.shape and _divisible(leaf.shape[0], env, env.dp_axes):
            names[0] = env.dp_axes
        if (seq_sharded and len(leaf.shape) >= 2 and env.tp_axis
                and _divisible(leaf.shape[1], env, env.tp_axis)):
            names[1] = env.tp_axis
        return P(*names)

    return jax.tree.map(spec, batch_tree)


def cache_specs(cache_tree, env: MeshEnv, batch: int):
    """Decode caches.  Rules (cf. models/model.py cache layouts):

      * attention K/V (.../k, .../v, ndim>=4): sequence dim (-3) over
        `model` (split-K flash decode), batch dim over DP.
      * rolling-window K/V and kpos: replicated (tiny).
      * recurrent states (c/n/h/m/tail): batch dim over DP, rest
        replicated (states are O(B·d)).

    Batch dims are found by size match against ``batch`` (stacked leaves
    have the layer-group axis leading; group counts never equal the
    global batch in the assigned cells).
    """
    tp = env.tp_axis

    def spec(path, leaf):
        names = [None] * len(leaf.shape)
        last = str(getattr(path[-1], "key", path[-1])) if path else ""
        is_kv = last in ("k", "v") and len(leaf.shape) >= 4
        # batch dim: first dim equal to `batch` (skip when ambiguous)
        for i, d in enumerate(leaf.shape):
            if d == batch and _divisible(d, env, env.dp_axes):
                names[i] = env.dp_axes
                break
        if is_kv and tp is not None:
            sdim = len(leaf.shape) - 3
            if (names[sdim] is None
                    and _divisible(leaf.shape[sdim], env, tp)):
                names[sdim] = tp
        return P(*names)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = [spec(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def shardings_of(spec_tree, env: MeshEnv):
    return jax.tree.map(lambda s: env.sharding(s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
