"""JAX version compatibility for the distributed layer.

The repo targets the mesh APIs as they exist post-0.5 (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``) but CI pins ``jax==0.4.37``,
where ``shard_map`` still lives in ``jax.experimental`` (with
``check_rep`` instead of ``check_vma``) and ``make_mesh`` takes no
``axis_types``.  Everything that must actually *run* on the pinned
version — the vocab-sharded merge path and its multi-device tests —
routes through these shims instead of feature-detecting inline.
"""
from __future__ import annotations

from typing import Sequence

import jax

try:                                    # jax >= 0.5
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:                  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, any jax version.

    The merge collectives psum *inside* the body and return per-shard
    slices; the static replication checker can't see through the Pallas
    call, so both API generations run with it disabled.
    """
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: False})


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where the API has them."""
    try:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    except (AttributeError, TypeError):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
