"""Fault-tolerant checkpointing.

Atomic (write-to-tmp + rename), content-hashed, keep-N pruned pytree
checkpoints.  A checkpoint is a directory:

    step_000123/
      manifest.json   {step, meta, leaves: [{path, file, sha, dtype, shape}]}
      leaf_*.npy      one blob per pytree leaf

Restores are verified against the manifest hashes (a torn write or bit
rot surfaces as a hard error, not a silently-corrupt resume).  The tree
*structure* is rebuilt from the manifest paths, so the checkpoint format
is independent of in-memory dict ordering.

This is the persistence layer for both the Trainer state and the
MLego model store (core/store.py ships its own npz form for single
models; the CheckpointManager snapshots whole training states).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out.append((key, leaf))
    return out


def _unflatten_from_paths(paths: List[str], leaves: List[Any]):
    """Rebuild nested dicts/lists/tuples from 'a/b/0/c' style paths.

    Integer components become list indices, everything else dict keys.
    """
    root: Dict = {}
    for path, leaf in zip(paths, leaves):
        parts = path.split("/")
        node = root
        for i, part in enumerate(parts[:-1]):
            node = node.setdefault(part, {})
        node[parts[-1]] = leaf

    def fix(node):
        if not isinstance(node, dict):
            return node
        keys = list(node.keys())
        if keys and all(re.fullmatch(r"\d+", k) for k in keys):
            return [fix(node[str(i)]) for i in range(len(keys))]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def _sha(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dirs(self) -> List[Tuple[int, str]]:
        out = []
        for name in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.root, name,
                                                 "manifest.json")):
                out.append((int(m.group(1)), os.path.join(self.root, name)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        dirs = self._step_dirs()
        return dirs[-1][0] if dirs else None

    # ------------------------------------------------------------------
    def save(self, tree, meta: Optional[Dict] = None, step: int = 0) -> str:
        final = os.path.join(self.root, f"step_{step:09d}")
        tmp = tempfile.mkdtemp(dir=self.root, prefix=".tmp_")
        manifest = {"step": step, "meta": meta or {}, "leaves": []}
        try:
            for i, (path, leaf) in enumerate(_flatten_with_paths(tree)):
                arr = np.asarray(leaf)
                fname = f"leaf_{i:05d}.npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"].append({
                    "path": path, "file": fname,
                    "sha": _sha(os.path.join(tmp, fname)),
                    "dtype": str(arr.dtype), "shape": list(arr.shape),
                })
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)   # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._prune()
        return final

    def _prune(self) -> None:
        dirs = self._step_dirs()
        for _, d in dirs[: max(0, len(dirs) - self.keep)]:
            shutil.rmtree(d, ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: int, verify: bool = True
                ) -> Tuple[Any, Dict]:
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, leaves = [], []
        for e in manifest["leaves"]:
            blob = os.path.join(d, e["file"])
            if verify and _sha(blob) != e["sha"]:
                raise IOError(f"checksum mismatch: {blob}")
            arr = np.load(blob)
            paths.append(e["path"])
            leaves.append(arr)
        tree = _unflatten_from_paths(paths, leaves)
        meta = dict(manifest["meta"])
        meta.setdefault("step", manifest["step"])
        return tree, meta

    def restore_latest(self, verify: bool = True
                       ) -> Optional[Tuple[Any, Dict]]:
        step = self.latest_step()
        if step is None:
            return None
        return self.restore(step, verify=verify)
