"""Gradient / sufficient-statistic compression for DP all-reduces.

Two codecs, composable with error feedback (the residual of what
compression dropped is carried into the next step so the compressed
SGD still converges — Stich et al. style memory):

  * ``int8``  — per-tensor symmetric quantization.  8x smaller
    all-reduce payload; decode-sum-encode happens around the collective.
  * ``topk``  — magnitude top-k sparsification (dense-indexed form:
    values + int32 indices, 2k entries vs n).

The compressed all-reduce (``compressed_psum``) runs inside shard_map
over the DP axes: each rank encodes its shard-local gradient, payloads
are summed with ``lax.psum`` (int8 payloads are summed in int32 —
quantized sums stay exact until decode), then decoded once.  This is a
*beyond-paper* distributed-optimization feature; the LDA merge path
reuses the same codecs for cross-pod ``ΔN_kv`` merges, where int8 is
lossless whenever counts < 127 per bucket scale.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    codec: str = "none"          # none | int8 | topk
    topk_frac: float = 0.01      # fraction of entries kept by topk
    error_feedback: bool = True


# ---------------------------------------------------------------------------
# int8 codec
# ---------------------------------------------------------------------------

def int8_encode(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# top-k codec (dense payload: zeros elsewhere — psum-able)
# ---------------------------------------------------------------------------

def topk_sparsify(x: jnp.ndarray, frac: float) -> jnp.ndarray:
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


# ---------------------------------------------------------------------------
# compressed all-reduce with error feedback
# ---------------------------------------------------------------------------

def compressed_psum(grad: jnp.ndarray, residual: Optional[jnp.ndarray],
                    axis, cfg: CompressionConfig
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-reduce ``grad`` over mesh ``axis`` with compression.

    Must be called inside shard_map.  Returns (summed_grad, residual').
    """
    g = grad.astype(jnp.float32)
    if cfg.error_feedback and residual is not None:
        g = g + residual

    if cfg.codec == "none":
        out = jax.lax.psum(g, axis)
        return out, jnp.zeros_like(g)

    if cfg.codec == "int8":
        q, scale = int8_encode(g)
        sent = int8_decode(q, scale)
        # exact int32 sum of quantized payloads; max-scale decode
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        smax = jax.lax.pmax(scale, axis)
        # re-quantize against the shared scale so the sum decodes exactly
        q2 = jnp.clip(jnp.round(g / smax), -127, 127).astype(jnp.int32)
        sent = q2.astype(jnp.float32) * smax
        out = jax.lax.psum(q2, axis).astype(jnp.float32) * smax
        return out, g - sent

    if cfg.codec == "topk":
        sparse = topk_sparsify(g, cfg.topk_frac)
        out = jax.lax.psum(sparse, axis)
        return out, g - sparse

    raise ValueError(f"unknown codec {cfg.codec!r}")


def tree_compressed_psum(grads, residuals, axis, cfg: CompressionConfig):
    """Pytree version; residuals may be None on the first step."""
    if residuals is None:
        residuals = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                                 grads)
    pairs = jax.tree.map(
        lambda g, r: compressed_psum(g, r, axis, cfg), grads, residuals)
    out = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return out, res
