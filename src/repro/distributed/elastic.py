"""Elastic scaling & failure recovery for the MLego workload.

The materialized-model store makes elasticity *local*: when the worker
count changes (scale-up, scale-down, or a node failure), the covered
attribute space does not need retraining — ranges are re-partitioned to
the new worker count and each worker's model is re-derived by *merging*
the materialized range models that fall inside its new partition
(Alg. 1/2 are associative, so re-binning statistics is exact).  Only
ranges whose models were lost (failed node before materialization) are
retrained, and only those.

This module is host-side control logic; the heavy ops (merge) run
through core/merge.py (or the collective form on device).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.lda_default import LDAConfig
from repro.core.lda import MaterializedModel
from repro.core.merge import merged_theta
from repro.core.plans import Interval, subtract
from repro.core.store import ModelStore


@dataclasses.dataclass
class Partition:
    worker: int
    span: Interval
    model_ids: List[int]           # store models merged into this worker
    missing: List[Interval]        # ranges that must be (re)trained


def partition_ranges(universe: Interval, n_workers: int) -> List[Interval]:
    edges = np.linspace(universe.lo, universe.hi, n_workers + 1)
    return [Interval(float(a), float(b)) for a, b in zip(edges, edges[1:])]


def plan_repartition(store: ModelStore, universe: Interval, n_workers: int,
                     kind: str = "vb") -> List[Partition]:
    """Assign store models to the new worker partitions.

    A model is assigned to the worker whose span contains it; models
    straddling a boundary are left out (their range joins ``missing`` —
    the retrain set) so every worker's merge stays exact.
    """
    spans = partition_ranges(universe, n_workers)
    parts: List[Partition] = []
    for w, span in enumerate(spans):
        inside = [m for m in store.models(kind) if span.contains(m.o)]
        # greedy non-overlapping cover, largest models first
        inside.sort(key=lambda m: -(m.o.hi - m.o.lo))
        chosen: List[MaterializedModel] = []
        for m in inside:
            if all(not m.o.overlaps(c.o) for c in chosen):
                chosen.append(m)
        missing = subtract(span, [m.o for m in chosen])
        parts.append(Partition(w, span, [m.model_id for m in chosen],
                               missing))
    return parts


def apply_repartition(parts: Sequence[Partition], store: ModelStore,
                      cfg: LDAConfig, train_fn) -> Dict[int, MaterializedModel]:
    """Build each worker's model: retrain missing ranges, then merge.

    ``train_fn(lo, hi)`` trains + materializes one range (the
    MLegoSession.train_range signature).  Returns worker -> merged model.
    """
    out: Dict[int, MaterializedModel] = {}
    for part in parts:
        models = [store.get(mid) for mid in part.model_ids]
        for gap in part.missing:
            m = train_fn(gap.lo, gap.hi)
            if m is not None:
                models.append(m)
        if not models:
            continue
        theta, kind = merged_theta(models, cfg)
        n_docs = sum(m.n_docs for m in models)
        n_tokens = sum(m.n_tokens for m in models)
        out[part.worker] = MaterializedModel(
            -(part.worker + 1), part.span, n_docs, n_tokens, kind, theta)
    return out


def recover_failed(store: ModelStore, failed_ranges: Sequence[Interval],
                   train_fn) -> List[MaterializedModel]:
    """Node-failure recovery: retrain exactly the lost ranges.

    Because Alg. 1/2 merges are order-independent reductions, a lost
    partition's delta is simply absent — recovery is local retraining
    of the lost ranges, then normal merging; nothing global restarts.
    """
    fresh = []
    for r in failed_ranges:
        covered = [m.o for m in store.models() if r.contains(m.o)]
        for gap in subtract(r, covered):
            m = train_fn(gap.lo, gap.hi)
            if m is not None:
                fresh.append(m)
    return fresh


def recover_quarantined(store: ModelStore, train_fn, *,
                        clear: bool = True) -> List[MaterializedModel]:
    """Retrain the ranges of the store's quarantined blobs.

    ``ModelStore.load(on_corrupt="quarantine")`` and runtime
    ``store.quarantine`` leave a ledger of blobs the store dropped
    (checksum mismatch, truncation, device loss mid-write); each entry
    carries the original range ``o``.  This is the same local-recovery
    argument as ``recover_failed``: a dropped blob is just a missing
    range, so recovery is retraining exactly those ranges — restricted
    to the parts not already covered by healthy capital (a re-ingested
    or compacted replacement makes retraining moot).  ``train_fn``
    persists through the normal path (``MLegoSession.train_range``),
    so the replacement blobs are checksummed and crash-safe.  With
    ``clear=True`` the ledger is drained afterwards — the quarantine
    has been acted on.
    """
    lost = [q.o for q in store.quarantined]
    fresh = recover_failed(store, lost, train_fn)
    if clear:
        store.clear_quarantined()
    return fresh
