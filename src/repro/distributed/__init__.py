from repro.distributed.sharding import (
    MeshEnv,
    get_env,
    set_env,
    single_device_env,
    infer_param_specs,
    constrain,
)

__all__ = [
    "MeshEnv",
    "get_env",
    "set_env",
    "single_device_env",
    "infer_param_specs",
    "constrain",
]
