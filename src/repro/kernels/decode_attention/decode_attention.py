"""Pallas TPU kernel: split-K flash decode over a KV cache.

One new token (per sequence) attends to a cache of S entries.  The
compute is a (G, hd)·(hd, S) matvec-batch — pure HBM-bandwidth over the
cache.  The kernel splits the cache axis across the innermost grid dim
(split-K) and carries partial softmax state (acc, m, l) in VMEM
scratch, exactly mirroring the cross-device split-K combine that
models/attention.decode_attention performs over the "model" mesh axis —
device-level and core-level splits compose.

Grid: (B, KVH, S/BK).  Blocks: q (1,1,G,hd), k/v (1,1,BK,hd).
The position bound (kpos <= pos, windowed lower bound) is applied from
a scalar-prefetch operand so cache positions beyond the current decode
position are masked without host round-trips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bk: int, g: int, window: int, n_k: int, scale: float):
    ik = pl.program_id(2)
    pos = pos_ref[0]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k0 = ik * bk
    relevant = k0 <= pos
    if window > 0:
        relevant = relevant & (k0 + bk - 1 > pos - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)             # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (G, BK)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
        mask = kpos <= pos
        if window > 0:
            mask &= kpos > pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        coef = jnp.exp(m_prev - m_new)
        l_ref[:, :1] = l_ref[:, :1] * coef + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * coef + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[:, :1] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, pos, *, window: int = 0,
                            block_k: int = 512, interpret: bool = False):
    """q: (B, 1, H, hd); caches: (B, S, KVH, hd) -> (B, 1, H, hd)."""
    b, _, h, hd = q.shape
    s = k_cache.shape[1]
    kvh = k_cache.shape[2]
    g = h // kvh
    bk = min(block_k, s)
    n_k = pl.cdiv(s, bk)

    qg = q.reshape(b, kvh, g, hd)
    kg = k_cache.transpose(0, 2, 1, 3)
    vg = v_cache.transpose(0, 2, 1, 3)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(_kernel, bk=bk, g=g, window=window, n_k=n_k,
                               scale=hd ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, kvh, n_k),
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), lambda b, h, ik, pos: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bk, hd), lambda b, h, ik, pos: (b, h, ik, 0)),
                pl.BlockSpec((1, 1, bk, hd), lambda b, h, ik, pos: (b, h, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda b, h, ik, pos: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, hd), jnp.float32),
                pltpu.VMEM((g, 128), jnp.float32),
                pltpu.VMEM((g, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        interpret=interpret,
    )(pos_arr, qg, kg, vg)
    return out.reshape(b, 1, h, hd)
