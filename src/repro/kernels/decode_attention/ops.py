"""jit'd public wrapper for the split-K decode attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "block_k",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     block_k: int = 512, interpret: bool = None):
    """q: (B, 1, H, hd); caches: (B, S, KVH, hd) -> (B, 1, H, hd)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return decode_attention_pallas(q, k_cache, v_cache, pos, window=window,
                                   block_k=block_k, interpret=interpret)
