"""jit'd public wrapper for the split-K decode attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas,
)


@functools.partial(jax.jit, static_argnames=("window", "block_k",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     block_k: int = 512, interpret: bool = None):
    """q: (B, 1, H, hd); caches: (B, S, KVH, hd) -> (B, 1, H, hd)."""
    interpret = default_interpret(interpret)
    return decode_attention_pallas(q, k_cache, v_cache, pos, window=window,
                                   block_k=block_k, interpret=interpret)
