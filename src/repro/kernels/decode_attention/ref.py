"""Pure-jnp oracle for split-K flash decode (same math as
kernels/flash_attention/ref.decode_attention_ref, re-exported so each
kernel directory is self-contained)."""
from repro.kernels.flash_attention.ref import decode_attention_ref

__all__ = ["decode_attention_ref"]
