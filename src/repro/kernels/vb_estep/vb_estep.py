"""Pallas TPU kernel: fused LDA VB E-step.

One grid step owns a block of documents and runs the whole
coordinate-ascent fixed point in VMEM:

    repeat n_iters:
        eeθ     = exp(ψ(γ) − ψ(Σγ))          (VPU, fused digamma)
        phinorm = eeθ @ eeβ                   (MXU,  BD×K @ K×V)
        γ       = α + eeθ * ((x/phinorm) @ eeβᵀ)   (MXU, BD×V @ V×K)

and finally accumulates this block's sufficient statistics
    sstats += eeθᵀ @ (x/phinorm) * eeβ        (MXU, K×BD @ BD×V)
into a revisited output block (grid is sequential on TPU, so the
accumulation is race-free).

Tiling: BD documents × full V in VMEM.  K is padded to 128 (MXU lane),
V to a 128 multiple.  The digamma is an 8-term shift + asymptotic
series — pure VPU ops, no transcendental table lookups.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _digamma(x):
    """ψ(x) for x > 0 — recurrence shift to x >= 8, then asymptotic."""
    shift = jnp.zeros_like(x)
    for _ in range(8):
        small = x < 8.0
        shift = shift - jnp.where(small, 1.0 / x, 0.0)
        x = jnp.where(small, x + 1.0, x)
    inv = 1.0 / x
    inv2 = inv * inv
    # ψ(x) ≈ ln x − 1/(2x) − 1/(12x²) + 1/(120x⁴) − 1/(252x⁶)
    series = (jnp.log(x) - 0.5 * inv
              - inv2 * (1.0 / 12.0 - inv2 * (1.0 / 120.0 - inv2 / 252.0)))
    return series + shift


def _exp_dirichlet(g):
    return jnp.exp(_digamma(g) - _digamma(g.sum(-1, keepdims=True)))


def _kernel(x_ref, eeb_ref, g0_ref, gamma_out, sstats_out, *, alpha: float,
            n_iters: int):
    i = pl.program_id(0)
    x = x_ref[...]
    eeb = eeb_ref[...]

    def body(_, gamma):
        eet = _exp_dirichlet(gamma)
        phinorm = jnp.dot(eet, eeb, preferred_element_type=jnp.float32) + 1e-30
        ratio = x / phinorm
        gamma = alpha + eet * jnp.dot(ratio, eeb.T,
                                      preferred_element_type=jnp.float32)
        return gamma

    gamma = jax.lax.fori_loop(0, n_iters, body, g0_ref[...])
    eet = _exp_dirichlet(gamma)
    phinorm = jnp.dot(eet, eeb, preferred_element_type=jnp.float32) + 1e-30
    part = jnp.dot(eet.T, x / phinorm,
                   preferred_element_type=jnp.float32) * eeb
    gamma_out[...] = gamma

    @pl.when(i == 0)
    def _init():
        sstats_out[...] = jnp.zeros_like(sstats_out)

    sstats_out[...] += part


def vb_estep_pallas(x, exp_elog_beta, gamma0, alpha: float, n_iters: int,
                    *, block_d: int = 128, interpret: bool = False):
    """x: (D, V) f32; exp_elog_beta: (K, V) f32; gamma0: (D, K) f32."""
    d, v = x.shape
    k = exp_elog_beta.shape[0]
    bd = min(block_d, d)
    n_blocks = pl.cdiv(d, bd)

    kernel = functools.partial(_kernel, alpha=alpha, n_iters=n_iters)
    gamma, sstats = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((bd, v), lambda i: (i, 0)),
            pl.BlockSpec((k, v), lambda i: (0, 0)),
            pl.BlockSpec((bd, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bd, k), lambda i: (i, 0)),
            pl.BlockSpec((k, v), lambda i: (0, 0)),   # revisited: accumulate
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, k), jnp.float32),
            jax.ShapeDtypeStruct((k, v), jnp.float32),
        ],
        interpret=interpret,
    )(x, exp_elog_beta, gamma0)
    return gamma, sstats
