"""jit'd public wrapper for the fused VB E-step kernel.

On this CPU host the kernel runs in interpret mode (correctness path);
on TPU it compiles to Mosaic.  The wrapper pads K to 128 and V to a
128-multiple (MXU alignment) and strips the padding on the way out —
pad topics receive exp(ψ(0-ish)) ≈ 0 mass and contribute nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.vb_estep.vb_estep import vb_estep_pallas


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("alpha", "n_iters", "block_d",
                                             "interpret"))
def vb_estep(x, exp_elog_beta, gamma0, alpha: float, n_iters: int,
             *, block_d: int = 128, interpret: bool = None):
    """Drop-in fused replacement for core.vb.vb_estep's inner loop."""
    interpret = default_interpret(interpret)
    d, v = x.shape
    k = exp_elog_beta.shape[0]
    kp, vp = _round_up(k, 128), _round_up(v, 128)
    # D must pad to a whole number of doc blocks: a ragged boundary
    # block would stream out-of-bounds rows into the sstats reduction
    # (x pads are zero, so whole pad blocks contribute nothing).
    bd = min(block_d, _round_up(d, 8))
    dp = _round_up(d, bd)
    block_d = bd
    # named scope: HLO metadata + jax.profiler timelines attribute the
    # launch to the MLego op by name
    with jax.named_scope("mlego.vb_estep"):
        if (kp, vp, dp) != (k, v, d):
            x = jnp.pad(x, ((0, dp - d), (0, vp - v)))
            # pad eeβ with ~0 (tiny positive keeps phinorm finite)
            exp_elog_beta = jnp.pad(exp_elog_beta,
                                    ((0, kp - k), (0, vp - v)),
                                    constant_values=1e-30)
            gamma0 = jnp.pad(gamma0, ((0, dp - d), (0, kp - k)),
                             constant_values=alpha)
        gamma, sstats = vb_estep_pallas(x, exp_elog_beta, gamma0, alpha,
                                        n_iters, block_d=block_d,
                                        interpret=interpret)
        return gamma[:d, :k], sstats[:k, :v]
