"""Pure-jnp oracle for the fused VB E-step kernel.

Identical math to core/vb.vb_estep (the kernel exists because this is
LDA's compute hot spot: 2 MXU matmuls per inner iteration over the
doc-term block, fused with the exp(digamma) Dirichlet expectation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def exp_dirichlet_expectation(x):
    return jnp.exp(
        jax.scipy.special.digamma(x)
        - jax.scipy.special.digamma(x.sum(-1, keepdims=True)))


def vb_estep_ref(x, exp_elog_beta, gamma0, alpha: float, n_iters: int):
    """x: (D, V); exp_elog_beta: (K, V); gamma0: (D, K).

    Returns (gamma (D, K), sstats (K, V)).
    """
    def body(gamma, _):
        ee_theta = exp_dirichlet_expectation(gamma)
        phinorm = ee_theta @ exp_elog_beta + 1e-30
        gamma = alpha + ee_theta * ((x / phinorm) @ exp_elog_beta.T)
        return gamma, None

    gamma, _ = jax.lax.scan(body, gamma0, None, length=n_iters)
    ee_theta = exp_dirichlet_expectation(gamma)
    phinorm = ee_theta @ exp_elog_beta + 1e-30
    sstats = (ee_theta.T @ (x / phinorm)) * exp_elog_beta
    return gamma, sstats
