"""Pure-jnp oracle for the doc-blocked CGS sweep kernel.

One *blocked* Gibbs sweep (the DSGS fixed-prior approximation applied
across doc blocks within a partition): every block resamples its
tokens sequentially against a frozen per-sweep snapshot of the
topic-word counts (``prior`` = local ``n_kv`` snapshot + global
``N_kv`` + β), while its document-topic counts ``n_kd`` stay exact —
documents never span blocks, so ``n_kd`` rows are block-private.
Blocks are independent given the snapshot, which is what lets the
sweep vmap across them (sequential chain length drops from Σ tokens to
max tokens-per-block); the kernel runs the identical math with one
grid step per block.

The only cross-block coupling is the *decrement* of the current
token's own assignment (it is still in the snapshot, so ``num``/``den``
stay ≥ β > 0) and the count reduction after the sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _sweep_block(words, ldoc, mask, u, z, nkd, prior, prior_k,
                 alpha: float, k_real: int):
    """Resample one doc block's tokens sequentially.

    words/ldoc/mask/u/z: (T,); nkd: (BD, K); prior: (K, V) snapshot
    counts + global counts + β; prior_k: (K,) its row sums (with Vβ).
    Returns (z', nkd').
    """
    k = prior.shape[0]
    kidx = jnp.arange(k)
    valid = (kidx < k_real).astype(jnp.float32)

    def token_step(carry, t):
        z, nkd = carry
        w = words[t]
        d = ldoc[t]
        m = mask[t]
        old = z[t]
        oh_old = (kidx == old).astype(jnp.float32) * m
        nd = nkd[d] - oh_old                      # exact doc-topic counts
        num = prior[:, w] - oh_old                # stale n_kv, own token out
        den = prior_k - oh_old
        p = valid * (nd + alpha) * num / den      # Eq. 7 w/ DSGS prior
        c = jnp.cumsum(p)
        new = jnp.searchsorted(c, u[t] * c[-1])
        new = jnp.clip(new, 0, k_real - 1)
        new = jnp.where(m > 0, new, old).astype(z.dtype)
        oh_new = (kidx == new).astype(jnp.float32) * m
        nkd = nkd.at[d].add(oh_new - oh_old)
        z = z.at[t].set(new)
        return (z, nkd), None

    (z, nkd), _ = jax.lax.scan(token_step, (z, nkd),
                               jnp.arange(words.shape[0]))
    return z, nkd


def gibbs_sweep_ref(words, ldoc, mask, u, z, nkd, prior, prior_k,
                    alpha: float, k_real: int = None):
    """One blocked CGS sweep over all doc blocks (vmapped).

    words/ldoc/mask/u/z: (B, T); nkd: (B, BD, K); prior: (K, V);
    prior_k: (K,).  Returns (z', nkd', nkv) with nkv (K, V) the token
    counts of the *new* assignments summed over blocks — the caller
    turns these into the next sweep's snapshot / the final ΔN_kv.
    """
    k, v = prior.shape
    k_real = k if k_real is None else k_real
    block = functools.partial(_sweep_block, alpha=alpha, k_real=k_real)
    z, nkd = jax.vmap(block, in_axes=(0, 0, 0, 0, 0, 0, None, None))(
        words, ldoc, mask, u, z, nkd, prior, prior_k)
    nkv = jnp.zeros((k, v), jnp.float32).at[
        z.ravel(), words.ravel()].add(mask.ravel())
    return z, nkd, nkv
