"""Pallas TPU kernel: doc-blocked collapsed-Gibbs sweep.

One grid step owns one *doc block* and keeps the whole sampler state
on-chip: the block's token assignments ``z`` (1, T) and its exact
document-topic counts ``n_kd`` (BD, K) live in VMEM for the entire
sweep, while every block samples against the same frozen per-sweep
snapshot of the topic-word counts (``prior`` = local n_kv + global
N_kv + β — the DSGS Eq. 8 fixed-prior approximation applied across
blocks).  Per token:

    oh      = onehot(z_t)                    (VPU compare on the K lane)
    p       = (n_kd[d] − oh + α)(prior[:,w] − oh)/(prior_k − oh)
    z_t     = inverse-CDF sample via cumsum + count(c < u·Σp)
    n_kd[d] += onehot(z_t) − oh              (dynamic_update_slice)

and the block streams its new token counts into a revisited (K, V)
output block (grid is sequential on TPU, so the accumulation is
race-free — same pattern as vb_estep's sstats).

The topic-word snapshot is passed *transposed* as ``prior_t`` (V, K)
so the per-token gather is a (1, K) dynamic row slice on the lane
axis, not a strided column read.  Uniforms are precomputed outside
(one (B, T) array per sweep) — sampling stays bit-identical to the
jnp reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(words_ref, ldoc_ref, mask_ref, u_ref, z_ref, nkd_ref,
            prior_t_ref, priork_ref, z_out, nkd_out, nkv_out,
            *, alpha: float, k_real: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        nkv_out[...] = jnp.zeros_like(nkv_out)

    words = words_ref[...]            # (1, T) i32
    ldoc = ldoc_ref[...]              # (1, T) i32
    mask = mask_ref[...]              # (1, T) f32
    u = u_ref[...]                    # (1, T) f32
    prior_t = prior_t_ref[...]        # (V, K) f32
    prior_k = priork_ref[...]         # (1, K) f32

    t_len = words.shape[1]
    k = prior_t.shape[1]
    kiota = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    valid = (kiota < k_real).astype(jnp.float32)

    def token(t, carry):
        z, nkd = carry                # (1, T) i32, (1, BD, K) f32
        w = words[0, t]
        d = ldoc[0, t]
        m = mask[0, t]
        old = z[0, t]
        oh_old = (kiota == old).astype(jnp.float32) * m          # (1, K)
        nd = jax.lax.dynamic_slice(nkd, (0, d, 0), (1, 1, k))[0] - oh_old
        num = jax.lax.dynamic_slice(prior_t, (w, 0), (1, k)) - oh_old
        den = prior_k - oh_old
        p = valid * (nd + alpha) * num / den                     # (1, K)
        c = jnp.cumsum(p, axis=1)
        target = u[0, t] * c[0, k - 1]
        new = jnp.sum((c < target).astype(jnp.int32))            # searchsorted
        new = jnp.clip(new, 0, k_real - 1)
        new = jnp.where(m > 0, new, old)
        oh_new = (kiota == new).astype(jnp.float32) * m
        nkd = jax.lax.dynamic_update_slice(
            nkd, (nd + oh_new)[None], (0, d, 0))
        z = jax.lax.dynamic_update_slice(
            z, new.reshape(1, 1).astype(z.dtype), (0, t))
        # stream the new assignment's count into the shared reduction
        cur = pl.load(nkv_out, (pl.ds(new, 1), pl.ds(w, 1)))
        pl.store(nkv_out, (pl.ds(new, 1), pl.ds(w, 1)), cur + m)
        return z, nkd

    z, nkd = jax.lax.fori_loop(0, t_len, token,
                               (z_ref[...], nkd_ref[...]))
    z_out[...] = z
    nkd_out[...] = nkd


def gibbs_sweep_pallas(words, ldoc, mask, u, z, nkd, prior_t, prior_k,
                       alpha: float, k_real: int, *,
                       interpret: bool = False):
    """One blocked CGS sweep; grid = doc blocks.

    words/ldoc/mask/u/z: (B, T); nkd: (B, BD, K); prior_t: (V, K)
    transposed snapshot (+global +β); prior_k: (1, K) row sums.
    Returns (z', nkd', nkv (K, V)) — nkv is the new assignments' token
    counts summed over all blocks.
    """
    b, t = words.shape
    _, bd, k = nkd.shape
    v = prior_t.shape[0]
    kernel = functools.partial(_kernel, alpha=alpha, k_real=k_real)
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            row, row, row, row, row,
            pl.BlockSpec((1, bd, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((v, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            row,
            pl.BlockSpec((1, bd, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((k, v), lambda i: (0, 0)),   # revisited: accumulate
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t), z.dtype),
            jax.ShapeDtypeStruct((b, bd, k), jnp.float32),
            jax.ShapeDtypeStruct((k, v), jnp.float32),
        ],
        interpret=interpret,
    )(words, ldoc, mask, u, z, nkd, prior_t, prior_k)
