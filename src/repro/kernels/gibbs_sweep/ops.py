"""jit'd public wrapper for the doc-blocked CGS sweep kernel.

``gibbs_sweep`` runs ONE blocked sweep.  Route selection mirrors the
other kernel packages but adds a host route: on TPU (or when
``MLEGO_KERNEL_INTERPRET=1`` forces the CI correctness leg) the Pallas
kernel body executes; everywhere else the vmapped jnp reference runs —
it is the same math, and XLA's batched lowering of the vmap IS the
blocked algorithm's speedup on hosts (sequential chain length drops
from Σ tokens to max tokens-per-block).  Interpret-mode Pallas would
serialize the grid and forfeit exactly that win, so it is reserved for
the kernel-exercising CI leg.

The kernel path pads K/V/T/BD to tile alignment (K, T lane-padded to
128; V, BD sublane-padded to 8 — V also to 128 for the (K, V) count
output) and strips the padding on the way out; pad topics are masked
out of the conditional (``k_real``), pad tokens carry zero mask, and
the snapshot is fed to the kernel transposed as (V, K) so the
per-token topic gather is a lane-aligned row slice.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, interpret_forced, on_tpu
from repro.kernels.gibbs_sweep.gibbs_sweep import gibbs_sweep_pallas
from repro.kernels.gibbs_sweep.ref import gibbs_sweep_ref


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def default_use_kernel(use_kernel: Optional[bool] = None) -> bool:
    """Resolve the kernel-vs-host-route default (see module docstring)."""
    if use_kernel is not None:
        return use_kernel
    return interpret_forced() or on_tpu()


@functools.partial(jax.jit, static_argnames=("alpha", "use_kernel",
                                             "interpret"))
def gibbs_sweep(words, ldoc, mask, u, z, nkd, prior, prior_k,
                alpha: float, *, use_kernel: Optional[bool] = None,
                interpret: Optional[bool] = None):
    """One blocked CGS sweep.

    words/ldoc/mask/u/z: (B, T); nkd: (B, BD, K); prior: (K, V)
    snapshot + global + β; prior_k: (K,) row sums (with Vβ).
    Returns (z', nkd', nkv (K, V)) with nkv the new assignments' count
    matrix (the next snapshot / final ΔN_kv source).
    """
    use_kernel = default_use_kernel(use_kernel)
    k, v = prior.shape
    if not use_kernel:
        return gibbs_sweep_ref(words, ldoc, mask, u, z, nkd, prior,
                               prior_k, alpha)
    interpret = default_interpret(interpret)
    b, t = words.shape
    bd = nkd.shape[1]
    kp, vp = _round_up(k, 128), _round_up(v, 128)
    tp, bdp = _round_up(t, 128), _round_up(bd, 8)
    # named scope: HLO metadata + jax.profiler timelines attribute the
    # launch to the MLego op by name
    with jax.named_scope("mlego.gibbs_sweep"):
        if (kp, vp, tp, bdp) != (k, v, t, bd):
            pad_row = ((0, 0), (0, tp - t))
            words = jnp.pad(words, pad_row)
            ldoc = jnp.pad(ldoc, pad_row)
            mask = jnp.pad(mask, pad_row)
            u = jnp.pad(u, pad_row)
            z = jnp.pad(z, pad_row)
            nkd = jnp.pad(nkd, ((0, 0), (0, bdp - bd), (0, kp - k)))
            # pad topics/words carry 1.0 so den stays finite; they are
            # masked out of the conditional via k_real and never sampled
            prior = jnp.pad(prior, ((0, kp - k), (0, vp - v)),
                            constant_values=1.0)
            prior_k = jnp.pad(prior_k, (0, kp - k), constant_values=1.0)
        z_new, nkd_new, nkv = gibbs_sweep_pallas(
            words, ldoc, mask, u, z, nkd,
            jnp.transpose(prior), prior_k.reshape(1, kp),
            alpha, k, interpret=interpret)
        return z_new[:, :t], nkd_new[:, :bd, :k], nkv[:k, :v]
