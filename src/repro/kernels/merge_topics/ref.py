"""Pure-jnp oracle for the weighted topic-statistic merge (Alg. 1/2).

    out = bias + sum_i w_i * (stats_i - base)

covers both merges:
  MVB (Alg. 1): bias = eta,  base = eta   (λ* = η + Σ w_i (λ_i − η))
  MGS (Alg. 2): bias = 0,    base = 0,  w_i = decay^{s_i}
"""
from __future__ import annotations

import jax.numpy as jnp


def merge_topics_ref(stats, weights, bias: float = 0.0, base: float = 0.0):
    """stats: (n, K, V); weights: (n,).  Returns (K, V)."""
    w = weights.astype(jnp.float32)[:, None, None]
    return bias + (w * (stats.astype(jnp.float32) - base)).sum(0)


def merge_topics_batched_ref(stats, weights, bias: float = 0.0,
                             base: float = 0.0):
    """stats: (b, n, K, V); weights: (b, n).  Returns (b, K, V)."""
    w = weights.astype(jnp.float32)[:, :, None, None]
    return bias + (w * (stats.astype(jnp.float32) - base)).sum(1)
