"""Pallas TPU kernel: weighted K×V statistic merge (memory-bound).

The paper's Alg. 1/2 merge is one pass over n' topic-word matrices —
pure HBM bandwidth.  The kernel fuses (subtract base, scale by weight
/ decay, accumulate, add bias) into a single read of each (K, V) tile,
so HBM traffic is exactly n'·K·V·4 bytes read + K·V·4 written (the
unfused jnp chain reads/writes intermediates ~3x).

Grid: (K/BK, V/BV); each step streams all n models' tiles (the n axis
is in the block: (n, BK, BV) — n' is small, ≤ ~64 in every paper
workload, so the tile set fits VMEM).

``merge_topics_ragged_pallas`` is the segmented (CSR) form: a batch of
b independent merges with *different* part counts flattened into one
(R, K, V) row stack plus per-row segment ids — one launch, zero pad
rows on any batch shape.  The segment id array rides as a scalar-
prefetch operand so the output index map can route row r's tile to
block ``seg_ids[r]`` (data-dependent output blocking).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(stats_ref, w_ref, out_ref, *, bias: float, base: float):
    s = stats_ref[...].astype(jnp.float32)          # (n, BK, BV)
    w = w_ref[...].astype(jnp.float32)              # (n, 1)
    acc = jnp.sum(w[:, :, None] * (s - base), axis=0)
    out_ref[...] = acc + bias


def merge_topics_pallas(stats, weights, bias: float = 0.0, base: float = 0.0,
                        *, block_k: int = 128, block_v: int = 512,
                        interpret: bool = False):
    """stats: (n, K, V) f32; weights: (n,) f32 -> (K, V) f32."""
    n, k, v = stats.shape
    bk = min(block_k, k)
    bv = min(block_v, v)
    w2 = weights.reshape(n, 1).astype(jnp.float32)
    kernel = functools.partial(_kernel, bias=bias, base=base)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(k, bk), pl.cdiv(v, bv)),
        in_specs=[
            pl.BlockSpec((n, bk, bv), lambda i, j: (0, i, j)),
            pl.BlockSpec((n, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bk, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, v), jnp.float32),
        interpret=interpret,
    )(stats, w2)


def _batched_kernel(stats_ref, w_ref, out_ref, *, bias: float, base: float):
    s = stats_ref[0].astype(jnp.float32)            # (n, BK, BV)
    w = w_ref[0].astype(jnp.float32)                # (n, 1)
    acc = jnp.sum(w[:, :, None] * (s - base), axis=0)
    out_ref[0] = acc + bias


def merge_topics_batched_pallas(stats, weights, bias: float = 0.0,
                                base: float = 0.0, *, block_k: int = 128,
                                block_v: int = 512, interpret: bool = False):
    """Batch of independent merges in one launch.

    stats: (b, n, K, V) f32; weights: (b, n) f32 -> (b, K, V) f32.
    One grid step per (query, K-tile, V-tile); ragged batches pad the
    n axis with zero-weight rows (0·(s − base) contributes nothing),
    so b queries with different part counts share a single launch.
    """
    b, n, k, v = stats.shape
    bk = min(block_k, k)
    bv = min(block_v, v)
    w3 = weights.reshape(b, n, 1).astype(jnp.float32)
    kernel = functools.partial(_batched_kernel, bias=bias, base=base)
    return pl.pallas_call(
        kernel,
        grid=(b, pl.cdiv(k, bk), pl.cdiv(v, bv)),
        in_specs=[
            pl.BlockSpec((1, n, bk, bv), lambda q, i, j: (q, 0, i, j)),
            pl.BlockSpec((1, n, 1), lambda q, i, j: (q, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bk, bv), lambda q, i, j: (q, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, k, v), jnp.float32),
        interpret=interpret,
    )(stats, w3)


def _ragged_kernel(seg_ref, stats_ref, w_ref, out_ref, *, bias: float,
                   base: float):
    r = pl.program_id(2)
    prev = seg_ref[jnp.maximum(r - 1, 0)]
    is_start = jnp.logical_or(r == 0, seg_ref[r] != prev)
    s = stats_ref[0].astype(jnp.float32)            # (BK, BV)
    w = w_ref[0, 0].astype(jnp.float32)
    contrib = w * (s - base)

    @pl.when(is_start)
    def _():
        out_ref[0] = contrib + bias

    @pl.when(jnp.logical_not(is_start))
    def _():
        out_ref[0] += contrib


def merge_topics_ragged_pallas(stats, weights, seg_ids, num_segments: int,
                               bias: float = 0.0, base: float = 0.0, *,
                               block_k: int = 128, block_v: int = 512,
                               interpret: bool = False):
    """Segmented merge: b ragged queries, one launch, zero pad rows.

    stats: (R, K, V) f32 — every query's part rows concatenated;
    weights: (R,) f32; seg_ids: (R,) int32 non-decreasing, seg_ids[r]
    names the query row r belongs to -> (num_segments, K, V) f32.

    The row axis is the *innermost* grid axis, so all rows of one
    segment revisit their shared output block on consecutive grid
    steps — the Pallas TPU requirement for read-modify-write output
    accumulation.  ``seg_ids`` is a scalar-prefetch operand: the output
    index map reads it to pick the destination block, and the kernel
    body compares seg_ids[r] against seg_ids[r-1] to detect segment
    starts (initialize with bias) vs continuations (accumulate).
    """
    n_rows, k, v = stats.shape
    bk = min(block_k, k)
    bv = min(block_v, v)
    w2 = weights.reshape(n_rows, 1).astype(jnp.float32)
    kernel = functools.partial(_ragged_kernel, bias=bias, base=base)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(pl.cdiv(k, bk), pl.cdiv(v, bv), n_rows),
        in_specs=[
            pl.BlockSpec((1, bk, bv), lambda i, j, r, seg: (r, i, j)),
            pl.BlockSpec((1, 1), lambda i, j, r, seg: (r, 0)),
        ],
        out_specs=pl.BlockSpec((1, bk, bv),
                               lambda i, j, r, seg: (seg[r], i, j)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_segments, k, v), jnp.float32),
        interpret=interpret,
    )(seg_ids, stats, w2)
