"""Pallas TPU kernel: weighted K×V statistic merge (memory-bound).

The paper's Alg. 1/2 merge is one pass over n' topic-word matrices —
pure HBM bandwidth.  The kernel fuses (subtract base, scale by weight
/ decay, accumulate, add bias) into a single read of each (K, V) tile,
so HBM traffic is exactly n'·K·V·4 bytes read + K·V·4 written (the
unfused jnp chain reads/writes intermediates ~3x).

Grid: (K/BK, V/BV); each step streams all n models' tiles (the n axis
is in the block: (n, BK, BV) — n' is small, ≤ ~64 in every paper
workload, so the tile set fits VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(stats_ref, w_ref, out_ref, *, bias: float, base: float):
    s = stats_ref[...].astype(jnp.float32)          # (n, BK, BV)
    w = w_ref[...].astype(jnp.float32)              # (n, 1)
    acc = jnp.sum(w[:, :, None] * (s - base), axis=0)
    out_ref[...] = acc + bias


def merge_topics_pallas(stats, weights, bias: float = 0.0, base: float = 0.0,
                        *, block_k: int = 128, block_v: int = 512,
                        interpret: bool = False):
    """stats: (n, K, V) f32; weights: (n,) f32 -> (K, V) f32."""
    n, k, v = stats.shape
    bk = min(block_k, k)
    bv = min(block_v, v)
    w2 = weights.reshape(n, 1).astype(jnp.float32)
    kernel = functools.partial(_kernel, bias=bias, base=base)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(k, bk), pl.cdiv(v, bv)),
        in_specs=[
            pl.BlockSpec((n, bk, bv), lambda i, j: (0, i, j)),
            pl.BlockSpec((n, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bk, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, v), jnp.float32),
        interpret=interpret,
    )(stats, w2)


def _batched_kernel(stats_ref, w_ref, out_ref, *, bias: float, base: float):
    s = stats_ref[0].astype(jnp.float32)            # (n, BK, BV)
    w = w_ref[0].astype(jnp.float32)                # (n, 1)
    acc = jnp.sum(w[:, :, None] * (s - base), axis=0)
    out_ref[0] = acc + bias


def merge_topics_batched_pallas(stats, weights, bias: float = 0.0,
                                base: float = 0.0, *, block_k: int = 128,
                                block_v: int = 512, interpret: bool = False):
    """Batch of independent merges in one launch.

    stats: (b, n, K, V) f32; weights: (b, n) f32 -> (b, K, V) f32.
    One grid step per (query, K-tile, V-tile); ragged batches pad the
    n axis with zero-weight rows (0·(s − base) contributes nothing),
    so b queries with different part counts share a single launch.
    """
    b, n, k, v = stats.shape
    bk = min(block_k, k)
    bv = min(block_v, v)
    w3 = weights.reshape(b, n, 1).astype(jnp.float32)
    kernel = functools.partial(_batched_kernel, bias=bias, base=base)
    return pl.pallas_call(
        kernel,
        grid=(b, pl.cdiv(k, bk), pl.cdiv(v, bv)),
        in_specs=[
            pl.BlockSpec((1, n, bk, bv), lambda q, i, j: (q, 0, i, j)),
            pl.BlockSpec((1, n, 1), lambda q, i, j: (q, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bk, bv), lambda q, i, j: (q, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, k, v), jnp.float32),
        interpret=interpret,
    )(stats, w3)
