"""jit'd public wrapper for the merge_topics kernel.

``merge_vb_stats`` / ``merge_gs_stats`` map the paper's Alg. 1/2 onto
the fused kernel; core/merge.py stays the host/NumPy reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.merge_topics.merge_topics import merge_topics_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("bias", "base", "interpret"))
def merge_topics(stats, weights, bias: float = 0.0, base: float = 0.0,
                 *, interpret: bool = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    n, k, v = stats.shape
    kp, vp = _round_up(k, 8), _round_up(v, 128)
    if (kp, vp) != (k, v):
        stats = jnp.pad(stats, ((0, 0), (0, kp - k), (0, vp - v)),
                        constant_values=base)
    out = merge_topics_pallas(stats, weights, bias, base,
                              interpret=interpret)
    return out[:k, :v]


def merge_vb_stats(lams, weights, eta: float, *, interpret: bool = None):
    """Alg. 1: λ* = η + Σ w_i (λ_i − η).  lams: (n, K, V)."""
    return merge_topics(lams, weights, bias=eta, base=eta,
                        interpret=interpret)


def merge_gs_stats(deltas, staleness, decay: float, *,
                   interpret: bool = None):
    """Alg. 2: N* = Σ decay^{s_i} ΔN_i.  deltas: (n, K, V)."""
    w = decay ** staleness.astype(jnp.float32)
    return merge_topics(deltas, w, bias=0.0, base=0.0, interpret=interpret)
