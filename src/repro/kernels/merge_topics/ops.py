"""jit'd public wrapper for the merge_topics kernel.

``merge_vb_stats`` / ``merge_gs_stats`` map the paper's Alg. 1/2 onto
the fused kernel; core/merge.py stays the host/NumPy reference.
``merge_topics_batch`` is the one-launch-per-batch entry for batches
whose plans all have the same part count; ``merge_topics_ragged`` is
the true ragged-batch entry the device execution backend uses — plans
with *different* part counts flatten into one CSR-style (R, K, V) row
stack merged by the segmented kernel in a single launch with zero pad
rows.  ``merge_topics_bucketed`` is the retired power-of-two-bucket
launcher; it stays only as a parity/efficiency reference for the
ragged path (tests compare the two).
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan_ir import size_buckets
from repro.kernels.common import default_interpret
from repro.kernels.merge_topics.merge_topics import (
    merge_topics_batched_pallas,
    merge_topics_pallas,
    merge_topics_ragged_pallas,
)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("bias", "base", "interpret"))
def merge_topics(stats, weights, bias: float = 0.0, base: float = 0.0,
                 *, interpret: bool = None):
    interpret = default_interpret(interpret)
    n, k, v = stats.shape
    kp, vp = _round_up(k, 8), _round_up(v, 128)
    # named scopes land in HLO metadata and jax.profiler traces, so a
    # device timeline attributes launch time to the MLego op by name
    with jax.named_scope("mlego.merge_topics"):
        if (kp, vp) != (k, v):
            stats = jnp.pad(stats, ((0, 0), (0, kp - k), (0, vp - v)),
                            constant_values=base)
        out = merge_topics_pallas(stats, weights, bias, base,
                                  interpret=interpret)
        return out[:k, :v]


@functools.partial(jax.jit, static_argnames=("bias", "base", "interpret"))
def merge_topics_batch(stats, weights, bias: float = 0.0, base: float = 0.0,
                       *, interpret: bool = None):
    """Batched merge: stats (b, n, K, V), weights (b, n) -> (b, K, V).

    Ragged batches pad n with zero-weight rows before calling; here we
    only pad K/V to tile alignment (pads carry ``base`` so they cancel).
    """
    interpret = default_interpret(interpret)
    b, n, k, v = stats.shape
    kp, vp = _round_up(k, 8), _round_up(v, 128)
    with jax.named_scope("mlego.merge_topics_batch"):
        if (kp, vp) != (k, v):
            stats = jnp.pad(stats,
                            ((0, 0), (0, 0), (0, kp - k), (0, vp - v)),
                            constant_values=base)
        out = merge_topics_batched_pallas(stats, weights, bias, base,
                                          interpret=interpret)
        return out[:, :k, :v]


@functools.partial(jax.jit, static_argnames=("num_segments", "bias", "base",
                                             "interpret"))
def _merge_topics_ragged_impl(stats, weights, seg_ids, num_segments: int,
                              bias: float = 0.0, base: float = 0.0,
                              *, interpret: bool = False):
    n_rows, k, v = stats.shape
    kp, vp = _round_up(k, 8), _round_up(v, 128)
    with jax.named_scope("mlego.merge_topics_ragged"):
        if (kp, vp) != (k, v):
            stats = jnp.pad(stats, ((0, 0), (0, kp - k), (0, vp - v)),
                            constant_values=base)
        out = merge_topics_ragged_pallas(stats, weights, seg_ids,
                                         num_segments, bias, base,
                                         interpret=interpret)
        return out[:, :k, :v]


def segment_ids(counts: Sequence[int]) -> jnp.ndarray:
    """CSR row->segment map for a ragged batch: (sum(counts),) int32."""
    return jnp.asarray(
        np.repeat(np.arange(len(counts)), list(counts)), jnp.int32)


def merge_topics_ragged(stats_list: Sequence, weights_list: Sequence,
                        bias: float = 0.0, base: float = 0.0,
                        *, interpret: bool = None
                        ) -> Tuple[List, int, int]:
    """Ragged batch of merges: one segmented launch, zero pad rows.

    ``stats_list[i]`` is query i's ``(n_i, K, V)`` stack,
    ``weights_list[i]`` its ``(n_i,)`` weights.  All stacks concatenate
    into one ``(R, K, V)`` row stack merged by the segmented kernel —
    no row padding on *any* batch shape (only K/V tile alignment, which
    pads with ``base`` and cancels).  Distinct ``(b, R)`` shapes
    compile separately; the former bucketing scheme existed to bound
    that recompilation, and the segmented kernel retires it by making
    every shape a zero-waste launch.

    Returns ``(merged, pad_rows, launches)`` matching the bucketed
    signature; ``pad_rows`` is always 0 and ``launches`` always 1.
    """
    interpret = default_interpret(interpret)
    counts = [int(s.shape[0]) for s in stats_list]
    if len(counts) == 1:
        out = merge_topics(stats_list[0], weights_list[0],
                           bias=bias, base=base, interpret=interpret)
        return [out], 0, 1
    stats = jnp.concatenate([jnp.asarray(s) for s in stats_list], axis=0)
    weights = jnp.concatenate(
        [jnp.asarray(w, jnp.float32) for w in weights_list])
    merged = _merge_topics_ragged_impl(stats, weights, segment_ids(counts),
                                       len(counts), bias, base,
                                       interpret=interpret)
    return [merged[i] for i in range(len(counts))], 0, 1


def merge_topics_bucketed(stats_list: Sequence, weights_list: Sequence,
                          bias: float = 0.0, base: float = 0.0,
                          *, interpret: bool = None
                          ) -> Tuple[List, int, int]:
    """Ragged batch of merges: bucketed launches instead of one padded one.

    Retired from the execution hot path in favor of
    :func:`merge_topics_ragged` (zero pad rows, one launch); kept as
    the parity/efficiency reference the ragged tests compare against.

    ``stats_list[i]`` is query i's ``(n_i, K, V)`` stack, ``weights_list[i]``
    its ``(n_i,)`` weights.  Plans are grouped into power-of-two size
    buckets (compiled batch shapes recur across calls); within a bucket
    rows pad with zero weight only to the bucket's actual widest plan,
    so total padding is pointwise ≤ the old pad-to-global-widest scheme.
    Buckets of one plan use the unbatched kernel (zero padding).

    Returns ``(merged, pad_rows, launches)`` with ``merged[i]`` the
    ``(K, V)`` result for input i, in input order.
    """
    counts = [int(s.shape[0]) for s in stats_list]
    out: List = [None] * len(counts)
    pad_rows = launches = 0
    for _, idxs in sorted(size_buckets(counts).items()):
        if len(idxs) == 1:
            i = idxs[0]
            out[i] = merge_topics(stats_list[i], weights_list[i],
                                  bias=bias, base=base, interpret=interpret)
            launches += 1
            continue
        widest = max(counts[i] for i in idxs)
        rows, weights = [], []
        for i in idxs:
            pad = widest - counts[i]
            stack = stats_list[i]
            if pad:
                # zero-weight rows: 0·(0 − base) contributes nothing
                stack = jnp.pad(stack, ((0, pad), (0, 0), (0, 0)))
                pad_rows += pad
            rows.append(stack)
            weights.append(jnp.pad(weights_list[i], (0, pad)))
        merged = merge_topics_batch(jnp.stack(rows), jnp.stack(weights),
                                    bias=bias, base=base,
                                    interpret=interpret)
        launches += 1
        for row, i in enumerate(idxs):
            out[i] = merged[row]
    return out, pad_rows, launches


def merge_vb_stats(lams, weights, eta: float, *, interpret: bool = None):
    """Alg. 1: λ* = η + Σ w_i (λ_i − η).  lams: (n, K, V)."""
    return merge_topics(lams, weights, bias=eta, base=eta,
                        interpret=interpret)


def merge_gs_stats(deltas, staleness, decay: float, *,
                   interpret: bool = None):
    """Alg. 2: N* = Σ decay^{s_i} ΔN_i.  deltas: (n, K, V)."""
    w = decay ** staleness.astype(jnp.float32)
    return merge_topics(deltas, w, bias=0.0, base=0.0, interpret=interpret)
