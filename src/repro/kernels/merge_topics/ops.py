"""jit'd public wrapper for the merge_topics kernel.

``merge_vb_stats`` / ``merge_gs_stats`` map the paper's Alg. 1/2 onto
the fused kernel; core/merge.py stays the host/NumPy reference.
``merge_topics_batch`` is the one-launch-per-batch entry the device
execution backend uses to merge several queries' plans at once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.merge_topics.merge_topics import (
    merge_topics_batched_pallas,
    merge_topics_pallas,
)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("bias", "base", "interpret"))
def merge_topics(stats, weights, bias: float = 0.0, base: float = 0.0,
                 *, interpret: bool = None):
    interpret = default_interpret(interpret)
    n, k, v = stats.shape
    kp, vp = _round_up(k, 8), _round_up(v, 128)
    if (kp, vp) != (k, v):
        stats = jnp.pad(stats, ((0, 0), (0, kp - k), (0, vp - v)),
                        constant_values=base)
    out = merge_topics_pallas(stats, weights, bias, base,
                              interpret=interpret)
    return out[:k, :v]


@functools.partial(jax.jit, static_argnames=("bias", "base", "interpret"))
def merge_topics_batch(stats, weights, bias: float = 0.0, base: float = 0.0,
                       *, interpret: bool = None):
    """Batched merge: stats (b, n, K, V), weights (b, n) -> (b, K, V).

    Ragged batches pad n with zero-weight rows before calling; here we
    only pad K/V to tile alignment (pads carry ``base`` so they cancel).
    """
    interpret = default_interpret(interpret)
    b, n, k, v = stats.shape
    kp, vp = _round_up(k, 8), _round_up(v, 128)
    if (kp, vp) != (k, v):
        stats = jnp.pad(stats, ((0, 0), (0, 0), (0, kp - k), (0, vp - v)),
                        constant_values=base)
    out = merge_topics_batched_pallas(stats, weights, bias, base,
                                      interpret=interpret)
    return out[:, :k, :v]


def merge_vb_stats(lams, weights, eta: float, *, interpret: bool = None):
    """Alg. 1: λ* = η + Σ w_i (λ_i − η).  lams: (n, K, V)."""
    return merge_topics(lams, weights, bias=eta, base=eta,
                        interpret=interpret)


def merge_gs_stats(deltas, staleness, decay: float, *,
                   interpret: bool = None):
    """Alg. 2: N* = Σ decay^{s_i} ΔN_i.  deltas: (n, K, V)."""
    w = decay ** staleness.astype(jnp.float32)
    return merge_topics(deltas, w, bias=0.0, base=0.0, interpret=interpret)
