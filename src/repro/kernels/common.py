"""Shared kernel-dispatch helpers.

Every kernel wrapper resolves its ``interpret`` default the same way:
on TPU the Pallas body compiles to Mosaic; everywhere else it runs in
interpret mode (the correctness path CI exercises).  Setting
``MLEGO_KERNEL_INTERPRET=1`` forces interpret mode even on TPU — the
switch the kernel CI leg flips so the suite provably executes the
kernel bodies rather than silently skipping them.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

INTERPRET_ENV = "MLEGO_KERNEL_INTERPRET"


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_forced() -> bool:
    return os.environ.get(INTERPRET_ENV, "") not in ("", "0")


def default_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve a wrapper's ``interpret=None`` default.

    Explicit True/False wins; otherwise interpret unless on TPU, and
    always interpret when ``MLEGO_KERNEL_INTERPRET`` is set.
    """
    if interpret is not None:
        return interpret
    return interpret_forced() or not on_tpu()
