"""Pure-jnp oracle for the sLSTM scan kernel (time-major form of
models/recurrent._slstm_local_scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _logsig(x):
    return -jax.nn.softplus(-x)


def slstm_scan_ref(xpre, r_mat, c0, n0, h0, m0):
    """xpre: (S, B, 4, H, hd) f32; r_mat: (H, hd, 4*hd);
    state: (B, H, hd) each.  Returns (h_out (S, B, H, hd), final state)."""
    s, b, _, h, hd = xpre.shape

    def step(carry, x_t):
        c, nrm, hprev, m = carry
        rec = jnp.einsum("bhd,hde->bhe", hprev, r_mat).reshape(b, h, 4, hd)
        tot = x_t + rec.transpose(0, 2, 1, 3)      # (B, 4, H, hd)
        z = jnp.tanh(tot[:, 0])
        logi = tot[:, 1]
        logf = _logsig(tot[:, 2])
        o = jax.nn.sigmoid(tot[:, 3])
        m_new = jnp.maximum(logf + m, logi)
        i_s = jnp.exp(logi - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c = f_s * c + i_s * z
        nrm = f_s * nrm + i_s
        hnew = o * c / jnp.maximum(nrm, 1e-6)
        return (c, nrm, hnew, m_new), hnew

    carry, hs = jax.lax.scan(step, (c0, n0, h0, m0), xpre)
    return hs, carry
