"""jit'd public wrapper for the sLSTM scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.slstm_scan.slstm_scan import slstm_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def slstm_scan(xpre, r_mat, *, chunk: int = 128, interpret: bool = None):
    """xpre: (S, B, 4, H, hd); r_mat: (H, hd, 4hd) -> h_out (S, B, H, hd).

    Final state intentionally not returned by the kernel (the decode
    handoff re-derives it from the last chunk in the jnp path); the
    fused form exists for the prefill/train hot loop.
    """
    interpret = default_interpret(interpret)
    return slstm_scan_pallas(xpre.astype(jnp.float32),
                             r_mat.astype(jnp.float32),
                             chunk=chunk, interpret=interpret)
