"""Pallas TPU kernel: sLSTM token scan with VMEM-resident recurrence.

Why: the sLSTM recurrence h_{t-1} @ R is h-dependent, so it cannot be
parallelized over time — and in the jnp lowering the (H, hd, 4hd)
recurrent matrix R is re-read from HBM at EVERY token: 16.8 MB x 32k
tokens = 3.3 TB of HBM traffic per layer on the xlstm prefill cell, the
single largest memory-roofline term in the whole assigned matrix
(EXPERIMENTS.md §Perf, xlstm iteration).

The kernel pins one head's R block (hd x 4hd = 4.2 MB at hd=512) in
VMEM and sweeps the token chunks sequentially, carrying the per-head
(c, n, h, m) state in scratch across grid steps.  sLSTM heads are
independent (block-diagonal R — the defining sLSTM trait), so the grid
is (H, S/CHUNK) with the chunk axis innermost; HBM traffic drops to
one read of xpre + one write of h_out + H reads of R.

Grid/blocks:
  xpre  (S, B, 4, H, hd) -> block (CHUNK, B, 4, 1, hd)   [h, ic]
  r_mat (H, hd, 4hd)     -> block (1, hd, 4hd)           [h]  (resident)
  h_out (S, B, H, hd)    -> block (CHUNK, B, 1, hd)
  state scratch: 4 x (B, hd) f32, reset at ic == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _logsig(x):
    return -jax.nn.softplus(-x)


def _kernel(xpre_ref, r_ref, out_ref, c_ref, n_ref, h_ref, m_ref, *,
            chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        h_ref[...] = jnp.zeros_like(h_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)

    r = r_ref[0]                       # (hd, 4hd) — VMEM resident
    xp = xpre_ref[...]                 # (CHUNK, B, 4, 1, hd)
    hd = r.shape[0]

    def step(t, state):
        c, nrm, hprev, m, out = state
        rec = jnp.dot(hprev, r, preferred_element_type=jnp.float32)
        rec = rec.reshape(hprev.shape[0], 4, hd)          # (B, 4, hd)
        tot = xp[t, :, :, 0, :] + rec
        z = jnp.tanh(tot[:, 0])
        logi = tot[:, 1]
        logf = _logsig(tot[:, 2])
        o = jax.nn.sigmoid(tot[:, 3])
        m_new = jnp.maximum(logf + m, logi)
        i_s = jnp.exp(logi - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c = f_s * c + i_s * z
        nrm = f_s * nrm + i_s
        hnew = o * c / jnp.maximum(nrm, 1e-6)
        out = jax.lax.dynamic_update_index_in_dim(
            out, hnew[:, None, :], t, 0)
        return c, nrm, hnew, m_new, out

    out0 = jnp.zeros(out_ref.shape, jnp.float32)
    c, nrm, h, m, out = jax.lax.fori_loop(
        0, chunk, step,
        (c_ref[...], n_ref[...], h_ref[...], m_ref[...], out0))
    c_ref[...] = c
    n_ref[...] = nrm
    h_ref[...] = h
    m_ref[...] = m
    out_ref[...] = out.astype(out_ref.dtype)


def slstm_scan_pallas(xpre, r_mat, *, chunk: int = 128,
                      interpret: bool = False):
    """xpre: (S, B, 4, H, hd) f32; r_mat: (H, hd, 4hd) f32.

    Returns (h_out (S, B, H, hd), (c, n, h, m) final each (B, H, hd)).
    """
    s, b, four, h, hd = xpre.shape
    assert four == 4
    ch = min(chunk, s)
    n_chunks = pl.cdiv(s, ch)

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=ch),
        grid=(h, n_chunks),
        in_specs=[
            pl.BlockSpec((ch, b, 4, 1, hd), lambda ih, ic: (ic, 0, 0, ih, 0)),
            pl.BlockSpec((1, hd, 4 * hd), lambda ih, ic: (ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((ch, b, 1, hd), lambda ih, ic: (ic, 0, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((s, b, h, hd), xpre.dtype),
        scratch_shapes=[
            pltpu.VMEM((b, hd), jnp.float32),
            pltpu.VMEM((b, hd), jnp.float32),
            pltpu.VMEM((b, hd), jnp.float32),
            pltpu.VMEM((b, hd), jnp.float32),
        ],
        interpret=interpret,
    )(xpre, r_mat)
    return out
