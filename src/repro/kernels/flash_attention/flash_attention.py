"""Pallas TPU kernel: GQA causal/windowed flash attention (prefill).

Layout (chosen for the MXU, not ported from a CUDA tiling):
  q   (B, KVH, G, S, hd)  — grouped-query heads folded next to their KV
  k,v (B, KVH, S, hd)

Grid: (B, KVH, S/BQ, S/BK), the KV axis innermost — TPU grids are
sequential, so the online-softmax state for one (b, kvh, iq) lives in
VMEM scratch across the BK sweep:

    acc (G·BQ, hd) f32, m/l (G·BQ, 128) f32 (lane-padded)

Each step: one (G·BQ, hd)x(hd, BK) MXU matmul for scores, one
(G·BQ, BK)x(BK, hd) for the PV product, VPU max/exp for the softmax
update.  Fully-masked causal blocks are skipped with ``pl.when``
(upper-triangle blocks cost zero MXU work); windowed attention also
skips blocks entirely below the band.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq: int, bk: int, g: int, causal: bool, window: int,
            n_k: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q0 = iq * bq
    k0 = ik * bk
    # block-level skip: causal above the diagonal, window below the band
    relevant = True
    if causal:
        relevant = q0 + bq - 1 >= k0
    if window > 0:
        relevant = relevant & (k0 + bk - 1 > q0 - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)               # (BK, hd)
        qf = q.reshape(g * bq, -1)
        s = jnp.dot(qf, k.T, preferred_element_type=jnp.float32)  # (G·BQ, BK)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (g, bq, bk), 1)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (g, bq, bk), 2)
        mask = jnp.ones((g, bq, bk), bool)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
        mask = mask.reshape(g * bq, bk)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, :1]                             # (G·BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        coef = jnp.exp(m_prev - m_new)
        l_ref[:, :1] = l_ref[:, :1] * coef + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * coef + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[:, :1] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).reshape(
            g, bq, -1).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, S, KVH, hd) -> (B, S, H, hd)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    bq = min(block_q, s)
    bk = min(block_k, s)
    n_q, n_k = pl.cdiv(s, bq), pl.cdiv(s, bk)

    qg = q.reshape(b, s, kvh, g, hd).transpose(0, 2, 3, 1, 4)  # (B,KVH,G,S,hd)
    kg = k.transpose(0, 2, 1, 3)                               # (B,KVH,S,hd)
    vg = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, bq=bq, bk=bk, g=g, causal=causal,
                               window=window, n_k=n_k, scale=hd ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, g, bq, hd),
                         lambda b, h, iq, ik: (b, h, 0, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, bq, hd),
                               lambda b, h, iq, ik: (b, h, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * bq, hd), jnp.float32),
            pltpu.VMEM((g * bq, 128), jnp.float32),
            pltpu.VMEM((g * bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, hd)
