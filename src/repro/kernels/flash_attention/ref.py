"""Pure-jnp oracle for GQA causal/windowed flash attention."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, S, H, hd); k, v: (B, S, KVH, hd).  Returns (B, S, H, hd)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd).astype(jnp.float32) * (hd ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bqkgs", qg, kf)
    pos = jnp.arange(s)
    d = pos[:, None] - pos[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= d >= 0
    if window > 0:
        mask &= d < window
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p, vf)
    return out.reshape(b, s, h, hd).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos, *, window: int = 0):
    """q: (B, 1, H, hd); caches: (B, S, KVH, hd); pos scalar int."""
    b, _, h, hd = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd).astype(jnp.float32) * (hd ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    kpos = jnp.arange(k_cache.shape[1])
    valid = kpos <= pos
    if window > 0:
        valid &= kpos > pos - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)
