"""jit'd public wrapper for the flash attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    """q: (B, S, H, hd); k, v: (B, S, KVH, hd) -> (B, S, H, hd)."""
    interpret = default_interpret(interpret)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
