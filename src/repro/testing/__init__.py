"""Deterministic failure tooling for tests, CI chaos legs, and benches."""
from repro.testing.faults import (FaultInjector, FaultRule, active_injector,
                                  from_env, injected, install, maybe_fail,
                                  uninstall)

__all__ = ["FaultInjector", "FaultRule", "active_injector", "from_env",
           "injected", "install", "maybe_fail", "uninstall"]
