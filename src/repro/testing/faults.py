"""Deterministic, seeded fault injection for the execution stack.

Production code calls ``maybe_fail("site.name")`` at named injection
sites (store get/save/load, backend merge/fetch/train, the sharded
merge collective, the serve worker loop).  With no injector installed
the call is a single global read and a ``None`` check — cheap enough
to leave in the hot path permanently.  With an injector installed,
each site draws from its *own* seeded RNG stream, so a given
``(seed, site, call-index)`` triple always produces the same verdict:
chaos runs are exactly reproducible in CI without real hardware
faults, and independent sites do not perturb each other's streams.

Rules name a site (exact, or a prefix — ``backend.merge`` matches
``backend.merge.device`` and ``backend.merge.device_sharded``), a
failure rate, and the error *kind* to raise (``transient``,
``permanent``, ``device_lost``, ``corrupt``, ``io``).  ``after`` skips
the first N calls; ``max_failures`` caps how many times the rule
fires (so a test can inject exactly one crash).

Activation:

- programmatic: ``with injected(FaultRule(...), seed=7): ...`` or
  ``install(FaultInjector(...))`` / ``uninstall()``;
- environment: ``MLEGO_FAULTS="seed=7,backend.merge:0.1:transient,
  store.load:1:corrupt:max=1"`` is parsed once at import and
  installed — the hook CI's chaos leg and the chaos bench use.
"""
from __future__ import annotations

import os
import random
import threading
import zlib
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Union

from repro.core.errors import (CorruptModelError, DeviceLostError,
                               PermanentExecutionError,
                               TransientExecutionError)

_KINDS = ("transient", "permanent", "device_lost", "corrupt", "io")


@dataclass(frozen=True)
class FaultRule:
    """One injection rule.

    ``site`` matches exactly or as a dotted prefix.  ``rate`` is the
    per-call failure probability (1.0 = always).  ``kind`` picks the
    exception type.  ``after`` exempts the first N matching calls;
    ``max_failures`` (None = unlimited) caps total firings.
    """

    site: str
    rate: float = 1.0
    kind: str = "transient"
    after: int = 0
    max_failures: Optional[int] = None

    def __post_init__(self) -> None:
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {self.kind!r}")

    def matches(self, site: str) -> bool:
        return site == self.site or site.startswith(self.site + ".")


def _raise_for(kind: str, site: str) -> None:
    msg = f"injected fault at {site!r}"
    if kind == "transient":
        raise TransientExecutionError(msg)
    if kind == "permanent":
        raise PermanentExecutionError(msg)
    if kind == "device_lost":
        # site is e.g. "backend.merge.device_sharded" — last component
        # names the backend that "lost" its device.
        raise DeviceLostError(msg, backend=site.rsplit(".", 1)[-1])
    if kind == "corrupt":
        raise CorruptModelError(msg)
    if kind == "io":
        raise IOError(msg)
    raise ValueError(f"unknown fault kind {kind!r}")


class FaultInjector:
    """Seeded rule set with per-site RNG streams and counters.

    Per-site streams are seeded ``crc32(site) ^ seed`` so adding a new
    site (or reordering calls across sites) never shifts another
    site's verdict sequence.  ``calls``/``failures`` counters are per
    *site string* and thread-safe; tests read them to assert exactly
    how much chaos a run absorbed.
    """

    def __init__(self, rules: Sequence[FaultRule] = (), *, seed: int = 0):
        self.rules: List[FaultRule] = list(rules)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._rngs: Dict[str, random.Random] = {}
        self.calls: Dict[str, int] = {}
        self.failures: Dict[str, int] = {}
        self._fired: Dict[int, int] = {}  # rule index -> firings

    def _rng(self, site: str) -> random.Random:
        rng = self._rngs.get(site)
        if rng is None:
            rng = random.Random((zlib.crc32(site.encode("utf-8"))
                                 & 0xFFFFFFFF) ^ self.seed)
            self._rngs[site] = rng
        return rng

    def check(self, site: str) -> None:
        """Record a call at ``site``; raise if a rule fires."""
        with self._lock:
            n_prior = self.calls.get(site, 0)
            self.calls[site] = n_prior + 1
            for idx, rule in enumerate(self.rules):
                if not rule.matches(site):
                    continue
                if n_prior < rule.after:
                    continue
                fired = self._fired.get(idx, 0)
                if rule.max_failures is not None \
                        and fired >= rule.max_failures:
                    continue
                # Draw even for rate=1.0 so stream positions stay
                # aligned when a test flips a rule's rate.
                if self._rng(site).random() >= rule.rate:
                    continue
                self._fired[idx] = fired + 1
                self.failures[site] = self.failures.get(site, 0) + 1
                kind = rule.kind
                break
            else:
                return
        _raise_for(kind, site)

    @property
    def total_failures(self) -> int:
        with self._lock:
            return sum(self.failures.values())

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {"calls": dict(self.calls),
                    "failures": dict(self.failures)}


# -- global hook ---------------------------------------------------------

_active: Optional[FaultInjector] = None


def maybe_fail(site: str) -> None:
    """The production-side hook: no-op unless an injector is installed."""
    inj = _active
    if inj is not None:
        inj.check(site)


def active_injector() -> Optional[FaultInjector]:
    return _active


def install(injector: FaultInjector) -> None:
    global _active
    _active = injector


def uninstall() -> None:
    global _active
    _active = None


@contextmanager
def injected(*rules: Union[FaultRule, "FaultInjector"],
             seed: int = 0) -> Iterator[FaultInjector]:
    """Scoped installation: ``with injected(FaultRule(...), seed=7) as inj:``.

    Accepts either rules (an injector is built around them) or a
    single pre-built ``FaultInjector``.  Restores the previous
    injector on exit, so scopes nest.
    """
    if len(rules) == 1 and isinstance(rules[0], FaultInjector):
        inj = rules[0]
    else:
        inj = FaultInjector([r for r in rules
                             if isinstance(r, FaultRule)], seed=seed)
    global _active
    prev = _active
    _active = inj
    try:
        yield inj
    finally:
        _active = prev


# -- environment hook ----------------------------------------------------

def from_env(value: str) -> FaultInjector:
    """Parse ``MLEGO_FAULTS`` syntax into an injector.

    ``"seed=7,backend.merge:0.1:transient,store.load:1:corrupt:max=1"``
    — comma-separated entries; ``seed=N`` anywhere sets the seed; each
    rule is ``site:rate[:kind][:after=N][:max=N]``.
    """
    seed = 0
    rules: List[FaultRule] = []
    for entry in value.split(","):
        entry = entry.strip()
        if not entry:
            continue
        if entry.startswith("seed="):
            seed = int(entry[5:])
            continue
        parts = entry.split(":")
        if len(parts) < 2:
            raise ValueError(f"bad MLEGO_FAULTS entry {entry!r} "
                             "(want site:rate[:kind][:after=N][:max=N])")
        site, rate = parts[0], float(parts[1])
        kind, after, max_failures = "transient", 0, None
        for extra in parts[2:]:
            if extra.startswith("after="):
                after = int(extra[6:])
            elif extra.startswith("max="):
                max_failures = int(extra[4:])
            else:
                kind = extra
        rules.append(FaultRule(site=site, rate=rate, kind=kind,
                               after=after, max_failures=max_failures))
    return FaultInjector(rules, seed=seed)


_env = os.environ.get("MLEGO_FAULTS", "")
if _env:
    install(from_env(_env))


__all__ = ["FaultInjector", "FaultRule", "active_injector", "from_env",
           "injected", "install", "maybe_fail", "uninstall"]
