"""Capital compaction & eviction — keeping streaming capital bounded.

Streaming ingestion (``repro.ingest.pipeline``) appends one base model
per time slice forever; left alone the store grows without bound and
every wide query pays a merge part per fine slice.  The compactor
enforces a byte budget over the managed kind's capital in two moves:

  **compact**  merge a contiguous run of the *oldest* slices into one
               coarse segment via the kind's merge family (Alg. 1 for
               the vb family, Alg. 2 for gs).  Both merges are exact
               natural-parameter additions, so a query that later
               merges the coarse segment with its neighbors computes
               the *same* β it would have from the fine slices — the
               only cost of compaction is range resolution (a query
               can no longer align to a boundary inside the segment).
               The swap goes through ``ModelStore.replace`` (atomic;
               "add" before "remove"s on the subscribe channel).

  **evict**    when compaction alone cannot reach the budget, drop the
               coldest managed models (least-recently fetched per the
               store's access clock, ties broken oldest-range /
               lowest-id first — fully deterministic for a fixed slice
               set and access history).

Only kinds with a built-in merge family compact (custom merge
callables have no materializable merged Θ); eviction applies to any
managed model.  The newest ``min_retained`` slices are exempt from
both moves — they are the hot frontier queries align to.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.api.trainers import merge_family_name, resolve_kind
from repro.configs.lda_default import LDAConfig
from repro.core.lda import MaterializedModel
from repro.core.merge import merge_gs, merge_vb
from repro.core.plans import Interval
from repro.core.store import ModelStore

# contiguity tolerance: slice bounds come from one grid expression
# (i * width), so adjacent bounds are bit-identical; the epsilon only
# forgives float noise in hand-built stores
_EPS = 1e-9


@dataclass(frozen=True)
class CompactionPolicy:
    """When and how hard to compact.

    max_bytes    : byte budget over the managed kind's capital
    merge_width  : fine models fused per compaction step
    min_retained : newest models (by range start) never touched
    evict        : allow cold-capital eviction when merging contiguous
                   runs cannot reach the budget alone
    """

    max_bytes: int
    merge_width: int = 4
    min_retained: int = 1
    evict: bool = True

    def __post_init__(self):
        if self.merge_width < 2:
            raise ValueError("merge_width must be >= 2")


@dataclass(frozen=True)
class CompactionReport:
    """One ``Compactor.run``'s ledger."""

    bytes_before: int
    bytes_after: int
    compacted: Tuple[Tuple[int, ...], ...] = ()   # replaced id groups
    compacted_into: Tuple[int, ...] = ()          # one coarse id per group
    evicted: Tuple[int, ...] = ()

    @property
    def changed(self) -> bool:
        return bool(self.compacted or self.evicted)


@dataclass(frozen=True)
class CompactionTotals:
    """Cumulative counters across every ``run`` (for service reports)."""

    runs: int = 0
    compactions: int = 0
    evictions: int = 0
    bytes_reclaimed: int = 0


class Compactor:
    """Budget enforcement over one store's managed kind.

    ``run()`` is idempotent at the fixpoint (under budget, or nothing
    left to move) and safe to call from the ingest builder thread —
    every store mutation it makes flows through the subscribe channel,
    so concurrent sessions' plan caches and device LRUs invalidate as
    for any manual mutation.  Runs are serialized by an internal lock.
    """

    def __init__(self, store: ModelStore, cfg: LDAConfig,
                 policy: CompactionPolicy, kind: str = "vb"):
        self.store = store
        self.cfg = cfg
        self.policy = policy
        self.kind = resolve_kind(kind)
        self.family = merge_family_name(self.kind)
        if self.family is None:
            raise ValueError(
                f"kind {self.kind!r} has a custom merge callable — no "
                f"materializable merged Θ, so it cannot compact (eviction"
                f"-only policies must still name a mergeable kind)")
        self._lock = threading.Lock()
        self._totals = CompactionTotals()

    # ------------------------------------------------------------------
    def managed(self) -> List[MaterializedModel]:
        """The models under budget, oldest range first."""
        out = []
        for m in self.store.models():
            try:
                mk = resolve_kind(m.kind)
            except ValueError:
                continue
            if mk == self.kind:
                out.append(m)
        return sorted(out, key=lambda m: (m.o.lo, m.o.hi, m.model_id))

    def bytes_used(self) -> int:
        return sum(m.nbytes() for m in self.managed())

    @property
    def totals(self) -> CompactionTotals:
        return self._totals

    # ------------------------------------------------------------------
    def _merged_theta(self, group: Sequence[MaterializedModel]) -> dict:
        if self.family == "vb":
            return {"lam": merge_vb(list(group), self.cfg)}
        return {"delta_nkv": merge_gs(list(group), self.cfg)}

    def _oldest_run(self, models: List[MaterializedModel]
                    ) -> Optional[List[MaterializedModel]]:
        """Oldest contiguous run of ``merge_width`` movable models."""
        movable = models[: max(len(models) - self.policy.min_retained, 0)]
        width = self.policy.merge_width
        run: List[MaterializedModel] = []
        for m in movable:
            if run and abs(m.o.lo - run[-1].o.hi) > _EPS * max(
                    1.0, abs(run[-1].o.hi)):
                run = []
            run.append(m)
            if len(run) == width:
                return run
        return None

    def _coldest(self, models: List[MaterializedModel]
                 ) -> Optional[MaterializedModel]:
        movable = models[: max(len(models) - self.policy.min_retained, 0)]
        if not movable:
            return None
        return min(movable, key=lambda m: (self.store.last_access(
            m.model_id), m.o.lo, m.model_id))

    # ------------------------------------------------------------------
    def run(self) -> CompactionReport:
        """Compact/evict until the managed capital fits the budget (or
        nothing movable remains).  Returns this run's ledger."""
        with self._lock:
            bytes_before = self.bytes_used()
            used = bytes_before
            compacted: List[Tuple[int, ...]] = []
            into: List[int] = []
            evicted: List[int] = []
            while used > self.policy.max_bytes:
                models = self.managed()
                group = self._oldest_run(models)
                if group is not None:
                    coarse = self.store.replace(
                        [m.model_id for m in group],
                        Interval(group[0].o.lo, group[-1].o.hi),
                        sum(m.n_docs for m in group),
                        sum(m.n_tokens for m in group),
                        self.kind, self._merged_theta(group))
                    compacted.append(tuple(m.model_id for m in group))
                    into.append(coarse.model_id)
                elif self.policy.evict:
                    cold = self._coldest(models)
                    if cold is None:
                        break
                    self.store.remove(cold.model_id)
                    evicted.append(cold.model_id)
                else:
                    break
                used = self.bytes_used()
            t = self._totals
            self._totals = CompactionTotals(
                runs=t.runs + 1,
                compactions=t.compactions + len(compacted),
                evictions=t.evictions + len(evicted),
                bytes_reclaimed=t.bytes_reclaimed
                + max(bytes_before - used, 0))
            return CompactionReport(
                bytes_before=bytes_before, bytes_after=used,
                compacted=tuple(compacted), compacted_into=tuple(into),
                evicted=tuple(evicted))


__all__ = ["CompactionPolicy", "CompactionReport", "CompactionTotals",
           "Compactor"]
