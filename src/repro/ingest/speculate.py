"""Workload-driven speculative gap pre-training.

The serving layer's query log is a prophecy: interactive exploration
replays hot σ ranges (pan/zoom, re-render, dashboard refresh), and a
hot range whose plan still carries ``TrainGapStep``s pays the gap
training on *every* volatile query.  The ``SpeculativeTrainer`` mines
``MLegoService``'s per-tenant query log for ranges seen at least
``min_count`` times inside ``window_s``, re-plans them against the
current store, and pre-trains the uncovered gap segments **only when
the cost provider forecasts the training lands before the range's next
predicted arrival** (``CostProvider.speculation_pays`` — with a
calibrated provider this is a measured-κ forecast, so speculation
self-throttles to gaps it can actually finish in time).  Trained
segments persist to the shared store and warm-insert into the backend
device cache via ``ExecutionBackend.note_trained``; the next hot query
fetches them instead of training (a *speculative hit*, counted by the
service when an answered plan's model ids intersect the speculated
set).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import List, Optional, Set, Tuple

from repro.core.plans import Interval

SPECULATION_TENANT = "__speculator__"


@dataclass(frozen=True)
class QueryLogEntry:
    """One answered query, as the speculator sees it."""

    tenant: str
    sigma: Tuple[Tuple[float, float], ...]   # normalized component bounds
    kind: str
    alpha: float
    backend: Optional[str]
    t: float                                 # monotonic arrival stamp


@dataclass(frozen=True)
class SpeculationReport:
    """Cumulative speculation counters."""

    scans: int = 0
    hot_ranges: int = 0          # (σ, kind, α) groups past min_count
    gaps_considered: int = 0
    trained: int = 0             # gap segments pre-trained
    trained_tokens: int = 0
    skipped_payoff: int = 0      # gaps whose forecast missed the window
    hits: int = 0                # answered queries that fetched
    #                              speculated capital
    paused: bool = False         # SLO loop currently holds speculation
    pauses: int = 0              # times the SLO loop paused it

    @property
    def hit_rate(self) -> float:
        return self.hits / self.trained if self.trained else 0.0


class SpeculativeTrainer:
    """Mines a service's query log; pre-trains hot gaps that pay.

    service   : the owning ``MLegoService`` (query log, sessions,
                shared cost provider and backends)
    window_s  : how far back in the log a range must repeat to be hot
    min_count : arrivals inside the window that make a range hot
    margin    : safety factor on the training-time forecast (> 1 =
                conservative: only speculate with headroom)
    poll_s    : background scan period (``start=False`` skips the
                thread; call ``scan_once`` manually — tests do)
    """

    def __init__(self, service, *, window_s: float = 30.0,
                 min_count: int = 2, margin: float = 1.0,
                 poll_s: float = 0.05, start: bool = True):
        self.service = service
        self.window_s = float(window_s)
        self.min_count = int(min_count)
        self.margin = float(margin)
        self._poll_s = poll_s
        self._lock = threading.Lock()
        self.trained_ids: Set[int] = set()
        self._scans = self._hot = self._considered = 0
        self._trained = self._trained_tokens = self._skipped = 0
        self._hits = 0
        self._paused = False
        self._pauses = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if start:
            self._thread = threading.Thread(
                target=self._loop, name="mlego-speculator", daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------
    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.wait(self._poll_s):
            try:
                self.scan_once()
            except Exception:
                # a failed scan must never kill speculation (or leak
                # into query threads) — the next scan starts clean
                pass

    # ------------------------------------------------------------------
    def note_hit(self) -> None:
        with self._lock:
            self._hits += 1

    @property
    def paused(self) -> bool:
        return self._paused

    def set_paused(self, paused: bool) -> None:
        """SLO hook: under heavy degradation the service parks the
        speculator so overload capacity answers queries instead of
        pre-training for them; cleared automatically when the latency
        window recovers."""
        with self._lock:
            if paused and not self._paused:
                self._pauses += 1
            self._paused = bool(paused)

    def _hot_groups(self, now: float) -> List[Tuple[Tuple, List[float]]]:
        """(group key, arrival stamps) for ranges hot in the window."""
        groups = {}
        for e in self.service.query_log():
            if now - e.t > self.window_s:
                continue
            groups.setdefault((e.sigma, e.kind, e.alpha, e.backend),
                              []).append(e.t)
        return [(k, sorted(ts)) for k, ts in sorted(groups.items())
                if len(ts) >= self.min_count]

    def scan_once(self) -> int:
        """One mining pass; returns the number of segments trained."""
        if self._paused:
            return 0
        now = time.monotonic()
        hot = self._hot_groups(now)
        with self._lock:
            self._scans += 1
            self._hot += len(hot)
        trained_here = 0
        sess = self.service.session(SPECULATION_TENANT)
        cost = self.service.cost
        for (sigma, kind, alpha, backend_name), ts in hot:
            backend = self.service.backend if backend_name is None \
                else self.service._shared_backend(backend_name)
            # predicted time until the range's next arrival: mean
            # inter-arrival past the last stamp (a single stamp can't
            # be hot — min_count >= 2 guards the division)
            inter = (ts[-1] - ts[0]) / (len(ts) - 1)
            budget = max(ts[-1] + inter - now, 0.0)
            models = sess._models(kind)
            cost.set_train_backend(backend.name)
            for lo, hi in sigma:
                res = sess.planner.plan(models, Interval(lo, hi), alpha)
                for g in res.ir.gaps:
                    if g.n_tokens <= 0:
                        continue
                    with self._lock:
                        self._considered += 1
                    if not cost.speculation_pays(g.n_tokens, budget,
                                                 self.margin):
                        with self._lock:
                            self._skipped += 1
                        continue
                    m = sess.executor.train_gap(
                        g.gap.lo, g.gap.hi, kind, persist=True,
                        backend=backend)
                    if m is None:
                        continue
                    trained_here += 1
                    with self._lock:
                        self.trained_ids.add(m.model_id)
                        self._trained += 1
                        self._trained_tokens += m.n_tokens
        return trained_here

    # ------------------------------------------------------------------
    def report(self) -> SpeculationReport:
        with self._lock:
            return SpeculationReport(
                scans=self._scans, hot_ranges=self._hot,
                gaps_considered=self._considered,
                trained=self._trained,
                trained_tokens=self._trained_tokens,
                skipped_payoff=self._skipped, hits=self._hits,
                paused=self._paused, pauses=self._pauses)


__all__ = ["QueryLogEntry", "SpeculationReport", "SpeculativeTrainer",
           "SPECULATION_TENANT"]
