"""Streaming ingestion & speculative capital building.

Turns the static model store into a living one: ``IngestPipeline``
appends document batches and trains per-time-slice base models in the
background, ``Compactor`` keeps the resulting capital under a byte
budget (merge-family compaction + cold eviction), and
``SpeculativeTrainer`` pre-trains the gap segments the serving layer's
query log predicts will be asked again.  Every store mutation all
three make flows through ``ModelStore.subscribe`` — the same channel
manual saves use — so plan caches and device LRUs stay coherent
without any new invalidation machinery.
"""
from repro.ingest.compaction import (
    CompactionPolicy,
    CompactionReport,
    CompactionTotals,
    Compactor,
)
from repro.ingest.pipeline import IngestPipeline, IngestReport
from repro.ingest.speculate import (
    SPECULATION_TENANT,
    QueryLogEntry,
    SpeculationReport,
    SpeculativeTrainer,
)

__all__ = [
    "CompactionPolicy",
    "CompactionReport",
    "CompactionTotals",
    "Compactor",
    "IngestPipeline",
    "IngestReport",
    "QueryLogEntry",
    "SPECULATION_TENANT",
    "SpeculationReport",
    "SpeculativeTrainer",
]
