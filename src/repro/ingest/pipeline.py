"""Streaming ingestion — append-only document batches into reuse capital.

The paper's store is built offline; ``IngestPipeline`` keeps it fresh
against a moving corpus.  Batches append through ``append`` (the
producer thread), land in the growing corpus snapshot immediately
(``on_corpus`` lets the serving layer re-home tenant sessions before
any model materializes — queries over not-yet-built slices simply gap
train from the raw documents), and are bucketed into fixed-width time
slices on the attr axis.  A slice *closes* when the ingest frontier
passes its upper bound — append-only means no later batch can add to
it — and the background **builder thread** then trains its base model
via the trainer registry and materializes it into the shared
``ModelStore``.  That ``store.add`` rides the normal subscribe
channel, so plan caches and device LRUs invalidate exactly as they do
for manual saves, and the next query over the slice fetches capital
instead of retraining.

Ordering invariant: the corpus snapshot always grows *before* a slice
model lands.  The reverse window (model in the store, docs missing
from the session index) would let the planner cover a range with a
model whose tokens the index counts as zero — an empty-looking plan.

After each built slice the pipeline drives its ``Compactor`` (if
configured), so the capital stays under its byte budget as it grows.
"""
from __future__ import annotations

import math
import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import jax

from repro.api.backend import ExecutionBackend
from repro.api.trainers import get_trainer, resolve_kind
from repro.configs.lda_default import LDAConfig
from repro.core.plans import Interval
from repro.core.store import ModelStore
from repro.data.corpus import Corpus, concat_corpora
from repro.ingest.compaction import Compactor


@dataclass(frozen=True)
class IngestReport:
    """Point-in-time snapshot of the pipeline."""

    batches: int = 0
    docs: int = 0
    tokens: int = 0
    slices_built: int = 0            # slice models materialized
    slices_pending: int = 0          # closed, waiting on the builder
    slices_empty: int = 0            # closed with no documents
    build_errors: int = 0
    frontier: float = 0.0            # max attr ingested so far
    # freshness lag: slice close -> model materialized, seconds
    freshness_lag_s_last: float = 0.0
    freshness_lag_s_mean: float = 0.0
    freshness_lag_s_max: float = 0.0
    # compaction (zero unless a compactor is attached)
    compactions: int = 0
    evictions: int = 0
    store_bytes: int = 0


class IngestPipeline:
    """One growing corpus, one builder thread, one managed kind.

    corpus      : the base snapshot ingestion grows from; its attr
                  frontier is where streaming may begin
    store       : shared ``ModelStore`` slice models materialize into
    cfg         : trainer config (one F for the whole stream)
    slice_width : attr width of one time slice
    kind        : trainer kind for slice base models
    backend     : execution backend whose registry-resolved trainer
                  runs the slice fits and whose device cache is warmed
                  (``note_trained``) per built slice; None = host
                  registry trainer, no warm-insert
    start       : first slice boundary (defaults to the next
                  ``slice_width`` multiple at/above the base frontier);
                  batches below it are rejected — they would overlap
                  capital the base store may already hold
    on_corpus   : called with every grown snapshot *before* the batch's
                  slices can close (the serving layer re-homes tenant
                  sessions here)
    compactor   : optional ``Compactor`` driven after each built slice
    """

    def __init__(self, corpus: Corpus, store: ModelStore, cfg: LDAConfig, *,
                 slice_width: float, kind: str = "vb",
                 backend: Optional[ExecutionBackend] = None,
                 start: Optional[float] = None, seed: int = 0,
                 on_corpus: Optional[Callable[[Corpus], None]] = None,
                 compactor: Optional[Compactor] = None):
        if slice_width <= 0:
            raise ValueError("slice_width must be positive")
        self.store = store
        self.cfg = cfg
        self.slice_width = float(slice_width)
        self.kind = resolve_kind(kind)
        self.backend = backend
        self.on_corpus = on_corpus
        self.compactor = compactor

        self._lock = threading.Lock()
        self._corpus = corpus
        base_frontier = float(corpus.attr[-1]) if corpus.n_docs else 0.0
        self._start = float(start) if start is not None \
            else math.ceil(base_frontier / self.slice_width) \
            * self.slice_width
        if self._start < base_frontier:
            raise ValueError(
                f"start={self._start} lies inside the base corpus "
                f"(frontier {base_frontier}); slice models would overlap "
                f"existing capital")
        self._frontier = self._start
        self._next_slice = 0             # first un-closed slice index
        self._closed = False

        self._batches = self._docs = self._tokens = 0
        self._built = self._empty = self._errors = 0
        self._lags: List[float] = []
        self._compactions = self._evictions = 0

        self._key = jax.random.PRNGKey(seed)
        # (lo, hi, closed_at, corpus snapshot) per closed slice
        self._queue: "queue.Queue[Optional[Tuple]]" = queue.Queue()
        self._builder = threading.Thread(
            target=self._build_loop, name="mlego-ingest-builder",
            daemon=True)
        self._builder.start()

    # ------------------------------------------------------------------
    # producer side
    # ------------------------------------------------------------------
    def _slice_bounds(self, i: int) -> Tuple[float, float]:
        return (self._start + i * self.slice_width,
                self._start + (i + 1) * self.slice_width)

    @property
    def frontier(self) -> float:
        return self._frontier

    @property
    def corpus(self) -> Corpus:
        """The current grown snapshot."""
        return self._corpus

    def append(self, batch: Corpus) -> None:
        """Ingest one document batch (attr-sorted, at/after the
        frontier).  Grows the snapshot, fires ``on_corpus``, and
        enqueues every slice the new frontier closed."""
        if batch.n_docs == 0:
            return
        with self._lock:
            if self._closed:
                raise RuntimeError("ingest pipeline is closed")
            lo = float(batch.attr[0])
            if lo < self._frontier:
                raise ValueError(
                    f"append-only: batch starts at attr {lo}, below the "
                    f"ingest frontier {self._frontier}")
            grown = concat_corpora(self._corpus, batch)
            self._corpus = grown
            self._frontier = float(batch.attr[-1])
            self._batches += 1
            self._docs += batch.n_docs
            self._tokens += batch.n_tokens
            closed = self._drain_closed_slices()
        # callbacks fire outside the lock, corpus first (see the module
        # ordering invariant), then the builder gets the closed slices
        if self.on_corpus is not None:
            self.on_corpus(grown)
        now = time.perf_counter()
        for s_lo, s_hi in closed:
            self._queue.put((s_lo, s_hi, now, grown))

    def _drain_closed_slices(self) -> List[Tuple[float, float]]:
        """Slices whose upper bound the frontier passed (lock held)."""
        out = []
        while True:
            s_lo, s_hi = self._slice_bounds(self._next_slice)
            if s_hi > self._frontier:
                return out
            out.append((s_lo, s_hi))
            self._next_slice += 1

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until every enqueued slice is built (True) or the
        timeout expires (False)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self._queue.unfinished_tasks == 0:
                return True
            if deadline is not None and time.monotonic() >= deadline:
                return False
            time.sleep(0.005)

    def close(self, build_partial: bool = True) -> None:
        """Stop accepting batches, optionally build the open partial
        slice (append-only means it can never grow again), drain the
        builder, and join it."""
        with self._lock:
            if self._closed:
                if self._builder.is_alive():
                    self._builder.join()
                return
            self._closed = True
            partial = None
            if build_partial:
                s_lo, s_hi = self._slice_bounds(self._next_slice)
                if self._frontier > s_lo:
                    partial = (s_lo, s_hi, time.perf_counter(),
                               self._corpus)
                    self._next_slice += 1
            snapshot = self._corpus
        if partial is not None:
            self._queue.put(partial)
        del snapshot
        self._queue.put(None)            # builder shutdown sentinel
        self._builder.join()

    # ------------------------------------------------------------------
    # builder side
    # ------------------------------------------------------------------
    def _next_key(self):
        with self._lock:
            self._key, k = jax.random.split(self._key)
            return k

    def _build_loop(self) -> None:
        while True:
            item = self._queue.get()
            try:
                if item is None:
                    return
                self._build_slice(*item)
            except Exception:
                with self._lock:
                    self._errors += 1
            finally:
                self._queue.task_done()

    def _build_slice(self, lo: float, hi: float, closed_at: float,
                     snapshot: Corpus) -> None:
        sub = snapshot.subset(lo, hi)
        if sub.n_docs == 0:
            with self._lock:
                self._empty += 1
            return
        trainer = self.backend.trainer(self.kind) \
            if self.backend is not None else get_trainer(self.kind)
        theta = trainer(sub, self.cfg, self._next_key())
        m = self.store.add(Interval(lo, hi), sub.n_docs, sub.n_tokens,
                           self.kind, theta)
        if self.backend is not None:
            self.backend.note_trained(m)
        lag = time.perf_counter() - closed_at
        with self._lock:
            self._built += 1
            self._lags.append(lag)
        if self.compactor is not None:
            rep = self.compactor.run()
            with self._lock:
                self._compactions += len(rep.compacted)
                self._evictions += len(rep.evicted)

    # ------------------------------------------------------------------
    def report(self) -> IngestReport:
        with self._lock:
            lags = list(self._lags)
            return IngestReport(
                batches=self._batches, docs=self._docs,
                tokens=self._tokens,
                slices_built=self._built,
                slices_pending=self._queue.unfinished_tasks,
                slices_empty=self._empty,
                build_errors=self._errors,
                frontier=self._frontier,
                freshness_lag_s_last=lags[-1] if lags else 0.0,
                freshness_lag_s_mean=sum(lags) / len(lags)
                if lags else 0.0,
                freshness_lag_s_max=max(lags) if lags else 0.0,
                compactions=self._compactions,
                evictions=self._evictions,
                store_bytes=self.store.nbytes())


__all__ = ["IngestPipeline", "IngestReport"]
