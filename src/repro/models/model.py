"""Unified model definition for the 10 assigned architectures.

One ``Model`` class covers every family (dense / moe / ssm / hybrid /
vlm / audio).  The layer stack is expressed as a ``lax.scan`` over
*pattern groups* (the block_pattern repeated n_layers // period times,
plus an unrolled tail) so that the HLO — and therefore dry-run compile
time and code size — is independent of depth.  Per-layer parameters are
stacked along a leading axis ("stack" in the param path tells the
sharding rules to skip it).

Execution modes:
  * ``loss`` / ``train``  — teacher-forced LM loss over (tokens, labels)
  * ``prefill``           — forward pass that also builds decode caches
  * ``decode_step``       — one new token against the caches

Distribution: batch over ("pod","data"), sequence over "model" (SP) via
the ring/flash modules in models/attention.py, experts over "model"
(EP) in models/moe.py, recurrent states replicated (they are O(B·d)).
Parameters are 2-D FSDP sharded by distributed/sharding.py rules.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import (MeshEnv, constrain,
                                          gather_for_compute, get_env,
                                          set_env)
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.layers import (
    act_fn,
    dense_init,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    rope_frequencies,
    apply_rope,
    sinusoidal_positions,
)

Params = Dict[str, Any]
Cache = Dict[str, Any]


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def cast_params(params: Params, dt) -> Params:
    """Compute-dtype copies of the f32 master weights (>=2-D leaves).

    Casting BEFORE the layer scan matters for distribution, not just
    speed: the FSDP all-gathers/reduce-scatters then move bf16 instead
    of the f32 masters — XLA does not hoist the convert above the
    gather on its own (measured 2x on every dense train cell,
    EXPERIMENTS.md §Perf).  1-D leaves (norm scales, gates) stay f32.
    """
    if dt == jnp.float32:
        return params
    return jax.tree.map(
        lambda x: x.astype(dt)
        if x.ndim >= 2 and x.dtype == jnp.float32 else x, params)


# ===========================================================================
# per-kind layer parameter initialisers
# ===========================================================================

def _attn_params(cfg: ArchConfig, key, cross: bool = False) -> Params:
    d, qd, kvd, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.hd
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], d, qd),
        "wk": dense_init(ks[1], d, kvd),
        "wv": dense_init(ks[2], d, kvd),
        "wo": dense_init(ks[3], qd, d, scale=1.0 / math.sqrt(qd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), jnp.float32)
        p["bk"] = jnp.zeros((kvd,), jnp.float32)
        p["bv"] = jnp.zeros((kvd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _layer_params(cfg: ArchConfig, kind: str, key) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": norm_init(cfg, d)}
    if kind in ("attn", "local"):
        p["attn"] = _attn_params(cfg, ks[0])
        p["norm2"] = norm_init(cfg, d)
        if cfg.is_moe:
            p["moe"] = moe_mod.moe_init(cfg, ks[1])
            if cfg.n_shared_experts:
                p["shared_mlp"] = mlp_init(
                    cfg, ks[2], d, cfg.d_ff_expert * cfg.n_shared_experts)
        else:
            p["mlp"] = mlp_init(cfg, ks[1], d, cfg.d_ff)
    elif kind == "rec":
        # Griffin recurrent block: gate & recurrent input projections,
        # conv4, RG-LRU gates, output projection — then its own MLP.
        dr = d
        p["proj_gate"] = dense_init(ks[0], d, dr)
        p["proj_in"] = dense_init(ks[1], d, dr)
        p["conv_w"] = jax.random.normal(ks[2], (4, dr), jnp.float32) * 0.1
        p["conv_b"] = jnp.zeros((dr,), jnp.float32)
        p["w_rg"] = dense_init(ks[3], dr, dr)
        p["b_rg"] = jnp.zeros((dr,), jnp.float32)
        p["w_ig"] = dense_init(ks[4], dr, dr)
        p["b_ig"] = jnp.zeros((dr,), jnp.float32)
        p["lam"] = jnp.full((dr,), 0.7, jnp.float32)  # a ≈ 0.96^c init
        p["wo"] = dense_init(ks[5], dr, d)
        p["norm2"] = norm_init(cfg, d)
        p["mlp"] = mlp_init(cfg, jax.random.fold_in(key, 7), d, cfg.d_ff)
    elif kind == "m":
        # mLSTM block: qkv + output projections + per-head i/f gates.
        h = cfg.n_heads
        p["wq"] = dense_init(ks[0], d, d)
        p["wk"] = dense_init(ks[1], d, d)
        p["wv"] = dense_init(ks[2], d, d)
        p["wo"] = dense_init(ks[3], d, d)
        p["w_if"] = dense_init(ks[4], d, 2 * h)   # input & forget gates
        p["b_if"] = jnp.concatenate(
            [jnp.zeros((h,), jnp.float32), jnp.full((h,), 3.0, jnp.float32)])
    elif kind == "s":
        # sLSTM block: z/i/f/o pre-activations + block-diag recurrent R.
        h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
        p["w_zifo"] = dense_init(ks[0], d, 4 * d)
        p["b_zifo"] = jnp.zeros((4, h, hd), jnp.float32)
        p["r_mat"] = jax.random.normal(ks[1], (h, hd, 4 * hd)) * (hd ** -0.5)
        p["wo"] = dense_init(ks[2], d, d)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    return p


# ===========================================================================
# per-kind sequence-mode forward (train / prefill)
# ===========================================================================

def _qk_norm(cfg: ArchConfig, x, scale):
    """Per-head RMSNorm (qwen3)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + cfg.norm_eps) * (1.0 + scale)
    return out.astype(x.dtype)


def _attn_qkv(cfg: ArchConfig, p, h, positions):
    b, s, _ = h.shape
    dt = h.dtype
    q = h @ p["wq"].astype(dt)
    k = h @ p["wk"].astype(dt)
    v = h @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, cfg.n_heads, cfg.hd)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = _qk_norm(cfg, q, p["q_norm"])
        k = _qk_norm(cfg, k, p["k_norm"])
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(cfg: ArchConfig, p, x, env: MeshEnv):
    if cfg.is_moe:
        y, aux = moe_mod.moe_dispatch(cfg, p["moe"], x, env=env)
        if cfg.n_shared_experts:
            y = y + mlp_apply(cfg, p["shared_mlp"], x)
        return y, aux
    return mlp_apply(cfg, p["mlp"], x), jnp.zeros((), jnp.float32)


def _attn_layer_seq(cfg: ArchConfig, p, x, env: MeshEnv, *, kind: str,
                    positions, causal: bool = True):
    h = norm_apply(cfg, x, p["norm1"])
    q, k, v = _attn_qkv(cfg, p["attn"], h, positions)
    window = cfg.window if kind == "local" else 0
    o = attn.ring_attention(q, k, v, env=env, causal=causal, window=window)
    b, s, _, _ = o.shape
    x = x + o.reshape(b, s, cfg.q_dim) @ p["attn"]["wo"].astype(x.dtype)
    h2 = norm_apply(cfg, x, p["norm2"])
    y, aux = _ffn(cfg, p, h2, env)
    return x + y, aux


def _rec_layer_seq(cfg: ArchConfig, p, x, env: MeshEnv):
    dt = x.dtype
    h = norm_apply(cfg, x, p["norm1"])
    gate = jax.nn.gelu(h @ p["proj_gate"].astype(dt))
    xin = h @ p["proj_in"].astype(dt)
    hr = rec.rglru_seq(xin, p["w_rg"], p["b_rg"], p["w_ig"], p["b_ig"],
                       p["conv_w"], p["conv_b"], p["lam"], env=env)
    x = x + (gate * hr) @ p["wo"].astype(dt)
    h2 = norm_apply(cfg, x, p["norm2"])
    return x + mlp_apply(cfg, p["mlp"], h2)


def _mlstm_layer_seq(cfg: ArchConfig, p, x, env: MeshEnv):
    dt = x.dtype
    b, s, d = x.shape
    hn, hd = cfg.n_heads, d // cfg.n_heads
    h = norm_apply(cfg, x, p["norm1"])
    q = (h @ p["wq"].astype(dt)).reshape(b, s, hn, hd)
    k = (h @ p["wk"].astype(dt)).reshape(b, s, hn, hd)
    v = (h @ p["wv"].astype(dt)).reshape(b, s, hn, hd)
    gates = h @ p["w_if"].astype(dt) + p["b_if"].astype(dt)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)       # (B,S,H)
    o = rec.mlstm_seq(q, k, v, i_raw, f_raw, env=env)
    return x + o.reshape(b, s, d) @ p["wo"].astype(dt)


def _slstm_layer_seq(cfg: ArchConfig, p, x, env: MeshEnv):
    dt = x.dtype
    b, s, d = x.shape
    hn, hd = cfg.n_heads, d // cfg.n_heads
    h = norm_apply(cfg, x, p["norm1"])
    pre = (h @ p["w_zifo"].astype(dt)).reshape(b, s, 4, hn, hd)
    pre = pre + p["b_zifo"].astype(dt)
    o = rec.slstm_seq(pre, p["r_mat"], env=env)
    return x + o.reshape(b, s, d) @ p["wo"].astype(dt)


def _layer_seq(cfg: ArchConfig, kind: str, p, x, env: MeshEnv, positions,
               causal: bool = True):
    """Returns (x, aux_loss)."""
    if kind in ("attn", "local"):
        return _attn_layer_seq(cfg, p, x, env, kind=kind,
                               positions=positions, causal=causal)
    if kind == "rec":
        return _rec_layer_seq(cfg, p, x, env), jnp.zeros((), jnp.float32)
    if kind == "m":
        return _mlstm_layer_seq(cfg, p, x, env), jnp.zeros((), jnp.float32)
    if kind == "s":
        return _slstm_layer_seq(cfg, p, x, env), jnp.zeros((), jnp.float32)
    raise ValueError(kind)


# ===========================================================================
# per-kind caches + decode-mode forward
# ===========================================================================

def _layer_cache(cfg: ArchConfig, kind: str, batch: int, cache_len: int,
                 dtype) -> Cache:
    hd = cfg.hd
    if kind == "attn":
        shape = (batch, cache_len, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == "local":
        w = min(cfg.window, cache_len)
        shape = (batch, w, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "kpos": jnp.full((w,), -1, jnp.int32)}
    d = cfg.d_model
    hn = cfg.n_heads
    hdm = d // hn
    if kind == "rec":
        return {"h": jnp.zeros((batch, d), jnp.float32),
                "tail": jnp.zeros((batch, 3, d), jnp.float32)}
    if kind == "m":
        return {"c": jnp.zeros((batch, hn, hdm, hdm), jnp.float32),
                "n": jnp.zeros((batch, hn, hdm), jnp.float32)}
    if kind == "s":
        z = jnp.zeros((batch, hn, hdm), jnp.float32)
        return {"c": z, "n": z, "h": z,
                "m": jnp.full((batch, hn, hdm), -1e30, jnp.float32)}
    raise ValueError(kind)


def _layer_decode(cfg: ArchConfig, kind: str, p, x, cache: Cache,
                  pos, env: MeshEnv) -> Tuple[jnp.ndarray, Cache]:
    """x: (B, 1, d) -> (x', cache')."""
    dt = x.dtype
    b, _, d = x.shape
    if kind in ("attn", "local"):
        h = norm_apply(cfg, x, p["norm1"])
        posv = jnp.full((1,), pos, jnp.int32)
        q, k, v = _attn_qkv(cfg, p["attn"], h, posv)
        if kind == "attn":
            o, kc, vc = attn.decode_attention(
                q, cache["k"], cache["v"], k, v, pos, env=env)
            cache = {"k": kc, "v": vc}
        else:
            o, kc, vc, kp = attn.window_decode_attention(
                q, cache["k"], cache["v"], cache["kpos"], k, v, pos,
                window=cfg.window)
            cache = {"k": kc, "v": vc, "kpos": kp}
        x = x + o.reshape(b, 1, cfg.q_dim) @ p["attn"]["wo"].astype(dt)
        h2 = norm_apply(cfg, x, p["norm2"])
        if cfg.is_moe:
            y = moe_mod.moe_decode(cfg, p["moe"], h2, env=env)
            if cfg.n_shared_experts:
                y = y + mlp_apply(cfg, p["shared_mlp"], h2)
        else:
            y = mlp_apply(cfg, p["mlp"], h2)
        return x + y, cache
    if kind == "rec":
        h = norm_apply(cfg, x, p["norm1"])[:, 0]
        gate = jax.nn.gelu(h @ p["proj_gate"].astype(dt))
        xin = h @ p["proj_in"].astype(dt)
        (hh, tail), hr = rec.rglru_decode_step(
            (cache["h"], cache["tail"]), xin, p["w_rg"], p["b_rg"],
            p["w_ig"], p["b_ig"], p["conv_w"], p["conv_b"], p["lam"])
        x = x + ((gate * hr.astype(dt)) @ p["wo"].astype(dt))[:, None]
        h2 = norm_apply(cfg, x, p["norm2"])
        return x + mlp_apply(cfg, p["mlp"], h2), {"h": hh, "tail": tail}
    hn, hdm = cfg.n_heads, d // cfg.n_heads
    if kind == "m":
        h = norm_apply(cfg, x, p["norm1"])[:, 0]
        q = (h @ p["wq"].astype(dt)).reshape(b, hn, hdm)
        k = (h @ p["wk"].astype(dt)).reshape(b, hn, hdm)
        v = (h @ p["wv"].astype(dt)).reshape(b, hn, hdm)
        gates = h @ p["w_if"].astype(dt) + p["b_if"].astype(dt)
        i_raw, f_raw = jnp.split(gates, 2, axis=-1)
        (c, n), o = rec.mlstm_decode_step(
            (cache["c"], cache["n"]), q, k, v, i_raw, f_raw)
        x = x + (o.reshape(b, d) @ p["wo"].astype(dt))[:, None]
        return x, {"c": c, "n": n}
    if kind == "s":
        h = norm_apply(cfg, x, p["norm1"])[:, 0]
        pre = (h @ p["w_zifo"].astype(dt)).reshape(b, 4, hn, hdm)
        pre = pre + p["b_zifo"].astype(dt)
        st = (cache["c"], cache["n"], cache["h"], cache["m"])
        (c, n, hh, m), o = rec.slstm_decode_step(st, pre, p["r_mat"])
        x = x + (o.reshape(b, d) @ p["wo"].astype(dt))[:, None]
        return x, {"c": c, "n": n, "h": hh, "m": m}
    raise ValueError(kind)


# ===========================================================================
# the Model
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # --- layout -----------------------------------------------------------
    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.cfg.block_pattern

    @property
    def n_groups(self) -> int:
        return self.cfg.n_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> Tuple[str, ...]:
        return self.pattern[: self.cfg.n_layers % len(self.pattern)]

    # --- init ---------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        v, d = cfg.padded_vocab, cfg.d_model
        keys = jax.random.split(key, 8)
        params: Params = {
            "embed": jax.random.normal(keys[0], (v, d), jnp.float32) * 0.02,
            "final_norm": norm_init(cfg, d),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = jax.random.normal(keys[1], (v, d),
                                                  jnp.float32) * 0.02

        def stacked(kind: str, key, n: int):
            return jax.vmap(lambda k: _layer_params(cfg, kind, k))(
                jax.random.split(key, n))

        if self.n_groups > 0:
            params["stack"] = {
                f"{j}_{kind}": stacked(kind, jax.random.fold_in(keys[2], j),
                                       self.n_groups)
                for j, kind in enumerate(self.pattern)
            }
        if self.tail_kinds:
            params["tail"] = {
                f"{j}_{kind}": _layer_params(cfg, kind,
                                             jax.random.fold_in(keys[3], j))
                for j, kind in enumerate(self.tail_kinds)
            }
        if cfg.is_encoder_decoder:
            ek = jax.random.split(keys[4], cfg.n_encoder_layers)
            params["enc_stack"] = jax.vmap(
                lambda k: _layer_params(cfg, "attn", k))(ek)
            params["enc_norm"] = norm_init(cfg, d)
            ck = jax.random.split(keys[5], cfg.n_layers)
            params["cross_stack"] = jax.vmap(
                lambda k: {"attn": _attn_params(cfg, k),
                           "norm": norm_init(cfg, d)})(ck)
        return params

    def param_count(self, params: Optional[Params] = None) -> int:
        tree = params if params is not None else jax.eval_shape(
            self.init, jax.random.PRNGKey(0))
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        cfg = self.cfg
        total = self.param_count()
        if not cfg.is_moe:
            return total
        per_expert = 3 * cfg.d_model * cfg.d_ff_expert
        dead = cfg.n_layers * (cfg.n_experts - cfg.moe_top_k) * per_expert
        return total - dead

    # --- embedding / head ---------------------------------------------------
    def _embed(self, params: Params, tokens, dt):
        cfg = self.cfg
        x = params["embed"].astype(dt)[tokens]
        if cfg.scale_embeds:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
        return x

    def _logits(self, params: Params, x):
        w = params["embed"] if self.cfg.tie_embeddings else params["unembed"]
        env = get_env()
        if env is not None and env.mesh.size > 1 and env.tp_axis:
            # vocab stays model-sharded (matches the logits constraint);
            # the data-sharded feature dim gathers so the unembed dot
            # does not partial-sum (B, S, V)-sized activations.
            if w.shape[0] % env.tp_size == 0:
                w = jax.lax.with_sharding_constraint(
                    w, env.sharding(P(env.tp_axis, None)))
            else:
                w = jax.lax.with_sharding_constraint(
                    w, env.sharding(P(None, None)))
        return x @ w.astype(x.dtype).T

    # --- stack application ---------------------------------------------------
    def _run_stack(self, params: Params, x, env: MeshEnv, positions, *,
                   causal: bool = True, remat: bool = True):
        cfg = self.cfg
        pattern = self.pattern

        def group(x, p_slice):
            p_slice = gather_for_compute(p_slice)
            aux = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(pattern):
                x, a = _layer_seq(cfg, kind, p_slice[f"{j}_{kind}"], x, env,
                                  positions, causal=causal)
                aux = aux + a
            return x, aux

        aux_total = jnp.zeros((), jnp.float32)
        if self.n_groups > 0:
            body = jax.checkpoint(group) if remat else group

            def scan_fn(x, p_slice):
                return body(x, p_slice)

            x, auxs = jax.lax.scan(scan_fn, x, params["stack"])
            aux_total = aux_total + auxs.sum()
        for j, kind in enumerate(self.tail_kinds):
            p_tail = gather_for_compute(params["tail"][f"{j}_{kind}"])
            x, a = _layer_seq(cfg, kind, p_tail, x,
                              env, positions, causal=causal)
            aux_total = aux_total + a
        return x, aux_total

    def _run_encoder(self, params: Params, frames, env: MeshEnv):
        """Whisper encoder: bidirectional attention over stub frame embeds."""
        cfg = self.cfg
        x = frames + sinusoidal_positions(frames.shape[1],
                                          cfg.d_model).astype(frames.dtype)
        x = constrain(x, "dp", "sp", None)

        def layer(x, p):
            p = gather_for_compute(p)
            x, _ = _layer_seq(cfg, "attn", p, x, env, None, causal=False)
            return x, None

        x, _ = jax.lax.scan(jax.checkpoint(layer), x, params["enc_stack"])
        return norm_apply(cfg, x, params["enc_norm"])

    def _cross_layer(self, cfg, p, x, enc_kv, env):
        """Decoder cross-attention (memory precomputed as k/v)."""
        h = norm_apply(cfg, x, p["norm"])
        dt = h.dtype
        b, s, _ = h.shape
        q = (h @ p["attn"]["wq"].astype(dt)).reshape(b, s, cfg.n_heads, cfg.hd)
        k, v = enc_kv
        o = attn.cross_attention(q, k, v, env=env)
        return x + o.reshape(b, s, cfg.q_dim) @ p["attn"]["wo"].astype(dt)

    def _enc_kv(self, params: Params, enc_out):
        """Precompute per-decoder-layer cross K/V from encoder output."""
        cfg = self.cfg
        dt = enc_out.dtype
        b, f, _ = enc_out.shape

        def kv(p):
            k = (enc_out @ p["attn"]["wk"].astype(dt)).reshape(
                b, f, cfg.n_kv_heads, cfg.hd)
            v = (enc_out @ p["attn"]["wv"].astype(dt)).reshape(
                b, f, cfg.n_kv_heads, cfg.hd)
            return k, v

        return jax.vmap(kv)(params["cross_stack"])   # (L, B, F, KVH, hd)

    def _run_decoder_with_cross(self, params: Params, x, enc_out,
                                env: MeshEnv, positions,
                                cache_len: Optional[int] = None):
        """Whisper decoder: self-attn layer + cross-attn, per layer.

        With ``cache_len`` set, also returns the per-layer self-attn K/V
        caches (prefill mode).
        """
        cfg = self.cfg
        kv = self._enc_kv(params, enc_out)
        collect = cache_len is not None

        def layer(x, xs):
            p_self, p_cross, k, v = xs
            p_self = gather_for_compute(p_self)
            p_cross = gather_for_compute(p_cross)
            h = norm_apply(cfg, x, p_self["norm1"])
            q, kk, vv = _attn_qkv(cfg, p_self["attn"], h, positions)
            o = attn.ring_attention(q, kk, vv, env=env, causal=True)
            b, s, _, _ = o.shape
            x = x + o.reshape(b, s, cfg.q_dim) @ p_self["attn"]["wo"].astype(x.dtype)
            x = self._cross_layer(cfg, p_cross, x, (k, v), env)
            h2 = norm_apply(cfg, x, p_self["norm2"])
            x = x + mlp_apply(cfg, p_self["mlp"], h2)
            cache = ({"k": _pad_cache(kk, cache_len),
                      "v": _pad_cache(vv, cache_len)} if collect else 0)
            return x, cache

        stack = params["stack"]["0_attn"]
        body = layer if collect else jax.checkpoint(layer)
        x, caches = jax.lax.scan(body, x,
                                 (stack, params["cross_stack"], kv[0], kv[1]))
        return (x, caches) if collect else x

    # --- loss (train) --------------------------------------------------------
    def loss(self, params: Params, batch: Dict[str, jnp.ndarray],
             env: MeshEnv, *, remat: bool = True):
        cfg = self.cfg
        dt = _dtype(cfg)
        params = cast_params(params, dt)
        tokens = batch["tokens"]
        labels = batch["labels"]
        x = self._embed(params, tokens, dt)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(dt)
            x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
        x = constrain(x, "dp", "sp", None)
        positions = jnp.arange(x.shape[1])
        if cfg.is_encoder_decoder:
            enc = self._run_encoder(params, batch["frames"].astype(dt), env)
            x = self._run_decoder_with_cross(params, x, enc, env, positions)
            aux = jnp.zeros((), jnp.float32)
        else:
            x, aux = self._run_stack(params, x, env, positions, remat=remat)
        x = norm_apply(cfg, x, params["final_norm"])
        logits = self._logits(params, x)
        logits = constrain(logits, "dp", None, "tp")
        logits = logits.astype(jnp.float32)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mask
        loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
        return loss + 0.01 * aux, {"nll": loss, "aux": aux}

    # --- prefill -------------------------------------------------------------
    def prefill(self, params: Params, batch: Dict[str, jnp.ndarray],
                env: MeshEnv, cache_len: Optional[int] = None):
        """Forward over the prompt; returns (last_logits, caches).

        The decode caches returned are sized ``cache_len`` (default: the
        prompt length) and hold the prompt K/V (attention kinds) or the
        final recurrent state (rec/m/s kinds).
        """
        cfg = self.cfg
        dt = _dtype(cfg)
        params = cast_params(params, dt)
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache_len = cache_len or s
        x = self._embed(params, tokens, dt)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(dt)
            x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
        x = constrain(x, "dp", "sp", None)
        positions = jnp.arange(s)

        caches: Cache = {}
        if cfg.is_encoder_decoder:
            enc = self._run_encoder(params, batch["frames"].astype(dt), env)
            caches["enc_kv"] = self._enc_kv(params, enc)
            x, self_kv = self._run_decoder_with_cross(
                params, x, enc, env, positions, cache_len=cache_len)
            caches["stack"] = {"0_attn": self_kv}
        else:
            # run the stack while collecting per-layer caches
            x, caches["stack"], caches["tail"] = self._run_stack_with_cache(
                params, x, env, positions, cache_len)
        x = norm_apply(cfg, x, params["final_norm"])
        logits = self._logits(params, x[:, -1:])
        return logits.astype(jnp.float32), caches

    def _run_stack_with_cache(self, params: Params, x, env: MeshEnv,
                              positions, cache_len):
        cfg = self.cfg
        pattern = self.pattern
        b, s, _ = x.shape
        dt = x.dtype

        def layer_with_cache(kind, p, x):
            """Sequence forward + the decode cache this layer leaves behind."""
            if kind in ("attn", "local"):
                h = norm_apply(cfg, x, p["norm1"])
                q, k, v = _attn_qkv(cfg, p["attn"], h, positions)
                window = cfg.window if kind == "local" else 0
                o = attn.ring_attention(q, k, v, env=env, causal=True,
                                        window=window)
                x = x + o.reshape(b, s, cfg.q_dim) @ p["attn"]["wo"].astype(dt)
                h2 = norm_apply(cfg, x, p["norm2"])
                y, _ = _ffn(cfg, p, h2, env)
                x = x + y
                if kind == "attn":
                    cache = {"k": _pad_cache(k, cache_len),
                             "v": _pad_cache(v, cache_len)}
                else:
                    # rolling-window cache: keep the last min(w, s) keys at
                    # their pos % w slots so decode writes continue the ring
                    w = min(cfg.window, cache_len)
                    keep = min(w, s)
                    kpos = jnp.arange(s - keep, s)          # kept positions
                    idx = kpos % w
                    kw = jnp.zeros((b, w) + k.shape[2:], k.dtype
                                   ).at[:, idx].set(k[:, s - keep:])
                    vw = jnp.zeros((b, w) + v.shape[2:], v.dtype
                                   ).at[:, idx].set(v[:, s - keep:])
                    kp = jnp.full((w,), -1, jnp.int32).at[idx].set(kpos)
                    cache = {"k": kw, "v": vw, "kpos": kp}
                return x, cache
            if kind == "rec":
                h = norm_apply(cfg, x, p["norm1"])
                gate = jax.nn.gelu(h @ p["proj_gate"].astype(dt))
                xin = h @ p["proj_in"].astype(dt)
                hr = rec.rglru_seq(xin, p["w_rg"], p["b_rg"], p["w_ig"],
                                   p["b_ig"], p["conv_w"], p["conv_b"],
                                   p["lam"], env=env)
                x = x + (gate * hr) @ p["wo"].astype(dt)
                h2 = norm_apply(cfg, x, p["norm2"])
                x = x + mlp_apply(cfg, p["mlp"], h2)
                cache = {"h": hr[:, -1].astype(jnp.float32),
                         "tail": xin[:, -3:].astype(jnp.float32)}
                return x, cache
            if kind == "m":
                hn, hdm = cfg.n_heads, cfg.d_model // cfg.n_heads
                h = norm_apply(cfg, x, p["norm1"])
                q = (h @ p["wq"].astype(dt)).reshape(b, s, hn, hdm)
                k = (h @ p["wk"].astype(dt)).reshape(b, s, hn, hdm)
                v = (h @ p["wv"].astype(dt)).reshape(b, s, hn, hdm)
                gates = h @ p["w_if"].astype(dt) + p["b_if"].astype(dt)
                i_raw, f_raw = jnp.split(gates, 2, axis=-1)
                o, (cT, nT) = _mlstm_with_state(q, k, v, i_raw, f_raw, env)
                x = x + o.reshape(b, s, cfg.d_model) @ p["wo"].astype(dt)
                return x, {"c": cT, "n": nT}
            if kind == "s":
                hn, hdm = cfg.n_heads, cfg.d_model // cfg.n_heads
                h = norm_apply(cfg, x, p["norm1"])
                pre = (h @ p["w_zifo"].astype(dt)).reshape(b, s, 4, hn, hdm)
                pre = pre + p["b_zifo"].astype(dt)
                o, st = _slstm_with_state(pre, p["r_mat"], env)
                x = x + o.reshape(b, s, cfg.d_model) @ p["wo"].astype(dt)
                return x, {"c": st[0], "n": st[1], "h": st[2], "m": st[3]}
            raise ValueError(kind)

        stack_caches = None
        if self.n_groups > 0:
            def group(x, p_slice):
                p_slice = gather_for_compute(p_slice)
                caches = {}
                for j, kind in enumerate(pattern):
                    x, c = layer_with_cache(kind, p_slice[f"{j}_{kind}"], x)
                    caches[f"{j}_{kind}"] = c
                return x, caches

            x, stack_caches = jax.lax.scan(group, x, params["stack"])
        tail_caches = {}
        for j, kind in enumerate(self.tail_kinds):
            x, c = layer_with_cache(
                kind, gather_for_compute(params["tail"][f"{j}_{kind}"]), x)
            tail_caches[f"{j}_{kind}"] = c
        return x, stack_caches, tail_caches

    # --- decode ----------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int) -> Cache:
        cfg = self.cfg
        dt = _dtype(cfg)
        caches: Cache = {}
        if self.n_groups > 0:
            caches["stack"] = {
                f"{j}_{kind}": jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x, (self.n_groups,) + x.shape).copy(),
                    _layer_cache(cfg, kind, batch, cache_len, dt))
                for j, kind in enumerate(self.pattern)
            }
        if self.tail_kinds:
            caches["tail"] = {
                f"{j}_{kind}": _layer_cache(cfg, kind, batch, cache_len, dt)
                for j, kind in enumerate(self.tail_kinds)
            }
        if cfg.is_encoder_decoder:
            f = _round_up(cfg.encoder_seq, 256)
            caches["enc_kv"] = (
                jnp.zeros((cfg.n_layers, batch, f, cfg.n_kv_heads, cfg.hd), dt),
                jnp.zeros((cfg.n_layers, batch, f, cfg.n_kv_heads, cfg.hd), dt),
            )
        return caches

    def decode_step(self, params: Params, caches: Cache, token, pos,
                    env: MeshEnv):
        """token: (B, 1) int32; pos: () int32.  Returns (logits, caches')."""
        cfg = self.cfg
        dt = _dtype(cfg)
        params = cast_params(params, dt)
        x = self._embed(params, token, dt)
        pattern = self.pattern

        new_caches: Cache = {}
        if cfg.is_encoder_decoder:
            kv = caches["enc_kv"]
            new_caches["enc_kv"] = kv

            def cross_step(x, p_cross, k, v):
                h = norm_apply(cfg, x, p_cross["norm"])
                b = x.shape[0]
                g = cfg.n_heads // cfg.n_kv_heads
                q = (h @ p_cross["attn"]["wq"].astype(dt)).reshape(
                    b, 1, cfg.n_kv_heads, g, cfg.hd)
                s = jnp.einsum("bqkgd,bskd->bqkgs", q, k,
                               preferred_element_type=jnp.float32)
                s = s * (cfg.hd ** -0.5)
                pr = jax.nn.softmax(s, axis=-1).astype(dt)
                o = jnp.einsum("bqkgs,bskd->bqkgd", pr, v)
                return x + o.reshape(b, 1, cfg.q_dim) @ \
                    p_cross["attn"]["wo"].astype(dt)

            def dec_layer(x, xs):
                # faithful whisper order: self-attn -> cross-attn -> FFN
                p_self, p_cross, k, v, c = xs
                b = x.shape[0]
                h = norm_apply(cfg, x, p_self["norm1"])
                posv = jnp.full((1,), pos, jnp.int32)
                q, kk, vv = _attn_qkv(cfg, p_self["attn"], h, posv)
                o, kc, vc = attn.decode_attention(
                    q, c["k"], c["v"], kk, vv, pos, env=env)
                x = x + o.reshape(b, 1, cfg.q_dim) @ \
                    p_self["attn"]["wo"].astype(dt)
                x = cross_step(x, p_cross, k, v)
                h2 = norm_apply(cfg, x, p_self["norm2"])
                x = x + mlp_apply(cfg, p_self["mlp"], h2)
                return x, {"k": kc, "v": vc}

            x, nc = jax.lax.scan(
                dec_layer, x,
                (params["stack"]["0_attn"], params["cross_stack"],
                 kv[0], kv[1], caches["stack"]["0_attn"]))
            new_caches["stack"] = {"0_attn": nc}
        else:
            if self.n_groups > 0:
                def group(x, xs):
                    # decode stays weight-stationary: one token cannot
                    # amortize a per-layer weight gather; the sharded
                    # dots' small activation psums are cheaper.
                    p_slice, c_slice = xs
                    out = {}
                    for j, kind in enumerate(pattern):
                        key = f"{j}_{kind}"
                        x, c = _layer_decode(cfg, kind, p_slice[key], x,
                                             c_slice[key], pos, env)
                        out[key] = c
                    return x, out

                x, nc = jax.lax.scan(group, x,
                                     (params["stack"], caches["stack"]))
                new_caches["stack"] = nc
            if self.tail_kinds:
                new_caches["tail"] = {}
                for j, kind in enumerate(self.tail_kinds):
                    key = f"{j}_{kind}"
                    x, c = _layer_decode(cfg, kind, params["tail"][key],
                                         x, caches["tail"][key], pos, env)
                    new_caches["tail"][key] = c
        x = norm_apply(cfg, x, params["final_norm"])
        logits = self._logits(params, x)
        logits = constrain(logits, "dp", None, "tp")
        return logits.astype(jnp.float32), new_caches


def _pad_cache(k, cache_len: int):
    s = k.shape[1]
    if s == cache_len:
        return k
    if s > cache_len:
        return k[:, :cache_len]
    pad = [(0, 0)] * k.ndim
    pad[1] = (0, cache_len - s)
    return jnp.pad(k, pad)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _mlstm_with_state(q, k, v, i_raw, f_raw, env: MeshEnv):
    """mlstm_seq + final (C, n) state for prefill->decode handoff."""
    out = rec.mlstm_seq(q, k, v, i_raw, f_raw, env=env)
    # recompute the final state from the summaries (cheap, no attention)
    hd = q.shape[-1]
    kf = k.astype(jnp.float32) * (hd ** -0.5)
    vf = v.astype(jnp.float32)
    logi = -jax.nn.softplus(-i_raw.astype(jnp.float32))
    logf = -jax.nn.softplus(-f_raw.astype(jnp.float32))
    cum = jnp.cumsum(logf, axis=1)
    wend = jnp.exp(cum[:, -1:, :] - cum + logi)
    cT = jnp.einsum("bshd,bshv,bsh->bhdv", kf, vf, wend)
    nT = jnp.einsum("bshd,bsh->bhd", kf, wend)
    return out, (cT, nT)


def _slstm_with_state(pre, r_mat, env: MeshEnv):
    """slstm_seq + final state (rerun the last step locally)."""
    out = rec.slstm_seq(pre, r_mat, env=env)
    b, s, _, hn, hd = pre.shape
    z = jnp.zeros((b, hn, hd), jnp.float32)
    st = (z, z, z, jnp.full((b, hn, hd), -1e30, jnp.float32))
    # exact final state requires the full scan; decode handoff re-derives
    # it from the last position's output (approximation documented in
    # DESIGN.md; exact for the smoke-scale tests via single-rank scan).
    _, carry = rec._slstm_local_scan(pre.astype(jnp.float32), r_mat, st)
    return out, carry


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
