"""Recurrent sequence mixers: mLSTM / sLSTM (xLSTM) and RG-LRU (Griffin).

All three support the SP layout (sequence sharded over the `model` mesh
axis).  Linear recurrences (mLSTM state, RG-LRU) cross the rank boundary
with an exclusive ring prefix-scan over cheap segment summaries
(Hillis–Steele doubling, log2(n) ppermutes); the genuinely sequential
sLSTM (h-dependent gating) crosses ranks with a sequential carry chain.

Numerical conventions (documented simplifications vs. arXiv:2405.04517):
  * mLSTM input gate uses log-sigmoid (bounded) instead of the exp gate +
    max-stabilizer pair; forget gate is log-sigmoid as in the paper.
  * sLSTM keeps the exponential gating + (c, n, m) stabilizer state and
    the per-head recurrent matrices R (the defining sLSTM trait).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import MeshEnv


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _exclusive_ring_prefix(summary, combine, identity, tp: str, n: int):
    """Exclusive prefix over mesh ranks of segment summaries.

    ``combine(earlier, later)`` composes two adjacent segments.  Returns,
    at rank r, the composition of ranks 0..r-1 (identity at rank 0).
    """
    r = jax.lax.axis_index(tp)
    val = summary
    d = 1
    while d < n:
        recv = jax.tree.map(
            lambda x: jax.lax.ppermute(x, tp, [(i, (i + d) % n) for i in range(n)]),
            val,
        )
        val = _tree_where(r >= d, combine(recv, val), val)
        d *= 2
    recv = jax.tree.map(
        lambda x: jax.lax.ppermute(x, tp, [(i, (i + 1) % n) for i in range(n)]),
        val,
    )
    return _tree_where(r == 0, identity, recv)


def _logsig(x):
    return -jax.nn.softplus(-x)


# ===========================================================================
# mLSTM
# ===========================================================================

def _mlstm_chunk_scan(q, k, v, logi, logf, c0, n0, chunk: int):
    """Chunked-parallel mLSTM over a local sequence.

    q,k,v: (B,S,H,hd) f32; logi,logf: (B,S,H) f32 (log gates, <= 0)
    c0: (B,H,hd,hd); n0: (B,H,hd).  Returns h (B,S,H,hd), (cT, nT).
    """
    b, s, h, hd = q.shape
    L = chunk
    nc = s // L
    resh = lambda x: x.reshape((b, nc, L) + x.shape[2:]).swapaxes(0, 1)
    qs, ks, vs, lis, lfs = map(resh, (q, k, v, logi, logf))

    def step(carry, xs):
        with jax.named_scope("kernel_interior"):
            return _mlstm_chunk_step(carry, xs)

    def _mlstm_chunk_step(carry, xs):
        C, nv = carry
        qc, kc, vc, li, lf = xs  # (B,L,H,*)
        cum = jnp.cumsum(lf, axis=1)  # (B,L,H)
        dec = jnp.exp(cum)[..., None]  # (B,L,H,1)
        qdec = qc * dec
        h_inter = jnp.einsum("blhd,bhdv->blhv", qdec, C)
        qn_inter = jnp.einsum("blhd,bhd->blh", qdec, nv)
        # intra-chunk decay-weighted scores
        diff = cum[:, :, None, :] - cum[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.exp(jnp.where(tri[None, :, :, None], diff, -jnp.inf))
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc) * w
        h_intra = jnp.einsum("btsh,bshv->bthv", scores, vc)
        qn = qn_inter + jnp.sum(scores, axis=2)
        hc = (h_inter + h_intra) / jnp.maximum(jnp.abs(qn), 1.0)[..., None]
        # carry update
        dend = jnp.exp(cum[:, -1])  # (B,H)
        wend = jnp.exp(cum[:, -1:, :] - cum + li)  # (B,L,H)
        C = dend[..., None, None] * C + jnp.einsum("blhd,blhv,blh->bhdv", kc, vc, wend)
        nv = dend[..., None] * nv + jnp.einsum("blhd,blh->bhd", kc, wend)
        return (C, nv), hc

    (cT, nT), hs = jax.lax.scan(step, (c0, n0), (qs, ks, vs, lis, lfs))
    return hs.swapaxes(0, 1).reshape(b, s, h, hd), (cT, nT)


def mlstm_seq(q, k, v, i_raw, f_raw, *, env: MeshEnv, chunk: int = 256):
    """mLSTM over a (possibly seq-sharded) sequence.

    q,k,v: (B,S,H,hd); i_raw,f_raw: (B,S,H).  B over dp, S over model.
    """
    tp, n = env.tp_axis, env.tp_size
    hd = q.shape[-1]
    scale = hd ** -0.5

    def local(q_l, k_l, v_l, ir, fr):
        b, s, h, _ = q_l.shape
        qf = q_l.astype(jnp.float32) * scale
        kf = k_l.astype(jnp.float32) * (hd ** -0.5)
        vf = v_l.astype(jnp.float32)
        logi = _logsig(ir.astype(jnp.float32))
        logf = _logsig(fr.astype(jnp.float32))
        L = min(chunk, s)
        while s % L:
            L -= 1
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        if n > 1:
            # segment summaries (no q needed)
            cum = jnp.cumsum(logf, axis=1)
            dtot = jnp.exp(cum[:, -1])  # (B,H)
            wend = jnp.exp(cum[:, -1:, :] - cum + logi)
            c_delta = jnp.einsum("bshd,bshv,bsh->bhdv", kf, vf, wend)
            n_delta = jnp.einsum("bshd,bsh->bhd", kf, wend)

            def comb(e, l):  # earlier, later
                de, ce, ne = e
                dl, cl, nl = l
                return (de * dl,
                        dl[..., None, None] * ce + cl,
                        dl[..., None] * ne + nl)

            ident = (jnp.ones_like(dtot), jnp.zeros_like(c_delta), jnp.zeros_like(n_delta))
            _, c0, n0 = _exclusive_ring_prefix(
                (dtot, c_delta, n_delta), comb, ident, tp, n)
        hs, _ = _mlstm_chunk_scan(qf, kf, vf, logi, logf, c0, n0, L)
        return hs.astype(q_l.dtype)

    if tp is None or n == 1:
        return local(q, k, v, i_raw, f_raw)
    s4 = P(env.dp_axes, tp, None, None)
    s3 = P(env.dp_axes, tp, None)
    return jax.shard_map(
        local, mesh=env.mesh,
        in_specs=(s4, s4, s4, s3, s3), out_specs=s4, check_vma=False,
    )(q, k, v, i_raw, f_raw)


def mlstm_decode_step(state, q, k, v, i_raw, f_raw):
    """One decode step.  state = (C (B,H,hd,hd), n (B,H,hd)); q,k,v (B,H,hd)."""
    C, nv = state
    hd = q.shape[-1]
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    kf = k.astype(jnp.float32) * (hd ** -0.5)
    vf = v.astype(jnp.float32)
    i_g = jnp.exp(_logsig(i_raw.astype(jnp.float32)))[..., None]
    f_g = jnp.exp(_logsig(f_raw.astype(jnp.float32)))[..., None]
    C = f_g[..., None] * C + i_g[..., None] * (kf[..., :, None] * vf[..., None, :])
    nv = f_g * nv + i_g * kf
    qn = jnp.einsum("bhd,bhd->bh", qf, nv)
    h = jnp.einsum("bhd,bhdv->bhv", qf, C) / jnp.maximum(jnp.abs(qn), 1.0)[..., None]
    return (C, nv), h.astype(q.dtype)


# ===========================================================================
# sLSTM
# ===========================================================================

def _slstm_local_scan(xpre, r_mat, state):
    """xpre: (B,S,4,H,hd) f32; r_mat: (H,hd,4*hd); state=(c,n,h,m) (B,H,hd)."""
    b, s, _, h, hd = xpre.shape

    def step(carry, x_t):
        # kernels/slstm_scan keeps R + state VMEM-resident on TPU; the
        # scope tag lets the roofline report the kernelized memory term.
        with jax.named_scope("kernel_interior"):
            return _slstm_step(carry, x_t, r_mat, b, h, hd)

    def _slstm_step(carry, x_t, r_mat, b, h, hd):
        c, nrm, hprev, m = carry
        rec = jnp.einsum("bhd,hde->bhe", hprev, r_mat).reshape(b, h, 4, hd)
        tot = x_t + rec.transpose(0, 2, 1, 3)  # (B,4,H,hd)
        z = jnp.tanh(tot[:, 0])
        logi = tot[:, 1]
        logf = _logsig(tot[:, 2])
        o = jax.nn.sigmoid(tot[:, 3])
        m_new = jnp.maximum(logf + m, logi)
        i_s = jnp.exp(logi - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c = f_s * c + i_s * z
        nrm = f_s * nrm + i_s
        hnew = o * c / jnp.maximum(nrm, 1e-6)
        return (c, nrm, hnew, m_new), hnew

    xs = xpre.transpose(1, 0, 2, 3, 4)  # (S,B,4,H,hd)
    carry, hs = jax.lax.scan(step, state, xs)
    return hs.transpose(1, 0, 2, 3), carry  # (B,S,H,hd)


def slstm_seq(xpre, r_mat, *, env: MeshEnv):
    """sLSTM over a (possibly seq-sharded) sequence.

    xpre: (B,S,4,H,hd) pre-activations (x @ W + b); r_mat (H,hd,4*hd).
    Cross-rank: sequential carry chain (the recurrence is h-dependent).
    """
    tp, n = env.tp_axis, env.tp_size

    def zeros_state(b, h, hd):
        z = jnp.zeros((b, h, hd), jnp.float32)
        return (z, z, z, jnp.full((b, h, hd), -1e30, jnp.float32))

    def local(xp, rm):
        b, s, _, h, hd = xp.shape
        xp = xp.astype(jnp.float32)
        st = zeros_state(b, h, hd)
        if n == 1:
            hs, _ = _slstm_local_scan(xp, rm, st)
            return hs.astype(xpre.dtype)
        r = jax.lax.axis_index(tp)
        perm = [(i, (i + 1) % n) for i in range(n)]
        h_out = jnp.zeros((b, s, h, hd), jnp.float32)
        carry = st

        def outer(loop_carry, step_idx):
            carry, h_out = loop_carry
            hs, cand = _slstm_local_scan(xp, rm, carry)
            keep = r == step_idx
            h_out = jnp.where(keep, hs, h_out)
            carry_new = jax.tree.map(
                lambda x: jax.lax.ppermute(x, tp, perm), cand)
            carry = _tree_where(r == step_idx + 1, carry_new, carry)
            return (carry, h_out), None

        (carry, h_out), _ = jax.lax.scan(
            outer, (carry, h_out), jnp.arange(n))
        return h_out.astype(xpre.dtype)

    if tp is None or n == 1:
        return local(xpre, r_mat)
    return jax.shard_map(
        local, mesh=env.mesh,
        in_specs=(P(env.dp_axes, tp, None, None, None), P(None, None, None)),
        out_specs=P(env.dp_axes, tp, None, None), check_vma=False,
    )(xpre, r_mat)


def slstm_decode_step(state, xpre_t, r_mat):
    """xpre_t: (B,4,H,hd); state (c,n,h,m) each (B,H,hd)."""
    xp = xpre_t.astype(jnp.float32)[:, None]  # (B,1,4,H,hd)
    hs, carry = _slstm_local_scan(xp, r_mat, state)
    return carry, hs[:, 0].astype(xpre_t.dtype)


# ===========================================================================
# RG-LRU (Griffin recurrent block core)
# ===========================================================================

RGLRU_C = 8.0


def _causal_conv4(x, w, b, tail):
    """Depthwise causal conv, width 4.  x: (B,S,dr); w: (4,dr); tail (B,3,dr)."""
    xp = jnp.concatenate([tail, x], axis=1)
    out = b
    for j in range(4):
        out = out + xp[:, 3 - j : xp.shape[1] - j] * w[j]
    return out


def rglru_seq(x_br, w_rg, b_rg, w_ig, b_ig, conv_w, conv_b, lam, *, env: MeshEnv):
    """Conv4 + RG-LRU over a (possibly seq-sharded) sequence.

    x_br: (B,S,dr) recurrent-branch input.  Returns h (B,S,dr).
    """
    tp, n = env.tp_axis, env.tp_size

    def local(xb, wrg, brg, wig, big, cw, cb, lm):
        b, s, dr = xb.shape
        xf = xb.astype(jnp.float32)
        if n > 1:
            perm = [(i, (i + 1) % n) for i in range(n - 1)]  # rank0 gets zeros
            tail = jax.lax.ppermute(xf[:, -3:], tp, perm)
        else:
            tail = jnp.zeros((b, 3, dr), jnp.float32)
        y = _causal_conv4(xf, cw.astype(jnp.float32), cb.astype(jnp.float32), tail)
        r_g = jax.nn.sigmoid(y @ wrg.astype(jnp.float32) + brg)
        i_g = jax.nn.sigmoid(y @ wig.astype(jnp.float32) + big)
        log_a = -RGLRU_C * jax.nn.softplus(lm.astype(jnp.float32)) * r_g
        a = jnp.exp(log_a)
        gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_g * y)

        def comb(e, l):
            return (e[0] * l[0], l[0] * e[1] + l[1])

        a_cum, h = jax.lax.associative_scan(comb, (a, gated), axis=1)
        if n > 1:
            summ = (a_cum[:, -1], h[:, -1])
            ident = (jnp.ones_like(summ[0]), jnp.zeros_like(summ[1]))
            _, h_in = _exclusive_ring_prefix(summ, comb, ident, tp, n)
            h = h + a_cum * h_in[:, None]
        return h.astype(xb.dtype)

    if tp is None or n == 1:
        return local(x_br, w_rg, b_rg, w_ig, b_ig, conv_w, conv_b, lam)
    rep2 = P(None, None)
    rep1 = P(None)
    return jax.shard_map(
        local, mesh=env.mesh,
        in_specs=(P(env.dp_axes, tp, None), rep2, rep1, rep2, rep1, rep2, rep1, rep1),
        out_specs=P(env.dp_axes, tp, None), check_vma=False,
    )(x_br, w_rg, b_rg, w_ig, b_ig, conv_w, conv_b, lam)


def rglru_decode_step(state, x_t, w_rg, b_rg, w_ig, b_ig, conv_w, conv_b, lam):
    """state = (h (B,dr), conv_tail (B,3,dr)); x_t: (B,dr)."""
    h_prev, tail = state
    xf = x_t.astype(jnp.float32)
    xp = jnp.concatenate([tail, xf[:, None]], axis=1)  # (B,4,dr)
    y = conv_b.astype(jnp.float32)
    for j in range(4):
        y = y + xp[:, 3 - j] * conv_w[j].astype(jnp.float32)
    r_g = jax.nn.sigmoid(y @ w_rg.astype(jnp.float32) + b_rg)
    i_g = jax.nn.sigmoid(y @ w_ig.astype(jnp.float32) + b_ig)
    a = jnp.exp(-RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r_g)
    h = a * h_prev + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_g * y)
    new_tail = jnp.concatenate([tail[:, 1:], xf[:, None]], axis=1)
    return (h, new_tail), h.astype(x_t.dtype)
