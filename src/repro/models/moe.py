"""Mixture-of-Experts layer with expert parallelism.

Two execution modes, both exact w.r.t. routing (capacity drops aside):

  * ``moe_dispatch``   (train / prefill): tokens are sharded over
    (dp..., model) [SP layout]; experts are sharded over `model` (EP)
    with their contraction dim FSDP-sharded over `data`.  Tokens are
    scatter-packed into per-expert capacity buffers, exchanged with a
    single ``all_to_all`` over `model`, processed with dense per-expert
    matmuls (true active-FLOPs only — no one-hot einsum dispatch), and
    exchanged back.

  * ``moe_decode``     (single-token decode): the token batch is tiny,
    so tokens are all-gathered over dp; each `model` rank gathers only
    the tokens routed to its local experts (capacity buffer), computes
    the expert FFN with d_ff TP-sharded over `data` (partial-sum psum),
    and contributions are psum-combined over `model`.  Expert weights
    stay fully sharded (E over model × d_ff over data) — resident
    memory per device is E/16 x d x f/16.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.sharding import MeshEnv
from repro.models.layers import act_fn, dense_init


def moe_init(cfg: ArchConfig, key):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, e),
        "expert_w_gate": jax.random.normal(ks[1], (e, d, f)) * (d ** -0.5),
        "expert_w_up": jax.random.normal(ks[2], (e, d, f)) * (d ** -0.5),
        "expert_w_down": jax.random.normal(ks[3], (e, f, d)) * (f ** -0.5),
    }
    return p


def _route(x_f32, router_w, top_k: int):
    """x: (t, d) f32.  Returns gates (t,k) f32, ids (t,k) int32, probs (t,E)."""
    logits = x_f32 @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, ids, probs


def _aux_loss(probs, ids, n_experts: int, axes) -> jnp.ndarray:
    """Switch-style load-balance loss, psum-averaged over all mesh axes."""
    t = probs.shape[0]
    frac = jnp.zeros((n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    p_sum = probs.sum(0)
    t_tot = jnp.asarray(t * ids.shape[1], jnp.float32)
    if axes:
        frac = jax.lax.psum(frac, axes)
        p_sum = jax.lax.psum(p_sum, axes)
        t_tot = jax.lax.psum(t_tot, axes)
    return n_experts * jnp.sum((frac / t_tot) * (p_sum / (t_tot / ids.shape[1])))


def _expert_ffn(cfg: ArchConfig, tokens, w_gate, w_up, w_down):
    """tokens: (E_loc, C, d); weights (E_loc, d, f)/(E_loc, f, d)."""
    act = act_fn(cfg.act)
    dt = tokens.dtype
    h = act(jnp.einsum("ecd,edf->ecf", tokens, w_gate.astype(dt))) * jnp.einsum(
        "ecd,edf->ecf", tokens, w_up.astype(dt))
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt))


# ---------------------------------------------------------------------------
# train / prefill: scatter -> all_to_all -> expert FFN -> all_to_all -> gather
# ---------------------------------------------------------------------------

def moe_dispatch(cfg: ArchConfig, p, x, *, env: MeshEnv):
    """x: (B, S, d) sharded (dp, model, None).  Returns (y, aux_loss)."""
    tp, n_tp = env.tp_axis, env.tp_size
    dp = env.dp_axes
    e, k = cfg.n_experts, cfg.moe_top_k
    e_loc = max(e // max(n_tp, 1), 1)
    has_data = "data" in env.axis_names

    def local(x_l, router_w, wg, wu, wd):
        b, s, d = x_l.shape
        t = b * s
        xt = x_l.reshape(t, d)
        gates, ids, probs = _route(xt.astype(jnp.float32), router_w, k)
        all_axes = tuple(a for a in env.axis_names)
        aux = _aux_loss(probs, ids, e, all_axes if n_tp > 1 or env.dp_size > 1 else ())

        cap = int(max(4, round(t * k / e * cfg.capacity_factor)))
        flat_ids = ids.reshape(-1)                       # (t*k,)
        # position-within-expert via sort-based ranking: O(n log n) and
        # O(n+E) memory, vs the one-hot cumsum formulation whose
        # (t·k, E) running sum lowers to an O(t·k·E) reduce-window —
        # the dominant HBM term of the MoE cells before this change
        # (EXPERIMENTS.md §Perf, qwen3-moe iteration 1).
        order = jnp.argsort(flat_ids, stable=True)       # grouped by expert
        counts = jnp.zeros((e,), jnp.int32).at[flat_ids].add(1)
        starts = jnp.cumsum(counts) - counts             # exclusive prefix
        pos_sorted = jnp.arange(t * k) - starts[flat_ids[order]]
        pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)
        src = jnp.repeat(jnp.arange(t), k)
        buf = jnp.zeros((e, cap, d), xt.dtype)
        buf = buf.at[flat_ids, pos].set(xt[src], mode="drop")

        if n_tp > 1:
            # (n_tp, E_loc, cap, d) -> exchange expert-owner blocks
            buf = buf.reshape(n_tp, e_loc, cap, d)
            recv = jax.lax.all_to_all(buf, tp, split_axis=0, concat_axis=0,
                                      tiled=True)       # (n_tp_src, E_loc, cap, d)
            tokens_e = recv.transpose(1, 0, 2, 3).reshape(e_loc, n_tp * cap, d)
        else:
            tokens_e = buf                                # (E, cap, d)

        # FSDP weight gather in COMPUTE dtype: tokens are data-parallel
        # (each data rank owns a batch shard), so expert weights must be
        # gathered over `data` — but gathering the f32 master copies
        # doubles the wire and HBM cost vs casting first.  (A tokens-stay
        # /weights-stay F-TP over `data` is unsound here: different data
        # ranks hold different tokens, their partial sums must not mix.)
        dt_ = tokens_e.dtype
        wg, wu, wd = (w.astype(dt_) for w in (wg, wu, wd))
        if has_data and env.size("data") > 1:
            wg = jax.lax.all_gather(wg, "data", axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=1, tiled=True)
        y_e = _expert_ffn(cfg, tokens_e, wg, wu, wd)

        if n_tp > 1:
            y_e = y_e.reshape(e_loc, n_tp, cap, d).transpose(1, 0, 2, 3)
            back = jax.lax.all_to_all(y_e, tp, split_axis=0, concat_axis=0,
                                      tiled=True)
            back = back.reshape(e, cap, d)
        else:
            back = y_e

        vals = back[flat_ids, jnp.clip(pos, 0, cap - 1)]
        vals = jnp.where((pos < cap)[:, None], vals, 0.0)
        y = (vals.reshape(t, k, d) * gates[..., None].astype(vals.dtype)).sum(1)
        return y.reshape(b, s, d), aux

    if tp is None:
        return local(x, p["router"], p["expert_w_gate"], p["expert_w_up"],
                     p["expert_w_down"])

    xspec = P(dp, tp, None)
    dspec = "data" if has_data else None
    wspec_gu = P(tp, None, dspec)     # (E, d, f): f over data
    wspec_d = P(tp, dspec, None)      # (E, f, d): f over data
    return jax.shard_map(
        local, mesh=env.mesh,
        in_specs=(xspec, P(None, None), wspec_gu, wspec_gu, wspec_d),
        out_specs=(xspec, P()),
        check_vma=False,
    )(x, p["router"], p["expert_w_gate"], p["expert_w_up"], p["expert_w_down"])


# ---------------------------------------------------------------------------
# decode: gather tokens -> capacity gather per model rank -> F-TP over data
# ---------------------------------------------------------------------------

def moe_decode(cfg: ArchConfig, p, x, *, env: MeshEnv):
    """x: (B, 1, d) sharded (dp, None, None).  Returns y (B, 1, d)."""
    tp, n_tp = env.tp_axis, env.tp_size
    dp = env.dp_axes
    e, k = cfg.n_experts, cfg.moe_top_k
    e_loc = max(e // max(n_tp, 1), 1)
    has_data = "data" in env.axis_names
    dsz = env.size("data") if has_data else 1

    def local(x_l, router_w, wg, wu, wd):
        b_loc, _, d = x_l.shape
        xt = x_l.reshape(b_loc, d)
        if dp and env.dp_size > 1:
            xt = jax.lax.all_gather(xt, dp, axis=0, tiled=True)  # (B_all, d)
        b_all = xt.shape[0]
        gates, ids, _ = _route(xt.astype(jnp.float32), router_w, k)

        r = jax.lax.axis_index(tp) if n_tp > 1 else 0
        lo = r * e_loc
        # (token, choice) pairs routed to local experts
        flat_ids = ids.reshape(-1)
        flat_gates = gates.reshape(-1)
        is_local = (flat_ids >= lo) & (flat_ids < lo + e_loc)
        cap = int(max(4, round(b_all * k / max(n_tp, 1) * 2)))
        order = jnp.argsort(~is_local)  # local pairs first (stable)
        sel = order[:cap]
        sel_valid = is_local[sel]
        sel_tok = sel // k
        sel_exp = jnp.clip(flat_ids[sel] - lo, 0, e_loc - 1)
        sel_gate = jnp.where(sel_valid, flat_gates[sel], 0.0)

        toks = xt[sel_tok]                        # (cap, d)
        wg_l, wu_l, wd_l = (w.astype(toks.dtype) for w in (wg, wu, wd))
        act = act_fn(cfg.act)
        h = act(jnp.einsum("cd,cdf->cf", toks, wg_l[sel_exp])) * jnp.einsum(
            "cd,cdf->cf", toks, wu_l[sel_exp])
        y_pair = jnp.einsum("cf,cfd->cd", h, wd_l[sel_exp])  # partial over f-slice
        if has_data and dsz > 1:
            y_pair = jax.lax.psum(y_pair, "data")
        y_pair = y_pair * sel_gate[:, None].astype(y_pair.dtype)
        y_all = jnp.zeros((b_all, d), y_pair.dtype).at[sel_tok].add(
            jnp.where(sel_valid[:, None], y_pair, 0.0))
        if n_tp > 1:
            y_all = jax.lax.psum(y_all, tp)
        if dp and env.dp_size > 1:
            idx = jax.lax.axis_index(dp[0])
            if len(dp) > 1:
                idx = idx * env.size(dp[1]) + jax.lax.axis_index(dp[1])
            y_all = jax.lax.dynamic_slice_in_dim(y_all, idx * b_loc, b_loc, 0)
        return y_all.reshape(b_loc, 1, d)

    if tp is None:
        return local(x, p["router"], p["expert_w_gate"], p["expert_w_up"],
                     p["expert_w_down"])

    xspec = P(dp, None, None)
    dspec = "data" if has_data else None
    return jax.shard_map(
        local, mesh=env.mesh,
        in_specs=(xspec, P(None, None), P(tp, None, dspec), P(tp, None, dspec),
                  P(tp, dspec, None)),
        out_specs=xspec,
        check_vma=False,
    )(x, p["router"], p["expert_w_gate"], p["expert_w_up"], p["expert_w_down"])
