"""Distributed attention.

Two paths, both head-count agnostic (heads are never sharded — the
production mesh's `model` axis shards the *sequence* instead):

  * ``ring_attention`` — train/prefill.  Activations are
    sequence-sharded over the `model` axis (SP); KV blocks rotate around
    the ring via ``ppermute`` while each device updates an online-softmax
    accumulator for its local queries (blockwise/ring attention).
    Supports causal, bidirectional and sliding-window masks; windowed
    attention stops the ring early (static step count).

  * ``decode_attention`` — single-token decode.  The KV cache is
    sequence-sharded over `model`; every device computes a partial
    flash-decode over its chunk (split-K) and partial softmax stats are
    merged with ``pmax``/``psum``.

The per-block math mirrors kernels/flash_attention (the Pallas TPU
kernel); on this CPU host the jnp path is used so the dry-run lowers to
plain HLO.  FLOPs are identical.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import MeshEnv

NEG_INF = -1e30


def _dp_spec(env: MeshEnv, b: int):
    """DP axes for a batch dim, or None when b is not divisible (B=1
    long-context decode replicates the batch)."""
    dp = env.dp_axes
    if not dp or b % env.dp_size != 0:
        return None
    return dp


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target."""
    if n <= target:
        return n
    for c in range(target, 0, -1):
        if n % c == 0:
            return c
    return n


def _mask(qpos, kpos, causal: bool, window: int):
    """(Sq, Sk) bool validity mask from global positions."""
    d = qpos[:, None] - kpos[None, :]
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= d >= 0
    if window > 0:
        m &= d < window
    return m


def _flash_update(acc, l, m, q, k, v, qpos, kpos, *, causal, window, kv_chunk):
    """Online-softmax update of (acc, l, m) with one KV block.

    q:   (B, Sq, KVH, G, hd)  — already scaled by 1/sqrt(hd)
    k,v: (B, Sk, KVH, hd)
    acc: (B, Sq, KVH, G, hd) f32;  l, m: (B, Sq, KVH, G) f32

    The body runs under ``named_scope("kernel_interior")``: on TPU this
    is the Pallas flash_attention kernel and its score/prob tensors
    never leave VMEM; the scope tag lets the HLO analyzer report the
    memory roofline with and without that traffic (§Roofline).
    """
    sk = k.shape[1]
    chunk = _pick_chunk(sk, kv_chunk)
    n_chunks = sk // chunk

    def body(carry, idx):
        acc, l, m = carry
        return _flash_block(acc, l, m, q, k, v, qpos, kpos, idx,
                            causal=causal, window=window, chunk=chunk), None

    if n_chunks == 1:
        (acc, l, m), _ = body((acc, l, m), 0)
    else:
        (acc, l, m), _ = jax.lax.scan(
            jax.checkpoint(body), (acc, l, m), jnp.arange(n_chunks)
        )
    return acc, l, m


def _flash_block(acc, l, m, q, k, v, qpos, kpos, idx, *, causal, window,
                 chunk):
    with jax.named_scope("kernel_interior"):
        k_c = jax.lax.dynamic_slice_in_dim(k, idx * chunk, chunk, axis=1)
        v_c = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, axis=1)
        kpos_c = jax.lax.dynamic_slice_in_dim(kpos, idx * chunk, chunk, axis=0)
        s = jnp.einsum(
            "bqkgd,bskd->bqkgs", q, k_c, preferred_element_type=jnp.float32
        )
        valid = _mask(qpos, kpos_c, causal, window)  # (Sq, chunk)
        s = jnp.where(valid[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(valid[None, :, None, None, :], p, 0.0)
        coef = jnp.exp(m - m_new)
        l = l * coef + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bqkgs,bskd->bqkgd", p.astype(v_c.dtype), v_c,
            preferred_element_type=jnp.float32,
        )
        acc = acc * coef[..., None] + pv
        return acc, l, m_new


def _group(q, n_kv: int):
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def _init_state(b, sq, kvh, g, hd):
    return (
        jnp.zeros((b, sq, kvh, g, hd), jnp.float32),
        jnp.zeros((b, sq, kvh, g), jnp.float32),
        jnp.full((b, sq, kvh, g), NEG_INF, jnp.float32),
    )


def _finish(acc, l, dtype):
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    b, sq, kvh, g, hd = out.shape
    return out.reshape(b, sq, kvh * g, hd).astype(dtype)


# ---------------------------------------------------------------------------
# local (single-device) flash attention — also the ref for the Pallas kernel
# ---------------------------------------------------------------------------

def flash_attention_local(q, k, v, qpos, kpos, *, causal=True, window=0,
                          kv_chunk=512):
    """q: (B,Sq,H,hd); k,v: (B,Sk,KVH,hd); positions are global indices."""
    kvh = k.shape[2]
    hd = q.shape[-1]
    qg = _group(q, kvh) * (hd ** -0.5)
    acc, l, m = _init_state(q.shape[0], q.shape[1], kvh, q.shape[2] // kvh, hd)
    acc, l, m = _flash_update(
        acc, l, m, qg, k, v, qpos, kpos,
        causal=causal, window=window, kv_chunk=kv_chunk,
    )
    return _finish(acc, l, q.dtype)


# ---------------------------------------------------------------------------
# ring attention (train / prefill), sequence sharded over env.tp_axis
# ---------------------------------------------------------------------------

def ring_attention(q, k, v, *, env: MeshEnv, causal=True, window=0,
                   base_offset=0, kv_chunk=512):
    """q: (B,S,H,hd); k,v: (B,S,KVH,hd). B sharded over dp, S over model."""
    tp = env.tp_axis
    n = env.tp_size
    dp = _dp_spec(env, q.shape[0])
    kvh = k.shape[2]
    hd = q.shape[-1]

    # windowed attention only needs ceil(window/chunk)+1 ring steps
    s_loc = q.shape[1] // n
    if window > 0:
        n_steps = min(n, -(-window // max(s_loc, 1)) + 1)
    else:
        n_steps = n

    def local(q_l, k_l, v_l):
        r = jax.lax.axis_index(tp) if n > 1 else 0
        sc = q_l.shape[1]
        sk = k_l.shape[1]          # cross attention: memory len != query len
        qpos = base_offset + r * sc + jnp.arange(sc)
        qg = _group(q_l, kvh) * (hd ** -0.5)
        state = _init_state(q_l.shape[0], sc, kvh, q_l.shape[2] // kvh, hd)
        perm = [(i, (i + 1) % n) for i in range(n)]

        # remat the flash update: without this the scan saves the per-step
        # softmax probabilities/masks (O(S_loc * S_loc) PER RING STEP) as
        # backward residuals — 2.5 GB/device/layer at 4k seq.  Recomputing
        # scores in the backward keeps residuals at the (k, v) blocks the
        # carry already stores.
        flash = jax.checkpoint(
            functools.partial(_flash_update, causal=causal, window=window,
                              kv_chunk=kv_chunk))

        def step(carry, s):
            (kb, vb), (acc, l, m) = carry
            blk = (r - s) % n
            kpos = base_offset + blk * sk + jnp.arange(sk)
            acc, l, m = flash(acc, l, m, qg, kb, vb, qpos, kpos)
            if n > 1:
                kb = jax.lax.ppermute(kb, tp, perm)
                vb = jax.lax.ppermute(vb, tp, perm)
            return ((kb, vb), (acc, l, m)), None

        if n_steps == 1:
            (_, (acc, l, m)), _ = step(((k_l, v_l), state), 0)
        else:
            (_, (acc, l, m)), _ = jax.lax.scan(
                step, ((k_l, v_l), state), jnp.arange(n_steps)
            )
        return _finish(acc, l, q_l.dtype)

    if tp is None:
        return local(q, k, v)

    spec = P(dp, tp, None, None)
    return jax.shard_map(
        local, mesh=env.mesh,
        in_specs=(spec, spec, spec),
        out_specs=spec,
        check_vma=False,
    )(q, k, v)


# ---------------------------------------------------------------------------
# rolling-window decode (local-attention layers; cache is tiny, replicated)
# ---------------------------------------------------------------------------

def window_decode_attention(q, k_cache, v_cache, kpos, k_new, v_new, pos, *,
                            window: int):
    """One-token decode against a rolling window cache (plain jnp).

    q: (B,1,H,hd); k/v_cache: (B,W,KVH,hd); kpos: (W,) int32 global
    positions of cached entries (-1 = empty).  Writes the new KV at slot
    ``pos % W`` and attends to entries with pos-window < kpos <= pos.
    Returns (out, k_cache', v_cache', kpos').
    """
    w = k_cache.shape[1]
    slot = pos % w
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, slot, axis=1)
    kpos = jax.lax.dynamic_update_slice_in_dim(
        kpos, jnp.full((1,), pos, kpos.dtype), slot, axis=0)
    kvh = k_cache.shape[2]
    hd = q.shape[-1]
    qg = _group(q, kvh) * (hd ** -0.5)                  # (B,1,KVH,G,hd)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    valid = (kpos >= 0) & (kpos <= pos) & (kpos > pos - window)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    b, sq, kv, g, d = out.shape
    return (out.reshape(b, sq, kv * g, d).astype(q.dtype),
            k_cache, v_cache, kpos)


# ---------------------------------------------------------------------------
# cross attention (bidirectional over provided memory; memory seq-sharded)
# ---------------------------------------------------------------------------

def cross_attention(q, k, v, *, env: MeshEnv, kv_chunk=512):
    """Decoder->encoder attention. q seq-sharded; kv seq-sharded; no mask.

    Implemented as a bidirectional ring over the memory.
    """
    return ring_attention(q, k, v, env=env, causal=False, window=0,
                          kv_chunk=kv_chunk)


# ---------------------------------------------------------------------------
# decode: split-K flash over a sequence-sharded KV cache
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, k_new, v_new, pos, *,
                     env: MeshEnv, window=0, update_cache=True,
                     kv_chunk=1024):
    """One-token decode against a seq-sharded cache.

    q:            (B, 1, H, hd)       replicated over model
    k/v_cache:    (B, S, KVH, hd)     S sharded over model
    k/v_new:      (B, 1, KVH, hd)     replicated over model
    pos:          ()  int32           position being written/attended
    Returns (out (B,1,H,hd), k_cache', v_cache').
    """
    tp = env.tp_axis
    n = env.tp_size
    dp = _dp_spec(env, q.shape[0])
    kvh = k_cache.shape[2]
    hd = q.shape[-1]

    def local(q_l, kc, vc, kn, vn, pos):
        r = jax.lax.axis_index(tp) if n > 1 else 0
        sc = kc.shape[1]
        start = r * sc
        if update_cache:
            idx = pos - start
            owned = (idx >= 0) & (idx < sc)
            safe = jnp.clip(idx, 0, sc - 1)
            kc_u = jax.lax.dynamic_update_slice_in_dim(kc, kn, safe, axis=1)
            vc_u = jax.lax.dynamic_update_slice_in_dim(vc, vn, safe, axis=1)
            kc = jnp.where(owned, kc_u, kc)
            vc = jnp.where(owned, vc_u, vc)
        kpos = start + jnp.arange(sc)
        qg = _group(q_l, kvh) * (hd ** -0.5)
        acc, l, m = _init_state(q_l.shape[0], 1, kvh, q_l.shape[2] // kvh, hd)
        # causal-by-position mask: kpos <= pos (and window)
        qpos = jnp.full((1,), pos, jnp.int32)
        acc, l, m = _flash_update(
            acc, l, m, qg, kc, vc, qpos, kpos,
            causal=True, window=window, kv_chunk=kv_chunk,
        )
        if n > 1:
            m_g = jax.lax.pmax(m, tp)
            coef = jnp.exp(m - m_g)
            l = jax.lax.psum(l * coef, tp)
            acc = jax.lax.psum(acc * coef[..., None], tp)
        out = _finish(acc, l, q_l.dtype)
        return out, kc, vc

    if tp is None:
        return local(q, k_cache, v_cache, k_new, v_new, pos)

    rep = P(dp, None, None, None)
    sharded = P(dp, tp, None, None)
    return jax.shard_map(
        local, mesh=env.mesh,
        in_specs=(rep, sharded, sharded, rep, rep, P()),
        out_specs=(rep, sharded, sharded),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, pos)
