"""Shared layer primitives: norms, RoPE, projections, MLPs, init."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def rmsnorm(x, scale, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, bias, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_apply(cfg: ArchConfig, x, p):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def norm_init(cfg: ArchConfig, d: int):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(hd: int, theta: float):
    return theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (S,) or (..., S) global token positions."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd//2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd//2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle[:, : (d + 1) // 2]))
    return pe


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(cfg: ArchConfig, key, d: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff),
        "w_up": dense_init(k2, d, d_ff),
        "w_down": dense_init(k3, d_ff, d),
    }


def mlp_apply(cfg: ArchConfig, p, x):
    dt = x.dtype
    act = act_fn(cfg.act)
    h = act(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    return h @ p["w_down"].astype(dt)
