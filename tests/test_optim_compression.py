"""Optimizers + gradient-compression codecs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (
    CompressionConfig,
    int8_decode,
    int8_encode,
    topk_sparsify,
)
from repro.train.optim import OptimizerConfig, build_optimizer


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_minimizes_quadratic(name):
    cfg = OptimizerConfig(name=name, lr=0.15, warmup_steps=1,
                          weight_decay=0.0, factored_min_dim=4)
    init, update = build_optimizer(cfg)
    params = {"w": jnp.full((8, 8), 5.0), "b": jnp.full((8,), -3.0)}
    state = init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    step = jnp.zeros((), jnp.int32)
    for i in range(80):
        grads = jax.grad(loss)(params)
        params, state, gnorm = update(grads, state, params, step + i)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_factored():
    cfg = OptimizerConfig(name="adafactor", factored_min_dim=8)
    init, _ = build_optimizer(cfg)
    params = {"big": jnp.zeros((16, 32)), "small": jnp.zeros((4,))}
    st = init(params)
    assert len(st["s"]["big"]) == 2          # (vr, vc)
    assert st["s"]["big"][0].shape == (16,)
    assert st["s"]["big"][1].shape == (32,)
    assert len(st["s"]["small"]) == 1        # full v


def test_int8_codec_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    q, scale = int8_encode(x)
    y = int8_decode(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.abs(x - y).max()) <= float(scale) * 0.5 + 1e-7


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0], jnp.float32)
    s = topk_sparsify(x, 2 / 6)
    nz = np.nonzero(np.asarray(s))[0]
    assert set(nz) == {1, 3}


@pytest.mark.parametrize("codec", ["int8", "topk"])
def test_error_feedback_converges(codec):
    """With error feedback, the accumulated compressed sum tracks the true
    gradient sum (the residual stays bounded)."""
    from repro.distributed.compression import compressed_psum
    cfg = CompressionConfig(codec=codec, topk_frac=0.25)
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    residual = jnp.zeros_like(g_true)
    total_sent = jnp.zeros_like(g_true)
    # single-device "mesh": psum over no axis == identity
    import jax
    mesh = jax.make_mesh((1,), ("d",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def one(residual):
        def body(g, r):
            return compressed_psum(g, r, "d", cfg)
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(),) * 2,
            out_specs=(jax.sharding.PartitionSpec(),) * 2,
            check_vma=False)(g_true, residual)

    for _ in range(20):
        sent, residual = one(residual)
        total_sent = total_sent + sent
    # after T steps: sum(sent) ≈ T*g_true with bounded residual
    err = float(jnp.abs(total_sent / 20 - g_true).max())
    scale = float(jnp.abs(g_true).max())
    assert err < 0.15 * scale, err
