"""Vocab-sharded merge parity: ShardedDeviceBackend vs single device.

The trivial one-device mesh runs in-process; real multi-device runs
fork a subprocess with ``--xla_force_host_platform_device_count=8``
(the main pytest process must keep the single real CPU device) and
``MLEGO_KERNEL_INTERPRET=1`` so the shard_map-launched Pallas bodies
execute on CPU.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api.backend import HostBackend, ShardedDeviceBackend
from repro.configs.lda_default import LDAConfig
from repro.core.lda import MaterializedModel
from repro.core.plans import Interval
from repro.distributed.sharding import local_mesh_env

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = LDAConfig(n_topics=6, vocab_size=150, alpha=0.5, eta=0.05,
                max_iters=6, e_step_iters=5, gibbs_sweeps=6)
RNG = np.random.default_rng(23)


def run_sub(body: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["MLEGO_KERNEL_INTERPRET"] = "1"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def _models(n, kind, k=CFG.n_topics, v=CFG.vocab_size, seed=0):
    rng = np.random.default_rng(seed)
    key = "lam" if kind == "vb" else "delta_nkv"
    return [MaterializedModel(
        i, Interval(float(i), float(i) + 1.0), 10, 100, kind,
        {key: rng.gamma(1.0, 1.0, (k, v)).astype(np.float32)})
        for i in range(n)]


# ---------------------------------------------------------------------------
# trivial one-device mesh (in-process): sharded semantics degrade cleanly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["vb", "gs"])
def test_single_device_mesh_matches_host(kind):
    env = local_mesh_env(max_devices=1)
    sharded = ShardedDeviceBackend(interpret=True, env=env)
    host = HostBackend()
    ms = _models(4, kind)
    np.testing.assert_allclose(
        sharded.merge(ms, kind, CFG), host.merge(ms, kind, CFG),
        rtol=1e-5, atol=1e-5)
    assert sharded.shards == 1


@pytest.mark.parametrize("kind", ["vb", "gs"])
def test_single_device_mesh_merge_many_matches_host(kind):
    env = local_mesh_env(max_devices=1)
    sharded = ShardedDeviceBackend(interpret=True, env=env)
    host = HostBackend()
    ms = _models(6, kind)
    batches = [ms[:1], ms[1:4], ms[4:]]       # ragged widths 1/3/2
    got = sharded.merge_many(batches, kind, CFG)
    want = host.merge_many(batches, kind, CFG)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)
    assert sharded.stats.pad_rows == 0
    assert sharded.stats.device_launches == 1


# ---------------------------------------------------------------------------
# 8-device mesh (subprocess): parity + over-budget model stacks
# ---------------------------------------------------------------------------

SUB_COMMON = """
import numpy as np
from repro.api.backend import DeviceBackend, HostBackend, ShardedDeviceBackend
from repro.configs.lda_default import LDAConfig
from repro.core.lda import MaterializedModel
from repro.core.plans import Interval

CFG = LDAConfig(n_topics=6, vocab_size=150, alpha=0.5, eta=0.05)

def models(n, kind, k=6, v=150, seed=0):
    rng = np.random.default_rng(seed)
    key = "lam" if kind == "vb" else "delta_nkv"
    return [MaterializedModel(
        i, Interval(float(i), float(i) + 1.0), 10, 100, kind,
        {key: rng.gamma(1.0, 1.0, (k, v)).astype(np.float32)})
        for i in range(n)]
"""


def test_sharded_merge_matches_single_device_8dev():
    run_sub(SUB_COMMON + """
for kind in ("vb", "gs"):
    sharded = ShardedDeviceBackend()
    assert sharded.shards == 8, sharded.shards
    host = HostBackend()
    ms = models(5, kind)
    np.testing.assert_allclose(
        sharded.merge(ms, kind, CFG), host.merge(ms, kind, CFG),
        rtol=1e-5, atol=1e-5)
print("sharded merge OK")
""")


def test_sharded_ragged_batch_matches_single_device_8dev():
    run_sub(SUB_COMMON + """
for kind in ("vb", "gs"):
    sharded = ShardedDeviceBackend()
    host = HostBackend()
    ms = models(8, kind)
    batches = [ms[:1], ms[1:2], ms[2:7], ms[7:]]   # widths 1/1/5/1
    got = sharded.merge_many(batches, kind, CFG)
    want = host.merge_many(batches, kind, CFG)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)
    assert sharded.stats.pad_rows == 0
    assert sharded.stats.device_launches == 1
print("sharded ragged OK")
""")


def test_sharded_cache_holds_stack_over_single_device_budget():
    run_sub(SUB_COMMON + """
# Budget sized so ONE model already busts it unsharded (6 x 1000 f32
# = 24000 B > 20000) but each device's 1/8 vocab slice set fits
# (6 x 3072 B = 18432): the sharded cache keeps the whole stack
# resident while the single-device cache can't hold even one model.
kind, n, max_bytes = "vb", 6, 20_000
ms = models(n, kind, v=1000)
host = HostBackend()
want = host.merge(ms, kind, CFG)

sharded = ShardedDeviceBackend(max_bytes=max_bytes)
got = sharded.merge(ms, kind, CFG)
np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
assert sum(m.theta["lam"].nbytes for m in ms) > max_bytes
assert len(sharded.cache) == n, (len(sharded.cache), n)
assert sharded.cache.evictions == 0
assert sharded.cache.resident_bytes <= max_bytes

single = DeviceBackend(max_bytes=max_bytes)
got1 = single.merge(ms, kind, CFG)
np.testing.assert_allclose(got1, want, rtol=1e-5, atol=1e-5)
assert single.cache.evictions > 0 or len(single.cache) < n
print("budget OK")
""")
