"""Plan IR: lowering correctness, provider pricing equivalence, and the
frozen-fixture parity regression — the IR-producing searchers and
batch optimizer must select exactly the model sets the pre-refactor
tuple path selected under the analytic cost provider
(tests/fixtures/plan_parity.json was generated at that commit)."""
import json
import os

import numpy as np
import pytest

from repro.core.batch_opt import batch_optimize
from repro.core.cost import CostModel, plan_stats
from repro.core.plan_ir import (
    FetchStep,
    MergeStep,
    Plan,
    TrainGapStep,
    pad_rows_bucketed,
    pad_rows_widest,
    size_buckets,
)
from repro.core.plans import Interval
from repro.core.search import SEARCHERS
from repro.data.corpus import DataIndex, make_corpus
from tests.conftest import build_store

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "plan_parity.json")


@pytest.fixture(scope="module")
def world():
    corpus, _ = make_corpus(300, 64, 4, mean_doc_len=12, seed=11)
    index = DataIndex(corpus)
    cost = CostModel(max_iters=10, n_topics=4)
    return index, cost


# ---------------------------------------------------------------------------
# frozen parity: IR path == pre-refactor tuple path
# ---------------------------------------------------------------------------

def _fixture():
    with open(FIXTURE) as f:
        return json.load(f)


def test_searchers_match_frozen_tuple_path(world):
    index, cost = world
    q = Interval(10.0, 280.0)
    stores = {}
    for case in _fixture()["search"]:
        key = (case["seed"], case["n_models"])
        if key not in stores:
            stores[key] = build_store(index, n_models=case["n_models"],
                                      seed=case["seed"], span=(0.0, 300.0),
                                      k=4, v=64)
        models = stores[key].models()
        r = SEARCHERS[case["method"]](models, q, index, cost, case["alpha"])
        assert list(r.model_ids) == case["model_ids"], case
        assert r.score == pytest.approx(case["score"], rel=1e-9)
        # the IR is the same plan, lowered
        assert r.ir is not None
        assert list(r.ir.model_ids) == case["model_ids"]


def test_batch_optimize_matches_frozen_tuple_path(world):
    index, cost = world
    for case in _fixture()["batch"]:
        store = build_store(index, n_models=6, seed=case["seed"],
                            span=(0.0, 300.0), k=4, v=64)
        queries = [Interval(lo, hi) for lo, hi in case["queries"]]
        b = batch_optimize(store.models(), queries, index, cost)
        got = [sorted(m.model_id for m in p) for p in b.plans]
        assert got == case["model_ids"], case
        assert b.total_time == pytest.approx(case["total_time"], rel=1e-9)
        assert [list(ir.model_ids) for ir in b.irs] == case["model_ids"]


# ---------------------------------------------------------------------------
# lowering: step structure mirrors the model set + index
# ---------------------------------------------------------------------------

def test_from_models_structure(world):
    index, _ = world
    store = build_store(index, n_models=6, seed=1, span=(0.0, 300.0),
                        k=4, v=64)
    models = sorted(store.models(), key=lambda m: m.o.lo)[:2]
    # force disjointness for a well-formed plan
    if models[0].o.overlaps(models[1].o):
        models = models[:1]
    sigma = Interval(0.0, 300.0)
    plan = Plan.from_models(models, sigma, index)

    assert len(plan.fetches) == len(models)
    assert plan.model_ids == tuple(sorted(m.model_id for m in models))
    # gaps tile sigma minus the fetched ranges
    fetched = sum(f.o.length for f in plan.fetches)
    gapped = sum(g.gap.length for g in plan.gaps)
    assert fetched + gapped == pytest.approx(sigma.length)
    # tokens agree with plan_stats (what the analytic provider prices)
    n, unc = plan_stats(tuple(models), sigma, index)
    assert plan.n_models == n
    assert plan.uncovered_tokens == pytest.approx(unc)
    # exactly one merge step, last
    assert isinstance(plan.steps[-1], MergeStep)
    assert sum(1 for s in plan.steps if isinstance(s, MergeStep)) == 1
    assert plan.n_parts == len(models) + sum(
        1 for g in plan.gaps if g.n_tokens > 0)


def test_empty_plan_is_single_train(world):
    index, _ = world
    sigma = Interval(0.0, 100.0)
    plan = Plan.from_models((), sigma, index)
    assert plan.fetches == ()
    assert len(plan.gaps) == 1
    assert plan.gaps[0].gap == sigma
    assert plan.n_parts == 1


def test_plan_key_is_value_identity(world):
    index, _ = world
    store = build_store(index, n_models=5, seed=2, span=(0.0, 300.0),
                        k=4, v=64)
    sigma = Interval(0.0, 300.0)
    models = tuple(store.models()[:1])
    a = Plan.from_models(models, sigma, index)
    b = Plan.from_models(models, sigma, index)
    assert a == b and a.key() == b.key() and hash(a) == hash(b)
    c = Plan.from_models((), sigma, index)
    assert c.key() != a.key()


# ---------------------------------------------------------------------------
# provider pricing: price_plan(ir) == score_models(tuple) == legacy score
# ---------------------------------------------------------------------------

def test_price_plan_equals_score_models(world):
    index, cost = world
    store = build_store(index, n_models=8, seed=3, span=(0.0, 300.0),
                        k=4, v=64)
    q = Interval(10.0, 280.0)
    scratch = float(index.tokens_in(q.lo, q.hi))
    for alpha in (0.0, 0.4, 1.0):
        r = SEARCHERS["psoa++"](store.models(), q, index, cost, alpha)
        via_models = cost.score_models(r.plan, q, index, alpha, scratch)
        via_ir = cost.price_plan(r.ir, alpha, scratch)
        n, unc = plan_stats(r.plan, q, index)
        legacy = cost.score(alpha, n, unc, scratch)
        assert via_models == pytest.approx(legacy, rel=1e-12)
        assert via_ir == pytest.approx(legacy, rel=1e-12)
        assert r.score == pytest.approx(legacy, rel=1e-12)


# ---------------------------------------------------------------------------
# batched-launch bucketing math (§V.C)
# ---------------------------------------------------------------------------

def test_bucketed_padding_never_exceeds_widest():
    rng = np.random.default_rng(0)
    for _ in range(200):
        counts = rng.integers(1, 33, size=rng.integers(1, 12)).tolist()
        assert pad_rows_bucketed(counts) <= pad_rows_widest(counts)


def test_bucket_grouping_pow2():
    buckets = size_buckets([1, 2, 3, 4, 5, 9, 16, 17])
    assert buckets == {1: [0], 2: [1], 4: [2, 3], 8: [4], 16: [5, 6],
                       32: [7]}
    # uniform batch: single bucket, zero padding
    assert pad_rows_bucketed([3, 3, 3]) == 0
    assert pad_rows_widest([3, 3, 3]) == 0
    # ragged: one wide plan no longer drags every row to 16
    assert pad_rows_widest([1, 1, 1, 16]) == 45
    assert pad_rows_bucketed([1, 1, 1, 16]) == 0
