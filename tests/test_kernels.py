"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# vb_estep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,v,k", [(32, 128, 16), (65, 200, 100),
                                   (128, 384, 128), (8, 64, 10),
                                   # D > block_d and not a block multiple:
                                   # regression for the ragged boundary
                                   # block reading garbage into sstats
                                   (135, 150, 6), (300, 192, 12)])
def test_vb_estep_kernel(d, v, k):
    from repro.kernels.vb_estep.ops import vb_estep
    from repro.kernels.vb_estep.ref import vb_estep_ref
    x = jnp.asarray(RNG.poisson(0.5, (d, v)), jnp.float32)
    eeb = jnp.asarray(RNG.gamma(1.0, 1.0, (k, v)), jnp.float32)
    eeb = eeb / eeb.sum(1, keepdims=True)
    g0 = jnp.ones((d, k), jnp.float32)
    g1, s1 = vb_estep(x, eeb, g0, 0.5, 8, interpret=True)
    g2, s2 = vb_estep_ref(x, eeb, g0, 0.5, 8)
    np.testing.assert_allclose(g1, g2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# merge_topics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,v", [(1, 16, 64), (5, 100, 300), (12, 128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_merge_topics_kernel(n, k, v, dtype):
    from repro.kernels.merge_topics.ops import merge_topics
    from repro.kernels.merge_topics.ref import merge_topics_ref
    st = jnp.asarray(RNG.normal(size=(n, k, v)), dtype)
    w = jnp.asarray(RNG.uniform(0.2, 2.0, n), jnp.float32)
    out = merge_topics(st, w, bias=0.05, base=0.05, interpret=True)
    ref = merge_topics_ref(st, w, 0.05, 0.05)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,n,k,v", [(1, 3, 16, 64), (4, 5, 100, 300),
                                     (3, 1, 24, 128)])
def test_merge_topics_batched_kernel(b, n, k, v):
    """One launch merging b independent plans, incl. zero-weight pad
    rows (how ragged submit_many batches share a launch)."""
    from repro.kernels.merge_topics.ops import merge_topics_batch
    from repro.kernels.merge_topics.ref import merge_topics_batched_ref
    st = jnp.asarray(RNG.normal(size=(b, n, k, v)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.2, 2.0, (b, n)), jnp.float32)
    if n > 1:
        w = w.at[0, -1:].set(0.0)        # simulate a ragged batch pad
    out = merge_topics_batch(st, w, bias=0.05, base=0.05, interpret=True)
    ref = merge_topics_batched_ref(st, w, 0.05, 0.05)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kvh,hd", [
    (1, 128, 4, 4, 32),    # MHA
    (2, 128, 8, 2, 64),    # GQA 4:1
    (1, 256, 5, 1, 64),    # MQA, odd heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(b, s, h, kvh, hd, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, s, kvh, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, s, kvh, hd)), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_windowed():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    q = jnp.asarray(RNG.normal(size=(1, 192, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 192, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 192, 2, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=50, block_q=64,
                          block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=50)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kvh,hd,pos", [
    (2, 256, 4, 2, 64, 0),       # first token
    (2, 256, 4, 2, 64, 255),     # full cache
    (1, 384, 6, 1, 32, 100),     # MQA mid-stream
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_kernel(b, s, h, kvh, hd, pos, dtype):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    q = jnp.asarray(RNG.normal(size=(b, 1, h, hd)), dtype)
    kc = jnp.asarray(RNG.normal(size=(b, s, kvh, hd)), dtype)
    vc = jnp.asarray(RNG.normal(size=(b, s, kvh, hd)), dtype)
    out = decode_attention(q, kc, vc, pos, block_k=128, interpret=True)
    ref = decode_attention_ref(q, kc, vc, pos)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_decode_attention_windowed():
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    q = jnp.asarray(RNG.normal(size=(1, 1, 4, 32)), jnp.float32)
    kc = jnp.asarray(RNG.normal(size=(1, 512, 2, 32)), jnp.float32)
    vc = jnp.asarray(RNG.normal(size=(1, 512, 2, 32)), jnp.float32)
    out = decode_attention(q, kc, vc, 300, window=64, block_k=128,
                           interpret=True)
    ref = decode_attention_ref(q, kc, vc, 300, window=64)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_kernel_split_k_matches_device_split():
    """Core-level split-K (kernel) == device-level split (attention.py
    decode path run unsharded) — the two splits compose."""
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.models.attention import flash_attention_local
    q = jnp.asarray(RNG.normal(size=(2, 1, 4, 32)), jnp.float32)
    kc = jnp.asarray(RNG.normal(size=(2, 128, 2, 32)), jnp.float32)
    vc = jnp.asarray(RNG.normal(size=(2, 128, 2, 32)), jnp.float32)
    pos = 90
    a = decode_attention(q, kc, vc, pos, block_k=32, interpret=True)
    qpos = jnp.full((1,), pos, jnp.int32)
    kpos = jnp.arange(128)
    b = flash_attention_local(q, kc, vc, qpos, kpos, causal=True)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# sLSTM scan (VMEM-resident recurrence)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,b,h,hd,chunk", [
    (32, 2, 2, 16, 8),     # multi-chunk
    (64, 4, 4, 32, 64),    # single chunk
    (48, 1, 3, 8, 16),     # odd head count, B=1
])
def test_slstm_scan_kernel(s, b, h, hd, chunk):
    from repro.kernels.slstm_scan.ops import slstm_scan
    from repro.kernels.slstm_scan.ref import slstm_scan_ref
    xpre = jnp.asarray(RNG.normal(size=(s, b, 4, h, hd)), jnp.float32) * 0.5
    r = jnp.asarray(RNG.normal(size=(h, hd, 4 * hd)), jnp.float32) * (hd ** -0.5)
    out = slstm_scan(xpre, r, chunk=chunk, interpret=True)
    z = jnp.zeros((b, h, hd), jnp.float32)
    ref, _ = slstm_scan_ref(xpre, r, z, z, z,
                            jnp.full((b, h, hd), -1e30, jnp.float32))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_slstm_scan_matches_model_layer():
    """Kernel == the recurrent.py sLSTM scan used by the xlstm arch."""
    from repro.kernels.slstm_scan.ops import slstm_scan
    from repro.models.recurrent import _slstm_local_scan
    s, b, h, hd = 24, 2, 2, 8
    xpre_bshd = jnp.asarray(RNG.normal(size=(b, s, 4, h, hd)),
                            jnp.float32) * 0.5
    r = jnp.asarray(RNG.normal(size=(h, hd, 4 * hd)), jnp.float32) * 0.3
    z = jnp.zeros((b, h, hd), jnp.float32)
    ref, _ = _slstm_local_scan(xpre_bshd, r,
                               (z, z, z, jnp.full((b, h, hd), -1e30)))
    out = slstm_scan(xpre_bshd.transpose(1, 0, 2, 3, 4), r, chunk=8,
                     interpret=True)
    np.testing.assert_allclose(out.transpose(1, 0, 2, 3), ref,
                               rtol=1e-5, atol=1e-5)
