"""The trip-count-aware HLO analyzer — validated against XLA's own
cost_analysis on loop-free graphs and against hand counts on scans."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analyzer import analyze_hlo, parse_computations


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


X = jax.ShapeDtypeStruct((64, 128), jnp.float32)
W = jax.ShapeDtypeStruct((128, 128), jnp.float32)


def test_loop_free_matches_xla():
    def f(x, w):
        return jnp.tanh(x @ w) @ w

    comp = _compile(f, X, W)
    st = analyze_hlo(comp.as_text(), 1)
    assert st.flops == pytest.approx(comp.cost_analysis()["flops"], rel=1e-6)


def test_scan_multiplies_by_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=9)
        return y

    comp = _compile(f, X, W)
    st = analyze_hlo(comp.as_text(), 1)
    assert st.flops == pytest.approx(2 * 64 * 128 * 128 * 9, rel=1e-6)
    # XLA undercounts — that's the whole reason this module exists
    assert comp.cost_analysis()["flops"] < st.flops


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(d, _):
                return d @ w, None
            d, _ = jax.lax.scan(inner, c, None, length=4)
            return d, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    comp = _compile(f, X, W)
    st = analyze_hlo(comp.as_text(), 1)
    assert st.flops == pytest.approx(2 * 64 * 128 * 128 * 20, rel=1e-6)


def test_grad_counts_forward_and_backward():
    def f(x, w):
        return jnp.sum((x @ w) ** 2)

    def g(x, w):
        return jax.grad(f, argnums=1)(x, w)

    comp = _compile(g, X, W)
    st = analyze_hlo(comp.as_text(), 1)
    fwd = 2 * 64 * 128 * 128
    # fwd dot + dL/dw dot (and possibly dL/dx) => at least 2x fwd
    assert st.flops >= 2 * fwd


def test_parse_computations_roundtrip():
    def f(x, w):
        return x @ w

    comp = _compile(f, X, W)
    comps, entry = parse_computations(comp.as_text())
    assert entry is not None
    assert entry in comps
    kinds = {op.kind for op in comps[entry].ops}
    assert "dot" in kinds or "fusion" in kinds
