"""Streaming ingestion subsystem: store lifecycle (append/compact/
evict notification ordering, atomic replace), compaction determinism /
quality parity / budget enforcement, the ingest pipeline end-to-end
through a session and the serving layer, speculative gap pre-training,
and the serve-layer satellites (shared named backends, per-tenant RNG
in coalesced groups, calibration sidecar locking)."""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.api import DeviceBackend, Interval, MLegoSession, QuerySpec
from repro.configs.lda_default import LDAConfig
from repro.core.cost import Calibration, CostModel
from repro.core.store import ModelStore
from repro.data.corpus import concat_corpora, make_corpus
from repro.ingest import (
    CompactionPolicy,
    Compactor,
    IngestPipeline,
)
from repro.serve import MLegoService

CFG = LDAConfig(n_topics=6, vocab_size=150, alpha=0.5, eta=0.05,
                max_iters=8, e_step_iters=5, gibbs_sweeps=6)

BASE_HI = 100.0      # base corpora end at this attr; streams start here


def _corpus(n_docs=200, seed=3, attr_max=BASE_HI):
    corpus, _ = make_corpus(n_docs, CFG.vocab_size, CFG.n_topics,
                            mean_doc_len=30, attr_max=attr_max, seed=seed)
    return corpus


def _stream(n_docs=120, seed=7, lo=BASE_HI, width=50.0):
    """A batch of *newer* documents with attr in [lo, lo + width)."""
    c = _corpus(n_docs=n_docs, seed=seed, attr_max=width)
    return dataclasses.replace(c, attr=c.attr + lo)


def _slice_model(store, lo, hi, seed=None, k=None, v=None):
    k = k if k is not None else CFG.n_topics
    v = v if v is not None else CFG.vocab_size
    rng = np.random.default_rng(int(seed if seed is not None else lo))
    return store.add(Interval(lo, hi), 10, 100, "vb",
                     {"lam": rng.random((k, v)).astype(np.float32) + 0.1})


# ---------------------------------------------------------------------------
# corpus growth
# ---------------------------------------------------------------------------

def test_concat_corpora_appends():
    a, b = _corpus(n_docs=40, seed=0), _stream(n_docs=30, seed=1)
    c = concat_corpora(a, b)
    assert c.n_docs == a.n_docs + b.n_docs
    assert c.n_tokens == a.n_tokens + b.n_tokens
    assert np.all(np.diff(c.attr) >= 0), "attr order must survive concat"
    np.testing.assert_array_equal(c.doc_offsets[: a.n_docs + 1],
                                  a.doc_offsets)
    # a subset straddling the seam selects docs from both halves
    seam = c.subset(float(a.attr[-1]) - 1.0, float(b.attr[0]) + 1.0)
    assert seam.n_docs >= 2
    assert int(c.doc_offsets[-1]) == len(c.tokens)


def test_concat_corpora_rejects_out_of_order():
    a = _corpus(n_docs=40, seed=0)
    stale = _corpus(n_docs=10, seed=1)          # attrs overlap a's range
    with pytest.raises(ValueError, match="append-only"):
        concat_corpora(a, stale)


# ---------------------------------------------------------------------------
# store lifecycle: replace + notification ordering
# ---------------------------------------------------------------------------

def test_store_replace_is_atomic_and_orders_events():
    store = ModelStore()
    fines = [_slice_model(store, 25.0 * i, 25.0 * (i + 1))
             for i in range(4)]
    events = []
    store.subscribe(lambda ev, mid: events.append((ev, mid)))
    coarse = store.replace([m.model_id for m in fines],
                           Interval(0.0, 100.0), 40, 400, "vb",
                           {"lam": fines[0].theta["lam"]})
    # coarse "add" lands before any fine "remove" — a listener never
    # observes the range uncovered
    assert events[0] == ("add", coarse.model_id)
    assert sorted(events[1:]) == sorted(
        ("remove", m.model_id) for m in fines)
    assert len(store) == 1
    assert store.get(coarse.model_id).o == Interval(0.0, 100.0)
    # unknown ids refuse atomically (store untouched)
    with pytest.raises(KeyError):
        store.replace([coarse.model_id, 999], Interval(0.0, 100.0),
                      1, 1, "vb", {"lam": fines[0].theta["lam"]})
    assert len(store) == 1


def test_store_lifecycle_event_sequence_append_compact_evict():
    """The full streaming lifecycle over one subscribe channel, in
    order: appends, then a compaction swap, then an eviction."""
    store = ModelStore()
    events = []
    store.subscribe(lambda ev, mid: events.append((ev, mid)))
    fines = [_slice_model(store, 25.0 * i, 25.0 * (i + 1))
             for i in range(2)]
    per_model = fines[0].nbytes()
    comp = Compactor(store, CFG, CompactionPolicy(
        max_bytes=0, merge_width=2, min_retained=0), kind="vb")
    rep = comp.run()
    assert rep.compacted == (tuple(m.model_id for m in fines),)
    assert len(rep.evicted) == 1, \
        "budget 0 must evict the coarse segment too"
    adds = [(ev, mid) for ev, mid in events if ev == "add"]
    assert [e for e, _ in events[:2]] == ["add", "add"]   # appends
    coarse_id = rep.compacted_into[0]
    assert events[2:] == [("add", coarse_id)] \
        + [("remove", m.model_id) for m in fines] \
        + [("remove", coarse_id)]
    assert len(adds) == 3
    assert store.nbytes() == 0
    assert per_model > 0


# ---------------------------------------------------------------------------
# compaction
# ---------------------------------------------------------------------------

def test_compaction_deterministic_for_fixed_slice_set():
    def build():
        s = ModelStore()
        for i in range(6):
            _slice_model(s, 25.0 * i, 25.0 * (i + 1), seed=i)
        return s

    reports = []
    for _ in range(2):
        store = build()
        per = store.models()[0].nbytes()
        comp = Compactor(store, CFG, CompactionPolicy(
            max_bytes=3 * per, merge_width=4, min_retained=1), kind="vb")
        reports.append(comp.run())
    a, b = reports
    assert a.compacted == b.compacted
    assert a.compacted_into == b.compacted_into
    assert a.evicted == b.evicted
    assert a.bytes_after == b.bytes_after <= 3 * build().models()[0].nbytes()


def test_compaction_quality_parity_through_query_path():
    """Post-compaction queries over the compacted range must compute
    the same β — the merge is an exact natural-parameter addition, so
    pre-merging slices changes only float association order."""
    corpus = _corpus()
    store = ModelStore()
    sess = MLegoSession(corpus, CFG, store=store, seed=0)
    for i in range(4):
        sess.train_range(25.0 * i, 25.0 * (i + 1))
    spec = QuerySpec(sigma=Interval(0.0, BASE_HI), alpha=1.0)
    before = sess.submit(spec)
    assert before.n_reused == 4

    per = store.models()[0].nbytes()
    comp = Compactor(store, CFG, CompactionPolicy(
        max_bytes=2 * per, merge_width=4, min_retained=0), kind="vb")
    rep = comp.run()
    assert len(rep.compacted) == 1 and not rep.evicted
    after = sess.submit(spec)
    assert after.n_reused == 1, "query now fetches the coarse segment"
    np.testing.assert_allclose(after.beta, before.beta,
                               rtol=1e-5, atol=1e-7)


def test_compaction_evicts_coldest_first():
    store = ModelStore()
    # non-contiguous slices: no run to merge, eviction is the only move
    ms = [_slice_model(store, 100.0 * i, 100.0 * i + 25.0, seed=i)
          for i in range(3)]
    store.get(ms[0].model_id)       # ms[0] is hot; ms[1]/ms[2] cold
    per = ms[0].nbytes()
    comp = Compactor(store, CFG, CompactionPolicy(
        max_bytes=per, merge_width=4, min_retained=0), kind="vb")
    rep = comp.run()
    assert not rep.compacted
    assert rep.evicted == (ms[1].model_id, ms[2].model_id), \
        "cold capital (never fetched, oldest range first) evicts first"
    assert store.nbytes() <= per


def test_compaction_invalidates_plan_cache_and_device_lru():
    corpus = _corpus()
    store = ModelStore()
    backend = DeviceBackend()
    sess = MLegoSession(corpus, CFG, store=store, backend=backend, seed=0)
    fine_ids = []
    for i in range(4):
        m = sess.train_range(25.0 * i, 25.0 * (i + 1))
        fine_ids.append(m.model_id)
    sess.submit(QuerySpec(sigma=Interval(0.0, BASE_HI), alpha=1.0))
    assert len(sess.plan_cache) > 0
    assert all(mid in backend.cache for mid in fine_ids)

    comp = Compactor(store, CFG, CompactionPolicy(
        max_bytes=2 * store.models()[0].nbytes(), merge_width=4,
        min_retained=0), kind="vb")
    rep = comp.run()
    assert len(rep.compacted) == 1
    assert len(sess.plan_cache) == 0, \
        "compaction must drop cached plans through the subscribe channel"
    assert all(mid not in backend.cache for mid in fine_ids), \
        "compacted fine slices must leave the device LRU"
    # the next query re-plans onto the coarse segment and still answers
    rep2 = sess.submit(QuerySpec(sigma=Interval(0.0, BASE_HI), alpha=1.0))
    assert rep2.model_ids == rep.compacted_into


# ---------------------------------------------------------------------------
# ingest pipeline
# ---------------------------------------------------------------------------

def test_pipeline_builds_slices_and_session_answers_fresh_range():
    corpus = _corpus()
    store = ModelStore()
    sess = MLegoSession(corpus, CFG, store=store, seed=0)
    events = []
    store.subscribe(lambda ev, mid: events.append((ev, mid)))

    pipe = IngestPipeline(corpus, store, CFG, slice_width=25.0,
                          kind="vb", on_corpus=sess.extend_corpus)
    assert pipe.frontier == BASE_HI     # base ends on the grid

    # the fresh range is unanswerable before ingest (no docs, no models)
    with pytest.raises(ValueError, match="selects no data"):
        sess.submit(QuerySpec(sigma=Interval(BASE_HI, BASE_HI + 25.0)))

    pipe.append(_stream(width=50.0))    # attrs in [100, 150)
    assert pipe.flush(timeout=30.0)
    r = pipe.report()
    assert r.batches == 1 and r.slices_built == 1, \
        "[100,125) closed (frontier passed 125); [125,150) still open"
    built = store.models("vb")
    assert [(m.o.lo, m.o.hi) for m in built] == [(100.0, 125.0)]
    assert ("add", built[0].model_id) in events

    # acceptance (a): the query over the ingested slice is answered
    # with no manual store mutation, riding the slice model
    rep = sess.submit(QuerySpec(sigma=Interval(BASE_HI, BASE_HI + 25.0)))
    assert rep.model_ids == (built[0].model_id,)
    assert rep.n_trained_tokens == 0

    # close() builds the open partial slice [125, 150)
    pipe.close()
    spans = sorted((m.o.lo, m.o.hi) for m in store.models("vb"))
    assert spans == [(100.0, 125.0), (125.0, 150.0)]
    assert pipe.report().freshness_lag_s_mean > 0.0


def test_pipeline_rejects_batches_behind_frontier():
    corpus = _corpus()
    pipe = IngestPipeline(corpus, ModelStore(), CFG, slice_width=25.0)
    with pytest.raises(ValueError, match="append-only"):
        pipe.append(_corpus(n_docs=10, seed=9))   # attrs inside the base
    pipe.append(_stream(n_docs=40, seed=8, width=30.0))
    with pytest.raises(ValueError, match="append-only"):
        pipe.append(_stream(n_docs=10, seed=9, width=10.0))  # behind now
    pipe.close()
    with pytest.raises(RuntimeError, match="closed"):
        pipe.append(_stream(n_docs=5, seed=10, lo=200.0))


def test_pipeline_drives_compaction_under_budget():
    corpus = _corpus()
    store = ModelStore()
    per = CFG.n_topics * CFG.vocab_size * 4
    comp = Compactor(store, CFG, CompactionPolicy(
        max_bytes=2 * per, merge_width=2, min_retained=1), kind="vb")
    pipe = IngestPipeline(corpus, store, CFG, slice_width=10.0,
                          kind="vb", compactor=comp)
    pipe.append(_stream(n_docs=160, seed=5, width=50.0))  # 5 slices
    pipe.close()
    r = pipe.report()
    assert r.slices_built == 5
    assert r.compactions > 0
    # acceptance (c): capital stays under the configured byte budget
    assert store.nbytes() <= 2 * per
    assert r.store_bytes <= 2 * per


# ---------------------------------------------------------------------------
# speculation
# ---------------------------------------------------------------------------

def test_speculation_payoff_predicate():
    cost = CostModel(max_iters=8, n_topics=6)
    t = cost.predict_train_seconds(1000.0)
    assert cost.speculation_pays(1000.0, t * 2.0)
    assert not cost.speculation_pays(1000.0, t * 0.5)
    assert not cost.speculation_pays(1000.0, t * 2.0, margin=10.0)
    assert not cost.speculation_pays(0.0, 1e9), "empty gaps never pay"


def test_speculator_pretrains_hot_gap_and_counts_hits():
    svc = MLegoService(_corpus(), CFG, window_s=0.0, seed=0)
    try:
        spec = QuerySpec(sigma=Interval(0.0, BASE_HI / 2), alpha=0.5,
                         materialize="volatile")
        for _ in range(2):
            svc.submit(spec).result(timeout=60)
        assert len(svc.store) == 0, "volatile queries leave no capital"

        # margin=0 disables the payoff gate (the predicate is unit-
        # tested above); the scan must mine the hot range and train it
        trainer = svc.attach_speculator(min_count=2, window_s=60.0,
                                        margin=0.0, start=False)
        assert trainer.scan_once() >= 1
        trained = list(trainer.trained_ids)
        assert trained and all(
            svc.store.get(i).o.lo >= 0.0 for i in trained)

        rep = svc.submit(spec).result(timeout=60)
        assert set(rep.model_ids) & set(trained), \
            "the hot query must now fetch speculated capital"
        sr = svc.report()
        assert sr.speculation is not None
        assert sr.speculation.trained >= 1
        assert sr.speculation.hits >= 1
        assert sr.speculation.hit_rate > 0.0
    finally:
        svc.close()


def test_speculator_respects_payoff_gate():
    svc = MLegoService(_corpus(), CFG, window_s=0.0, seed=0)
    try:
        spec = QuerySpec(sigma=Interval(0.0, BASE_HI / 2), alpha=0.5,
                         materialize="volatile")
        for _ in range(2):
            svc.submit(spec).result(timeout=60)
        trainer = svc.attach_speculator(min_count=2, window_s=60.0,
                                        margin=1e12, start=False)
        assert trainer.scan_once() == 0
        assert trainer.report().skipped_payoff >= 1
        assert len(svc.store) == 0
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# service wiring: ingestion end-to-end + satellites
# ---------------------------------------------------------------------------

def test_service_ingest_end_to_end():
    svc = MLegoService(_corpus(), CFG, window_s=0.0, seed=0)
    try:
        pipe = svc.attach_ingest(slice_width=25.0)
        svc.ingest(_stream(width=50.0))
        assert pipe.flush(timeout=30.0)
        fut = svc.submit(QuerySpec(sigma=Interval(BASE_HI, BASE_HI + 25.0)),
                         tenant="ana")
        rep = fut.result(timeout=60)
        assert rep.n_trained_tokens == 0 and rep.model_ids
        sr = svc.report()
        assert sr.ingest is not None and sr.ingest.slices_built == 1
        assert sr.store_bytes > 0
    finally:
        svc.close()
    # close() built the open partial slice
    assert svc.report().ingest.slices_built == 2


def test_extend_corpus_bumps_data_epoch_past_stale_plans():
    corpus = _corpus()
    sess = MLegoSession(corpus, CFG, seed=0)
    sess.train_range(0.0, BASE_HI)
    spec = QuerySpec(sigma=Interval(0.0, BASE_HI + 50.0), alpha=0.5)
    first = sess.submit(spec)
    assert sess.submit(spec).plan_cached, "unchanged world: cached plan"

    # pure corpus growth: no store mutation, so only the data epoch
    # can drop the cached plan that believes [100, 150) is empty
    sess.extend_corpus(concat_corpora(corpus, _stream(width=50.0)))
    rep = sess.submit(spec)
    assert not rep.plan_cached
    assert rep.n_trained_tokens > 0, \
        "the re-plan must train the freshly ingested range"
    assert first.n_trained_tokens == 0


def test_service_routes_named_backend_to_shared_instance():
    svc = MLegoService(_corpus(), CFG, window_s=0.0, seed=0)
    try:
        spec = QuerySpec(sigma=Interval(0.0, BASE_HI / 2),
                         backend="device")
        svc.submit(spec, tenant="a").result(timeout=60)
        svc.submit(spec, tenant="b").result(timeout=60)
        ba = svc.session("a")._backends["device"]
        bb = svc.session("b")._backends["device"]
        assert ba is bb, "named backends must share one instance " \
                         "(one device LRU) across tenants"
        assert ba is svc._shared_backend("device")
        # a tenant created later adopts the shared instance too
        assert svc.session("c")._backends["device"] is ba
    finally:
        svc.close()


def test_coalesced_gap_training_uses_per_tenant_streams():
    """A tenant's answer must not depend on who it coalesced with:
    fused groups train each shared segment on the owning tenant's RNG
    stream, so fused == solo for disjoint ranges."""
    zed_spec = QuerySpec(sigma=Interval(0.0, BASE_HI / 2), alpha=0.5,
                         materialize="volatile")
    ann_spec = QuerySpec(sigma=Interval(BASE_HI / 2, BASE_HI), alpha=0.5,
                         materialize="volatile")

    solo = MLegoService(_corpus(), CFG, window_s=0.0, seed=0)
    try:
        beta_solo = solo.session("zed").submit(zed_spec).beta
    finally:
        solo.close()

    for order in ((("ann", ann_spec), ("zed", zed_spec)),
                  (("zed", zed_spec), ("ann", ann_spec))):
        svc = MLegoService(_corpus(), CFG, window_s=0.4, seed=0)
        try:
            futs = [svc.submit(s, tenant=t) for t, s in order]
            reps = {t: f.result(timeout=60)
                    for (t, _), f in zip(order, futs)}
            assert svc.report().coalesced_groups == 1, \
                "queries must actually have fused for this test"
            np.testing.assert_allclose(reps["zed"].beta, beta_solo,
                                       rtol=1e-6, atol=1e-8)
        finally:
            svc.close()


def test_calibration_save_merge_is_transactional():
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "calibration.json")
        cals = []
        for i in range(8):
            c = Calibration()
            c.push_train("host", (float(1000 + i), 0.5 + i))
            cals.append(c)
        barrier = threading.Barrier(len(cals))

        def save(c):
            barrier.wait()
            c.save(path)

        threads = [threading.Thread(target=save, args=(c,)) for c in cals]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        merged = Calibration.load(path)
        assert merged is not None
        got = sorted(merged.train_obs["host"])
        want = sorted((float(1000 + i), 0.5 + i) for i in range(8))
        assert got == want, \
            "concurrent merge-saves must union all writers' samples"
