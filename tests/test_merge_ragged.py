"""Ragged segmented merge kernel vs the bucketed launcher and host ref.

The segmented kernel (one launch, CSR segment ids, zero pad rows on
*any* batch shape) replaced the power-of-two bucketed launcher on the
execution hot path; the bucketed form stays as the parity reference.
Adversarial batch shapes run as example tests everywhere; hypothesis
(optional dev dep, see ci.yml) widens them to random ragged batches.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.merge_topics.ops import (
    merge_topics_bucketed,
    merge_topics_ragged,
    segment_ids,
)
from repro.kernels.merge_topics.ref import merge_topics_ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:         # optional dev dep (see ci.yml)
    HAVE_HYPOTHESIS = False

RNG = np.random.default_rng(17)


def _batch(counts, k, v):
    stats = [jnp.asarray(RNG.gamma(1.0, 1.0, (n, k, v)), jnp.float32)
             for n in counts]
    weights = [jnp.asarray(RNG.uniform(0.2, 2.0, n), jnp.float32)
               for n in counts]
    return stats, weights


def _check(counts, k, v, bias, base):
    stats, weights = _batch(counts, k, v)
    out, pad_rows, launches = merge_topics_ragged(
        stats, weights, bias=bias, base=base, interpret=True)
    assert pad_rows == 0, f"ragged launch padded on shape {counts}"
    assert launches == 1
    ref_out, _, _ = merge_topics_bucketed(
        stats, weights, bias=bias, base=base, interpret=True)
    for got, buck, s, w in zip(out, ref_out, stats, weights):
        ref = merge_topics_ref(s, w, bias, base)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(buck),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# adversarial batch shapes (run everywhere)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("counts", [
    [1],                  # n' = 1: single row, single segment
    [1, 1, 1],            # all-equal width 1
    [3, 3, 3],            # all-equal width > 1
    [5, 4, 3, 2, 1],      # strictly descending
    [1, 1, 1, 16],        # single wide outlier (worst bucketed shape)
])
@pytest.mark.parametrize("k,v", [(12, 128), (6, 150)])  # aligned + ragged KV
def test_ragged_matches_bucketed_and_ref(counts, k, v):
    _check(counts, k, v, bias=0.05, base=0.05)      # MVB form
    _check(counts, k, v, bias=0.0, base=0.0)        # MGS form


def test_segment_ids_csr():
    np.testing.assert_array_equal(
        np.asarray(segment_ids([2, 1, 3])), [0, 0, 1, 2, 2, 2])
    assert segment_ids([4]).dtype == jnp.int32


def test_ragged_never_pads_where_bucketed_does():
    """The one-wide-outlier shape forces the bucketed launcher to pad;
    the segmented launch must not, while agreeing on every output."""
    counts = [1, 1, 1, 16]
    stats, weights = _batch(counts, 8, 128)
    _, ragged_pad, ragged_launches = merge_topics_ragged(
        stats, weights, interpret=True)
    _, bucketed_pad, bucketed_launches = merge_topics_bucketed(
        stats, weights, interpret=True)
    assert ragged_pad == 0
    assert ragged_launches == 1
    assert bucketed_launches >= 2       # one per occupied bucket


# ---------------------------------------------------------------------------
# property tests (hypothesis, when available)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    COUNTS = st.lists(st.integers(1, 8), min_size=1, max_size=5)

    @settings(max_examples=15, deadline=None)
    @given(COUNTS, st.sampled_from([(8, 128), (5, 96), (11, 130)]),
           st.sampled_from([(0.05, 0.05), (0.0, 0.0)]))
    def test_ragged_property_parity(counts, kv, form):
        k, v = kv
        bias, base = form
        _check(counts, k, v, bias=bias, base=base)

    @settings(max_examples=15, deadline=None)
    @given(COUNTS)
    def test_ragged_property_zero_pad(counts):
        stats, weights = _batch(counts, 8, 128)
        _, pad_rows, launches = merge_topics_ragged(
            stats, weights, interpret=True)
        assert pad_rows == 0
        assert launches == 1
