"""Multi-tenant serving layer: the coalescing queue, MLegoService's
async front door (fusion into submit_many, failure isolation,
per-tenant stats, shutdown), and cross-session sharing of the plan
cache / device model LRU / calibration log over one store."""
import threading
import time

import numpy as np
import pytest

from repro.api import (
    DeviceBackend,
    Interval,
    MLegoSession,
    PlanCache,
    QuerySpec,
    get_trainer,
    register_trainer,
)
from repro.configs.lda_default import LDAConfig
from repro.core.store import ModelStore
from repro.data.corpus import make_corpus, train_test_split
from repro.serve import CoalescingQueue, MLegoService, PendingQuery

CFG = LDAConfig(n_topics=6, vocab_size=150, alpha=0.5, eta=0.05,
                max_iters=8, e_step_iters=5, gibbs_sweeps=6)


@pytest.fixture(scope="module")
def train():
    corpus, _ = make_corpus(300, CFG.vocab_size, CFG.n_topics,
                            mean_doc_len=30, seed=3)
    train, _ = train_test_split(corpus, test_frac=0.1, seed=1)
    return train


def _hi(train):
    return float(train.attr[-1]) + 1.0


# ---------------------------------------------------------------------------
# CoalescingQueue
# ---------------------------------------------------------------------------

def _pending(lo=0.0, hi=10.0, tenant="t"):
    return PendingQuery(spec=QuerySpec(sigma=Interval(lo, hi)),
                        tenant=tenant)


def test_queue_drains_window_batch():
    q = CoalescingQueue(window_s=0.2, max_width=8)
    for i in range(3):
        q.put(_pending(lo=float(i)))
    batch = q.drain(timeout=0.1)
    assert len(batch) == 3, "items already queued must drain together"
    assert q.drain(timeout=0.01) == []


def test_queue_respects_max_width():
    q = CoalescingQueue(window_s=0.2, max_width=2)
    for i in range(5):
        q.put(_pending(lo=float(i)))
    assert len(q.drain(timeout=0.1)) == 2
    assert len(q.drain(timeout=0.1)) == 2
    assert len(q.drain(timeout=0.1)) == 1


def test_queue_zero_window_is_fifo_serial():
    q = CoalescingQueue(window_s=0.0, max_width=8)
    q.put(_pending(lo=0.0))
    q.put(_pending(lo=1.0))
    first = q.drain(timeout=0.1)
    assert [p.spec.sigma[0].lo for p in first] == [0.0, 1.0] or \
        len(first) == 1, "window 0 takes only what is instantly available"


def test_queue_window_collects_late_arrivals():
    q = CoalescingQueue(window_s=0.5, max_width=8)
    q.put(_pending(lo=0.0))

    def late():
        time.sleep(0.05)
        q.put(_pending(lo=1.0))

    t = threading.Thread(target=late)
    t.start()
    batch = q.drain(timeout=0.1)
    t.join()
    assert len(batch) == 2, "an arrival inside the window must fuse"


def test_queue_close_rejects_put_but_drains():
    q = CoalescingQueue(window_s=0.0)
    q.put(_pending())
    q.close()
    with pytest.raises(RuntimeError, match="closed"):
        q.put(_pending())
    assert len(q.drain(timeout=0.01)) == 1


def test_queue_rejects_bad_params():
    with pytest.raises(ValueError, match="window_s"):
        CoalescingQueue(window_s=-1.0)
    with pytest.raises(ValueError, match="max_width"):
        CoalescingQueue(max_width=0)


# ---------------------------------------------------------------------------
# MLegoService: correctness of the async front door
# ---------------------------------------------------------------------------

def test_service_answer_matches_direct_session(train):
    """Over identical capital the async front door answers exactly
    what a synchronous session answers (merges are deterministic)."""
    hi = _hi(train)
    spec = QuerySpec(sigma=Interval(0.0, hi), alpha=1.0)

    direct = MLegoSession(train, CFG, seed=0)
    for i in range(3):
        direct.train_range(i * hi / 3, (i + 1) * hi / 3)
    want = direct.submit(spec)

    with MLegoService(train, CFG, store=direct.store,
                      window_s=0.0) as svc:
        got = svc.submit(spec).result(timeout=60)
    np.testing.assert_array_equal(got.beta, want.beta)
    assert got.model_ids == want.model_ids


def test_service_coalesces_burst_into_one_batch(train):
    """A burst of compatible volatile specs must ride one submit_many:
    every shared gap segment trains exactly once for the whole group."""
    calls = []

    def counting_vb(corpus, cfg, key):
        calls.append(corpus.n_docs)
        return get_trainer("vb")(corpus, cfg, key)

    register_trainer("count_vb", counting_vb, merge="vb")
    try:
        hi = _hi(train)
        with MLegoService(train, CFG, kind="count_vb", window_s=0.5,
                          max_width=8) as svc:
            specs = [QuerySpec(sigma=Interval(0.0, hi / 2),
                               kind="count_vb", materialize="volatile")
                     for _ in range(4)]
            futs = [svc.submit(s, tenant=f"t{i}")
                    for i, s in enumerate(specs)]
            reps = [f.result(timeout=60) for f in futs]
            rep = svc.report()
        assert len(calls) == 1, \
            "the shared gap segment must train once for the whole group"
        for r in reps:
            assert np.isfinite(r.beta).all()
        assert rep.queries == 4
        assert rep.coalesced_groups >= 1
        assert rep.max_coalesce_width == 4
        for t in ("t0", "t1", "t2", "t3"):
            assert rep.tenant(t).queries == 1
            assert rep.tenant(t).max_width == 4
    finally:
        from repro.api import trainers as tr
        tr._TRAINERS.pop("count_vb", None)
        tr._MERGES.pop("count_vb", None)


def test_service_groups_incompatible_kinds_separately(train):
    """vb and gs specs in one window must execute as separate groups
    (submit_many's one-kind contract), both successfully."""
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.5, max_width=8) as svc:
        fa = svc.submit(QuerySpec(sigma=Interval(0.0, hi / 4), kind="vb"))
        fb = svc.submit(QuerySpec(sigma=Interval(0.0, hi / 4), kind="gs"))
        ra, rb = fa.result(timeout=120), fb.result(timeout=120)
    assert np.isfinite(ra.beta).all() and np.isfinite(rb.beta).all()


def test_service_mixed_alpha_group_rides_alpha_split(train):
    """α may differ inside a group — the session's α-split machinery
    handles it, so the group still fuses instead of failing."""
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.5, max_width=8) as svc:
        svc.train_range(0.0, hi)
        futs = [svc.submit(QuerySpec(sigma=Interval(0.0, hi), alpha=a))
                for a in (0.0, 1.0, 0.0)]
        reps = [f.result(timeout=60) for f in futs]
        rep = svc.report()
    assert all(np.isfinite(r.beta).all() for r in reps)
    assert rep.max_coalesce_width == 3


def test_service_isolates_failing_spec(train):
    """One empty-predicate spec must not poison its coalescing
    neighbors: its future raises, theirs resolve."""
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.5, max_width=8) as svc:
        svc.train_range(0.0, hi)
        good1 = svc.submit(QuerySpec(sigma=Interval(0.0, hi)))
        bad = svc.submit(QuerySpec(sigma=Interval(hi + 10.0, hi + 20.0)))
        good2 = svc.submit(QuerySpec(sigma=Interval(0.0, hi / 2)))
        assert np.isfinite(good1.result(timeout=60).beta).all()
        assert np.isfinite(good2.result(timeout=60).beta).all()
        with pytest.raises(ValueError, match="selects no data"):
            bad.result(timeout=60)
        rep = svc.report()
    assert rep.errors == 1
    assert rep.queries == 3


def test_service_tenant_stats_and_queue_wait(train):
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.2, max_width=8) as svc:
        svc.train_range(0.0, hi)
        futs = [svc.submit(QuerySpec(sigma=Interval(0.0, hi)),
                           tenant="ana") for _ in range(2)]
        futs.append(svc.submit(QuerySpec(sigma=Interval(0.0, hi / 2)),
                               tenant="bob"))
        for f in futs:
            f.result(timeout=60)
        rep = svc.report()
    assert set(rep.tenants) == {"ana", "bob"}
    assert rep.tenant("ana").queries == 2
    assert rep.tenant("bob").queries == 1
    assert rep.tenant("ana").queue_wait_s >= 0.0
    assert rep.queries == 3
    # an unknown tenant reads as zeros, not a KeyError
    assert rep.tenant("nobody").queries == 0


def test_service_close_rejects_new_drains_pending(train):
    hi = _hi(train)
    svc = MLegoService(train, CFG, window_s=0.0)
    svc.train_range(0.0, hi)
    fut = svc.submit(QuerySpec(sigma=Interval(0.0, hi)))
    svc.close()
    assert np.isfinite(fut.result(timeout=60).beta).all(), \
        "close() must drain already-accepted queries"
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(QuerySpec(sigma=Interval(0.0, hi)))
    svc.close()      # idempotent


def test_cancelled_future_does_not_kill_worker(train):
    """A client cancelling a queued future must not strand the rest
    of the batch (regression: set_result on a cancelled future raises
    InvalidStateError, which used to kill the worker thread)."""
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.5, max_width=8) as svc:
        svc.train_range(0.0, hi)
        doomed = svc.submit(QuerySpec(sigma=Interval(0.0, hi)))
        alive = svc.submit(QuerySpec(sigma=Interval(0.0, hi / 2)))
        cancelled = doomed.cancel()      # races the worker; both fine
        rep = alive.result(timeout=60)
        assert np.isfinite(rep.beta).all(), \
            "neighbor of a cancelled future must still resolve"
        if cancelled:
            assert doomed.cancelled()
        # the worker survived: it keeps answering
        again = svc.submit(QuerySpec(sigma=Interval(0.0, hi)))
        assert np.isfinite(again.result(timeout=60).beta).all()


def test_service_concurrent_submitters(train):
    """Many client threads hammering submit concurrently: every future
    resolves, nothing deadlocks, counts add up."""
    hi = _hi(train)
    results = []
    with MLegoService(train, CFG, window_s=0.05, max_width=8) as svc:
        svc.train_range(0.0, hi)

        def client(name):
            futs = [svc.submit(QuerySpec(sigma=Interval(0.0, hi)),
                               tenant=name) for _ in range(3)]
            results.extend(f.result(timeout=120) for f in futs)

        threads = [threading.Thread(target=client, args=(f"c{i}",))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        rep = svc.report()
    assert len(results) == 12
    assert all(np.isfinite(r.beta).all() for r in results)
    assert rep.queries == 12
    assert sum(t.queries for t in rep.tenants.values()) == 12


# ---------------------------------------------------------------------------
# cross-session sharing (the acceptance criterion): a second session /
# tenant over the shared store reuses the first one's plan search and
# device-resident parameters
# ---------------------------------------------------------------------------

def test_second_session_reuses_plan_and_device_cache(train):
    hi = _hi(train)
    store, backend, cache = ModelStore(), DeviceBackend(), PlanCache()
    a = MLegoSession(train, CFG, store=store, backend=backend,
                     plan_cache=cache, seed=0)
    b = MLegoSession(train, CFG, store=store, backend=backend,
                     plan_cache=cache, seed=1)
    for i in range(3):
        a.train_range(i * hi / 3, (i + 1) * hi / 3)
    spec = QuerySpec(sigma=Interval(0.0, hi), alpha=1.0)
    ra = a.submit(spec)
    rb = b.submit(spec)
    np.testing.assert_allclose(ra.beta, rb.beta, rtol=1e-5, atol=1e-5)
    assert not ra.plan_cached, "first search over this store is cold"
    assert rb.plan_cached, \
        "second session must ride the shared plan cache"
    assert rb.cache_hits > 0 and rb.cache_misses == 0, \
        "second session must read A's device-resident parameters"


def test_service_tenants_share_plan_cache(train):
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.0) as svc:
        svc.train_range(0.0, hi)
        spec = QuerySpec(sigma=Interval(0.0, hi), alpha=1.0)
        first = svc.submit(spec, tenant="ana").result(timeout=60)
        second = svc.submit(spec, tenant="bob").result(timeout=60)
    assert not first.plan_cached
    assert second.plan_cached, \
        "tenant bob must reuse tenant ana's plan search"


def test_shared_plan_cache_requires_shared_store(train):
    cache = PlanCache()
    MLegoSession(train, CFG, store=ModelStore(), plan_cache=cache)
    with pytest.raises(ValueError, match="different store"):
        MLegoSession(train, CFG, store=ModelStore(), plan_cache=cache)


def test_shared_calibrated_provider_requires_shared_store(train):
    """A calibrated provider's size probe reads one store; adopting it
    into a session over a different store would mis-size every fetch
    via id collisions — it must refuse, like backend/plan-cache
    sharing does."""
    from repro.core.cost import CalibratedCostModel

    provider = CalibratedCostModel()
    store = ModelStore()
    first = MLegoSession(train, CFG, store=store, cost=provider)
    MLegoSession(train, CFG, store=store, cost=provider)   # same store: fine
    with pytest.raises(ValueError, match="wired to a different store"):
        MLegoSession(train, CFG, store=ModelStore(), cost=provider)
    # and the wiring session can't pull the probe's store out from
    # under the other sharers either
    with pytest.raises(ValueError, match="shared cost provider"):
        first.store = ModelStore()


def test_store_mutation_invalidates_both_sessions(train):
    """Mutating the shared store from one session must drop the shared
    plan cache (visible to both) exactly once per mutation."""
    hi = _hi(train)
    store, cache = ModelStore(), PlanCache()
    a = MLegoSession(train, CFG, store=store, plan_cache=cache, seed=0)
    b = MLegoSession(train, CFG, store=store, plan_cache=cache, seed=1)
    a.train_range(0.0, hi)
    spec = QuerySpec(sigma=Interval(0.0, hi), alpha=1.0)
    assert b.submit(spec).plan_cached is False
    assert a.submit(spec).plan_cached is True      # b's entry, a's hit
    inv0 = cache.invalidations
    b.train_range(0.0, hi / 2)                     # mutate from session b
    assert cache.invalidations == inv0 + 1
    assert len(cache) == 0
    assert a.submit(spec).plan_cached is False, \
        "session a must see session b's invalidation"


def test_service_shared_calibration_log(train):
    """Every tenant's measured timings land in one calibration log."""
    hi = _hi(train)
    with MLegoService(train, CFG, cost="calibrated",
                      window_s=0.0) as svc:
        svc.submit(QuerySpec(sigma=Interval(0.0, hi / 2)),
                   tenant="ana").result(timeout=60)
        svc.submit(QuerySpec(sigma=Interval(hi / 2, hi)),
                   tenant="bob").result(timeout=60)
        rep = svc.report()
        assert rep.calibration_samples > 0
        assert svc.session("ana").cost is svc.session("bob").cost, \
            "tenants must share one provider (one log)"


def test_service_calibration_sidecar_saved_on_close(train, tmp_path):
    hi = _hi(train)
    path = str(tmp_path / "calibration.json")
    svc = MLegoService(train, CFG, cost="calibrated",
                       calibration_path=path, window_s=0.0)
    svc.submit(QuerySpec(sigma=Interval(0.0, hi / 2))).result(timeout=60)
    svc.close()
    from repro.core.cost import Calibration
    assert Calibration.load(path) is not None, \
        "close() must persist the shared calibration log"
