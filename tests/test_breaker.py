"""Per-backend circuit breaker: the state machine under a fake clock,
and the serve-layer integration — device loss quarantines the backend,
open breakers reroute traffic down the fallback chain, half-open
probes re-admit, and ``ServiceReport.breaker`` exposes it all.

This file (with ``test_faults.py``) is the CI chaos-smoke leg.
"""
import time

import numpy as np
import pytest

from repro.api import DeviceLostError, Interval, MLegoSession, QuerySpec
from repro.configs.lda_default import LDAConfig
from repro.data.corpus import make_corpus
from repro.serve import (
    CLOSED,
    HALF_OPEN,
    OPEN,
    BreakerPolicy,
    CircuitBreaker,
    MLegoService,
)
from repro.testing.faults import FaultRule, injected

CFG = LDAConfig(n_topics=4, vocab_size=100, alpha=0.5, eta=0.05,
                max_iters=5, e_step_iters=4, gibbs_sweeps=4)


@pytest.fixture(scope="module")
def corpus():
    c, _ = make_corpus(200, CFG.vocab_size, CFG.n_topics,
                       mean_doc_len=25, seed=11)
    return c


def _hi(corpus):
    return float(corpus.attr[-1]) + 1.0


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# state machine
# ---------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError):
        BreakerPolicy(window=0)
    with pytest.raises(ValueError):
        BreakerPolicy(failure_threshold=0.0)
    with pytest.raises(ValueError):
        BreakerPolicy(failure_threshold=1.5)
    with pytest.raises(ValueError):
        BreakerPolicy(half_open_probes=0)


def test_opens_on_windowed_error_rate_not_before_min_samples():
    clock = FakeClock()
    cb = CircuitBreaker(BreakerPolicy(window=10, failure_threshold=0.5,
                                      min_samples=5, cooldown_s=1.0),
                        clock=clock)
    # 4 failures < min_samples: still closed even at 100% error rate
    for _ in range(4):
        cb.record_failure()
    assert cb.state == CLOSED and cb.allow()
    cb.record_failure()                     # 5th sample trips 100% >= 50%
    assert cb.state == OPEN
    assert not cb.allow()
    snap = cb.snapshot()
    assert snap.opens == 1 and snap.reroutes >= 1
    assert snap.error_rate == 1.0


def test_successes_dilute_the_window_below_threshold():
    cb = CircuitBreaker(BreakerPolicy(window=10, failure_threshold=0.5,
                                      min_samples=5), clock=FakeClock())
    for _ in range(6):
        cb.record_success()
    for _ in range(4):
        cb.record_failure()                 # 4/10 = 40% < 50%
    assert cb.state == CLOSED


def test_hard_failure_trips_immediately_from_any_state():
    clock = FakeClock()
    cb = CircuitBreaker(BreakerPolicy(cooldown_s=1.0), clock=clock)
    cb.record_success()
    cb.record_failure(hard=True)            # one device loss is enough
    assert cb.state == OPEN
    clock.t = 2.0                           # cooldown elapses
    assert cb.state == HALF_OPEN
    cb.record_failure(hard=True)            # half-open probe dies
    assert cb.state == OPEN


def test_half_open_probes_then_close_and_window_clears():
    clock = FakeClock()
    cb = CircuitBreaker(BreakerPolicy(cooldown_s=1.0, half_open_probes=2,
                                      min_samples=1,
                                      failure_threshold=0.5),
                        clock=clock)
    cb.record_failure(hard=True)
    assert not cb.allow()                   # open: denied
    clock.t = 1.5
    assert cb.allow() and cb.allow()        # two probes admitted
    assert not cb.allow()                   # third denied while probing
    cb.record_success()
    assert cb.state == HALF_OPEN            # one success is not enough
    cb.record_success()
    assert cb.state == CLOSED
    assert cb.snapshot().window == 0        # window cleared on close


def test_probe_failure_reopens_and_restarts_cooldown():
    clock = FakeClock()
    cb = CircuitBreaker(BreakerPolicy(cooldown_s=1.0), clock=clock)
    cb.force_open()
    clock.t = 1.1
    assert cb.allow()                       # probe
    cb.record_failure()                     # soft failure still re-opens
    assert cb.state == OPEN
    clock.t = 1.5                           # cooldown restarted at 1.1
    assert not cb.allow()
    clock.t = 2.2
    assert cb.allow()


def test_transition_hook_fires_after_lock_release():
    clock = FakeClock()
    seen = []
    cb = CircuitBreaker(BreakerPolicy(cooldown_s=1.0), clock=clock,
                        on_transition=lambda old, new:
                        (seen.append((old, new)),
                         cb.snapshot()))    # re-entering must not deadlock
    cb.record_failure(hard=True)
    clock.t = 1.5
    _ = cb.state
    cb.record_success()
    cb.record_success()
    assert seen == [(CLOSED, OPEN), (OPEN, HALF_OPEN),
                    (HALF_OPEN, CLOSED)]


def test_snapshot_since_tracks_state_age():
    clock = FakeClock()
    cb = CircuitBreaker(BreakerPolicy(cooldown_s=10.0), clock=clock)
    clock.t = 3.0
    assert cb.snapshot().since_s == 3.0
    cb.force_open()
    clock.t = 5.0
    snap = cb.snapshot()
    assert snap.state == OPEN and snap.since_s == 2.0


# ---------------------------------------------------------------------------
# session-level device-loss fallback (no service)
# ---------------------------------------------------------------------------

def test_session_replays_on_fallback_chain_and_quarantines(corpus):
    hi = _hi(corpus)
    sess = MLegoSession(corpus, CFG, backend="device", seed=0)
    sess.train_range(0.0, hi / 2)
    spec = QuerySpec(sigma=Interval(0.0, hi / 2))
    with injected(FaultRule("backend.merge.device", rate=1.0,
                            kind="device_lost", max_failures=1), seed=2):
        rep = sess.submit(spec)
    assert rep.fallback_from == "device"
    assert rep.backend == "host"            # replayed downstream
    assert np.all(np.isfinite(rep.beta))
    device = sess._backend_for(QuerySpec(sigma=Interval(0.0, hi / 2),
                                         backend="device"))
    assert device.quarantined               # flagged for the serve layer

    # the quarantine flag is advisory at session level (the service's
    # breaker enforces routing); with the fault gone, direct use works
    rep2 = sess.submit(spec)
    assert rep2.backend == "device" and rep2.fallback_from is None
    # the fallback chain itself does skip quarantined backends
    assert sess._fail_over(device).name == "host"


def test_session_chain_exhaustion_surfaces_device_lost(corpus):
    hi = _hi(corpus)
    sess = MLegoSession(corpus, CFG, backend="host", seed=0)
    sess.train_range(0.0, hi / 2)
    # host has no fallback: a device-lost style failure must surface
    with injected(FaultRule("backend.merge.host", rate=1.0,
                            kind="device_lost"), seed=2):
        with pytest.raises(DeviceLostError):
            sess.submit(QuerySpec(sigma=Interval(0.0, hi / 2)))


# ---------------------------------------------------------------------------
# serve-layer integration
# ---------------------------------------------------------------------------

def test_device_loss_opens_breaker_reroutes_then_readmits(corpus):
    hi = _hi(corpus)
    svc = MLegoService(corpus, CFG, backend="device", window_s=0.0,
                       breaker=BreakerPolicy(cooldown_s=0.3))
    try:
        svc.train_range(0.0, hi / 2)
        spec = QuerySpec(sigma=Interval(0.0, hi / 2))

        with injected(FaultRule("backend.merge.device", rate=1.0,
                                kind="device_lost", max_failures=1),
                      seed=3):
            rep = svc.submit(spec).result(timeout=60)
        # the session absorbed the loss; the report carries the signal
        assert rep.fallback_from == "device" and rep.backend == "host"
        r = svc.report()
        assert r.breaker["device"].state == OPEN
        assert r.breaker["device"].opens == 1
        assert svc.backend.quarantined

        # open breaker: traffic reroutes to the fallback pool, answered
        rep2 = svc.submit(spec).result(timeout=60)
        assert rep2.backend == "host"
        assert svc.report().breaker_reroutes >= 1

        # cooldown -> half-open probe -> consecutive successes close it
        time.sleep(0.35)
        rep3 = svc.submit(spec).result(timeout=60)
        rep4 = svc.submit(spec).result(timeout=60)
        assert rep3.backend == "device" and rep4.backend == "device"
        r = svc.report()
        assert r.breaker["device"].state == CLOSED
        assert not svc.backend.quarantined  # re-admitted
    finally:
        svc.close()


def test_breaker_snapshots_always_on_report(corpus):
    hi = _hi(corpus)
    svc = MLegoService(corpus, CFG, backend="host", window_s=0.0)
    try:
        svc.train_range(0.0, hi / 4)
        svc.submit(QuerySpec(sigma=Interval(0.0, hi / 4))) \
           .result(timeout=60)
        r = svc.report()
        assert r.breaker["host"].state == CLOSED
        assert r.breaker_reroutes == 0
    finally:
        svc.close()
