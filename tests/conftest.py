"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; multi-device tests run in
subprocesses (tests/test_multidevice.py)."""
import numpy as np
import pytest

from repro.configs.lda_default import LDAConfig
from repro.core.cost import CostModel
from repro.core.plans import Interval
from repro.core.store import ModelStore
from repro.data.corpus import DataIndex, make_corpus


@pytest.fixture(scope="session")
def small_cfg():
    return LDAConfig(n_topics=8, vocab_size=200, alpha=0.5, eta=0.05,
                     max_iters=15, e_step_iters=8, gibbs_sweeps=10)


@pytest.fixture(scope="session")
def small_corpus(small_cfg):
    corpus, beta = make_corpus(400, small_cfg.vocab_size,
                               small_cfg.n_topics, mean_doc_len=30, seed=7)
    return corpus, beta


@pytest.fixture(scope="session")
def small_index(small_corpus):
    return DataIndex(small_corpus[0])


def build_store(index, n_models=10, seed=0, span=(0.0, 400.0), k=8, v=200,
                kind="vb"):
    """Random store of materialized stand-in models (stats are dummies —
    plan-search tests only use ranges and counts)."""
    rng = np.random.default_rng(seed)
    store = ModelStore()
    for _ in range(n_models):
        lo = rng.uniform(span[0], span[1] * 0.8)
        hi = lo + rng.uniform((span[1] - span[0]) * 0.02,
                              (span[1] - span[0]) * 0.3)
        nd, nt = index.count(lo, hi)
        theta = ({"lam": np.ones((k, v), np.float32)} if kind == "vb"
                 else {"delta_nkv": np.ones((k, v), np.float32)})
        store.add(Interval(lo, hi), nd, nt, kind, theta)
    return store


@pytest.fixture()
def cost_model():
    return CostModel(max_iters=15, n_topics=8)
