"""Cost providers: the analytic/calibrated split, coefficient fitting
from measured timings, cache-aware fetch pricing, and the version
counter the session plan cache keys on."""
import numpy as np
import pytest

from repro.core.cost import (
    CalibratedCostModel,
    Calibration,
    CostModel,
    PerformanceLoss,
)
from repro.core.plan_ir import Plan, FetchStep, MergeStep
from repro.core.plans import Interval

BASE = CostModel(kappa_train=1e-9, t_merge=1e-4, max_iters=10, n_topics=4)


# ---------------------------------------------------------------------------
# parity: an unobserved calibrated provider prices like its base
# ---------------------------------------------------------------------------

def test_unobserved_calibrated_matches_analytic():
    cal = CalibratedCostModel(BASE)
    for alpha in (0.0, 0.5, 1.0):
        for n, unc in ((0, 1000.0), (2, 0.0), (3, 250.0)):
            assert cal.score(alpha, n, unc, 2000.0) == pytest.approx(
                BASE.score(alpha, n, unc, 2000.0), rel=1e-12)
    assert cal.t_merge == BASE.t_merge
    assert cal.c_train(123.0) == pytest.approx(BASE.c_train(123.0))


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------

def test_kappa_fit_recovers_synthetic_rate():
    cal = CalibratedCostModel(BASE)
    true_kappa = 3e-8
    for tok in (100, 400, 900):
        secs = true_kappa * BASE.max_iters * tok ** 2 * BASE.n_topics
        cal.observe_train(tok, secs)
    assert cal.c_train(500.0) == pytest.approx(
        true_kappa * BASE.max_iters * 500.0 ** 2 * BASE.n_topics, rel=1e-6)


def test_t_merge_fit_from_host_merges():
    cal = CalibratedCostModel(BASE)
    for x in (1, 2, 4):
        cal.observe_merge_host(x, 2e-3 * x)
    assert cal.t_merge == pytest.approx(2e-3, rel=1e-6)
    assert cal.c_merge(3) == pytest.approx(6e-3, rel=1e-6)


def test_device_fit_separates_hit_and_miss():
    cal = CalibratedCostModel(BASE)
    # synthetic: launch 1ms, hit 0.5ms, miss 4ms
    for h, m in ((0, 3), (3, 0), (2, 1), (1, 2), (4, 4)):
        cal.observe_merge_device(h, m, 1e-3 + 0.5e-3 * h + 4e-3 * m)
    assert cal.version > 0          # reading a price triggers the lazy fit
    assert cal._t_hit == pytest.approx(0.5e-3, rel=1e-6)
    assert cal._t_miss == pytest.approx(4e-3, rel=1e-6)


def test_underdetermined_device_fit_keeps_hit_below_miss():
    cal = CalibratedCostModel(BASE)
    cal.observe_merge_device(2, 2, 4e-3)
    assert cal.version > 0
    assert 0.0 <= cal._t_hit < cal._t_miss


def test_pad_fit():
    cal = CalibratedCostModel(BASE)
    cal.observe_pad(4, 8e-3)
    cal.observe_pad(2, 4e-3)
    assert cal.padding_cost(3) == pytest.approx(6e-3, rel=1e-6)
    assert BASE.padding_cost(3) == 0.0


# ---------------------------------------------------------------------------
# cache-aware fetch pricing
# ---------------------------------------------------------------------------

class _M:
    def __init__(self, mid, lo, hi, tok):
        self.model_id = mid
        self.o = Interval(lo, hi)
        self.n_tokens = tok


class _Idx:
    """Stub index: token mass uniform, 1 token per unit length."""

    def tokens_in(self, lo, hi):
        return max(hi - lo, 0.0)


def test_cached_plan_prices_below_uncached():
    cached_ids = {1, 2}
    cal = CalibratedCostModel(BASE, cache_probe=lambda mid: mid in cached_ids)
    for h, m in ((0, 3), (3, 0), (2, 1), (1, 2)):
        cal.observe_merge_device(h, m, 1e-3 + 0.5e-3 * h + 4e-3 * m)
    idx = _Idx()
    q = Interval(0.0, 100.0)
    # two full-coverage plans with equal merge counts: the cached pair
    # must price strictly below the uncached pair
    warm = (_M(1, 0.0, 50.0, 50), _M(2, 50.0, 100.0, 50))
    cold = (_M(7, 0.0, 50.0, 50), _M(8, 50.0, 100.0, 50))
    sc_warm = cal.score_models(warm, q, idx, 0.0, 100.0)
    sc_cold = cal.score_models(cold, q, idx, 0.0, 100.0)
    assert sc_warm < sc_cold
    # the analytic provider cannot tell them apart
    assert BASE.score_models(warm, q, idx, 0.0, 100.0) == pytest.approx(
        BASE.score_models(cold, q, idx, 0.0, 100.0))


def test_price_plan_uses_fetch_ids():
    cached_ids = {5}
    cal = CalibratedCostModel(BASE, cache_probe=lambda mid: mid in cached_ids)
    for h, m in ((0, 2), (2, 0), (1, 1)):
        cal.observe_merge_device(h, m, 1e-3 + 1e-3 * h + 5e-3 * m)
    sigma = Interval(0.0, 10.0)
    warm = Plan(sigma, (FetchStep(5, sigma, 10), MergeStep(1)))
    cold = Plan(sigma, (FetchStep(9, sigma, 10), MergeStep(1)))
    assert cal.price_plan(warm, 0.0, 10.0) < cal.price_plan(cold, 0.0, 10.0)


# ---------------------------------------------------------------------------
# version counter (the plan-cache coupling)
# ---------------------------------------------------------------------------

def test_version_bumps_on_material_refit_only():
    cal = CalibratedCostModel(BASE)
    v0 = cal.version
    cal.observe_train(500, 1.0)
    assert cal.version > v0, "first fit must change prices"
    v1 = cal.version
    # identical repeat observations: coefficients unchanged -> version
    # stable (repeated interactive queries keep hitting the plan cache)
    for _ in range(5):
        cal.observe_train(500, 1.0)
    assert cal.version == v1
    # one 10x outlier is jitter/compile noise, not a price change
    cal.observe_train(500, 10.0)
    assert cal.version == v1
    # a *sustained* 10x slower training world is a material change
    for _ in range(8):
        cal.observe_train(500, 10.0)
    assert cal.version > v1


def test_warmup_outlier_does_not_skew_device_fit():
    """The first launch pays jit compile; the fit must not chase it."""
    cal = CalibratedCostModel(BASE)
    cal.observe_merge_device(0, 4, 0.5)            # cold: compile-dominated
    cal.observe_merge_device(4, 0, 4e-3)
    v = None
    for _ in range(4):
        cal.observe_merge_device(4, 0, 4e-3)
        v = cal.version
        cal.observe_merge_device(4, 0, 4e-3)
        assert cal.version == v, "steady-state replays must not reprice"
    assert cal._t_miss < 0.1, "compile outlier leaked into t_miss"


def test_rolling_window_caps_observations():
    from repro.core.cost import _MAX_OBS
    cal = CalibratedCostModel(BASE)
    for i in range(_MAX_OBS + 50):
        cal.observe_merge_host(1, 1e-3)
    assert len(cal.calibration.host_obs) == _MAX_OBS


def test_performance_loss_fit_roundtrip():
    pl = PerformanceLoss(rho=0.95)
    xs = [1, 2, 4, 8]
    losses = [pl.loss(x) for x in xs]
    fitted = PerformanceLoss.fit(xs, losses)
    assert fitted.rho == pytest.approx(0.95, rel=1e-6)


# ---------------------------------------------------------------------------
# backend-keyed kappa (host vs device gap training priced separately)
# ---------------------------------------------------------------------------

def test_backend_keyed_kappa_prices_backends_separately():
    cal = CalibratedCostModel(BASE)
    # host trains 10x slower than device on this synthetic machine
    for tok in (100, 400, 900):
        unit = BASE.max_iters * tok ** 2 * BASE.n_topics
        cal.observe_train(tok, 1e-7 * unit, backend="host")
        cal.observe_train(tok, 1e-8 * unit, backend="device")
    cal.set_train_backend("host")
    host_price = cal.c_train(500.0)
    cal.set_train_backend("device")
    dev_price = cal.c_train(500.0)
    assert host_price == pytest.approx(10 * dev_price, rel=1e-6)


def test_unfit_device_backend_falls_back_to_host_kappa():
    cal = CalibratedCostModel(BASE)
    for tok in (100, 400):
        unit = BASE.max_iters * tok ** 2 * BASE.n_topics
        cal.observe_train(tok, 5e-8 * unit)          # host default
    cal.set_train_backend("device")
    assert cal.c_train(300.0) == pytest.approx(
        5e-8 * BASE.max_iters * 300.0 ** 2 * BASE.n_topics, rel=1e-6)


def test_new_backend_kappa_bumps_version():
    cal = CalibratedCostModel(BASE)
    cal.observe_train(500, 1.0, backend="host")
    v = cal.version
    cal.observe_train(500, 0.001, backend="device")
    assert cal.version > v, "a newly priced backend is a material change"


# ---------------------------------------------------------------------------
# calibration persistence (the store's JSON sidecar)
# ---------------------------------------------------------------------------

def test_calibration_sidecar_roundtrip(tmp_path):
    cal = CalibratedCostModel(BASE)
    for tok in (100, 400, 900):
        unit = BASE.max_iters * tok ** 2 * BASE.n_topics
        cal.observe_train(tok, 3e-8 * unit, backend="device")
    cal.observe_merge_host(2, 4e-3)
    cal.observe_merge_device(1, 2, 9e-3)
    cal.observe_pad(4, 8e-3)
    cal.set_train_backend("device")
    warm_price = cal.c_train(500.0)

    path = str(tmp_path / "calibration.json")
    cal.calibration.save(path)

    loaded = Calibration.load(path)
    assert loaded is not None
    assert loaded == cal.calibration
    warm = CalibratedCostModel(BASE, calibration=loaded)
    warm.set_train_backend("device")
    assert warm.c_train(500.0) == pytest.approx(warm_price, rel=1e-9)
    assert warm.version > 0, "a preloaded calibration is already priced"


def test_calibration_load_missing_or_stale_is_cold_start(tmp_path):
    assert Calibration.load(str(tmp_path / "absent.json")) is None
    stale = tmp_path / "stale.json"
    stale.write_text('{"format": 999, "train_obs": {}}')
    assert Calibration.load(str(stale)) is None
    garbage = tmp_path / "garbage.json"
    garbage.write_text("not json at all {")
    assert Calibration.load(str(garbage)) is None


def test_session_calibration_path_warm_starts(tmp_path):
    """MLegoSession(cost="calibrated", calibration_path=...) must load
    the sidecar and price like the session that wrote it."""
    from repro.api import MLegoSession
    from repro.configs.lda_default import LDAConfig
    from repro.data.corpus import make_corpus

    cfg = LDAConfig(n_topics=4, vocab_size=60, max_iters=4,
                    e_step_iters=3, gibbs_sweeps=3)
    corpus, _ = make_corpus(60, cfg.vocab_size, cfg.n_topics,
                            mean_doc_len=15, seed=2)
    path = str(tmp_path / "calibration.json")

    from repro.api import Interval, QuerySpec
    first = MLegoSession(corpus, cfg, cost="calibrated",
                         calibration_path=path)
    first.submit(QuerySpec(sigma=Interval(0.0, 40.0)))
    assert len(first.cost.calibration) > 0
    assert first.save_calibration() == path

    warm = MLegoSession(corpus, cfg, cost="calibrated",
                        calibration_path=path)
    assert warm.cost.calibration == first.cost.calibration
    assert warm.cost.c_train(1000.0) == pytest.approx(
        first.cost.c_train(1000.0))
    # and an analytic cold-start session prices differently
    cold = MLegoSession(corpus, cfg, cost="calibrated")
    assert cold.cost.c_train(1000.0) != pytest.approx(
        warm.cost.c_train(1000.0))


def test_calibration_path_on_uncalibrated_provider_raises(tmp_path):
    """A sidecar path the provider can't load into must fail loudly at
    construction, not silently plan at analytic prices."""
    from repro.api import MLegoSession
    from repro.configs.lda_default import LDAConfig
    from repro.data.corpus import make_corpus

    cfg = LDAConfig(n_topics=4, vocab_size=60, max_iters=4,
                    e_step_iters=3, gibbs_sweeps=3)
    corpus, _ = make_corpus(40, cfg.vocab_size, cfg.n_topics,
                            mean_doc_len=10, seed=2)
    path = str(tmp_path / "calibration.json")
    with pytest.raises(ValueError, match="calibration_path requires"):
        MLegoSession(corpus, cfg, calibration_path=path)
    with pytest.raises(ValueError, match="calibration_path requires"):
        MLegoSession(corpus, cfg, cost=CostModel(), calibration_path=path)
    # a caller-supplied CalibratedCostModel instance loads the sidecar
    Calibration(host_obs=[(1, 2e-3)]).save(path)
    provider = CalibratedCostModel(BASE)
    MLegoSession(corpus, cfg, cost=provider, calibration_path=path)
    assert len(provider.calibration) == 1


def test_calibration_save_merges_with_disk_sidecar(tmp_path):
    """Two sessions saving into one sidecar must union their logs
    (dedup by observation identity), not last-writer-wins clobber."""
    path = str(tmp_path / "calibration.json")
    first = Calibration(host_obs=[(1, 1e-3), (2, 2e-3)])
    first.train_obs["host"] = [(100.0, 0.5)]
    first.save(path)

    second = Calibration(host_obs=[(2, 2e-3), (3, 3e-3)])
    second.train_obs["device"] = [(100.0, 0.05)]
    second.save(path)

    merged = Calibration.load(path)
    assert sorted(merged.host_obs) == [(1, 1e-3), (2, 2e-3), (3, 3e-3)], \
        "shared samples dedup, disjoint samples union"
    assert merged.train_obs["host"] == [(100.0, 0.5)]
    assert merged.train_obs["device"] == [(100.0, 0.05)]


def test_calibration_save_merge_opt_out_clobbers(tmp_path):
    path = str(tmp_path / "calibration.json")
    Calibration(host_obs=[(1, 1e-3)]).save(path)
    Calibration(host_obs=[(9, 9e-3)]).save(path, merge=False)
    assert Calibration.load(path).host_obs == [(9, 9e-3)]


def test_calibration_merge_respects_rolling_window(tmp_path):
    from repro.core.cost import _MAX_OBS
    path = str(tmp_path / "calibration.json")
    Calibration(host_obs=[(i, 1e-3) for i in range(1, _MAX_OBS + 1)]) \
        .save(path)
    fresh = Calibration(host_obs=[(-1, 5e-3)])
    fresh.save(path)
    merged = Calibration.load(path)
    assert len(merged.host_obs) == _MAX_OBS
    assert merged.host_obs[-1] == (-1, 5e-3), \
        "the saving session's fresh samples must survive the trim"


def test_concurrent_observers_lose_nothing():
    """The calibration log is shared by every session of a service —
    concurrent observe_* calls must all land."""
    import threading

    cal = CalibratedCostModel(BASE)
    n, threads = 200, []

    def observer(tid):
        for i in range(n):
            cal.observe_train(100 + i, 1e-3, backend=f"b{tid}")
            cal.observe_merge_host(1, 1e-3)

    for t in range(4):
        threads.append(threading.Thread(target=observer, args=(t,)))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for t in range(4):
        assert len(cal.calibration.train_obs[f"b{t}"]) == n
    assert cal.version >= 0          # refit under contention must not throw


# ---------------------------------------------------------------------------
# per-byte fetch terms (heterogeneous model shapes price correctly)
# ---------------------------------------------------------------------------

def test_fetch_cost_scales_with_model_bytes():
    """t_miss is per byte: a plan over a 4x-bigger model must price
    ~4x the fetch, which per-part pricing could never see."""
    sizes = {1: 1000, 2: 4000}
    cal = CalibratedCostModel(BASE, cache_probe=lambda mid: False,
                              size_probe=sizes.get)
    for hb, mb in ((0, 3000), (3000, 0), (2000, 1000), (1000, 2000)):
        cal.observe_merge_device(hb, mb, 1e-3 + 2e-7 * hb + 8e-7 * mb)
    small = cal.fetch_cost((1,), 0.0)
    big = cal.fetch_cost((2,), 0.0)
    assert big == pytest.approx(4 * small, rel=1e-3)


def test_fetch_cost_falls_back_to_hint_then_unit():
    cal = CalibratedCostModel(BASE, cache_probe=lambda mid: False,
                              part_bytes_hint=500.0)
    for hb, mb in ((0, 3000), (3000, 0), (2000, 1000), (1000, 2000)):
        cal.observe_merge_device(hb, mb, 1e-3 + 2e-7 * hb + 8e-7 * mb)
    hinted = cal.fetch_cost((7,), 0.0)           # unknown id -> hint
    assert hinted == pytest.approx(cal._t_miss * 500.0, rel=1e-6)
    bare = CalibratedCostModel(BASE, cache_probe=lambda mid: False)
    for hb, mb in ((0, 3000), (3000, 0), (2000, 1000), (1000, 2000)):
        bare.observe_merge_device(hb, mb, 1e-3 + 2e-7 * hb + 8e-7 * mb)
    assert bare.fetch_cost((7,), 0.0) == pytest.approx(bare._t_miss)


def test_padding_cost_prices_rows_at_hint_bytes():
    cal = CalibratedCostModel(BASE, part_bytes_hint=100.0)
    cal.observe_pad(400, 8e-3)                    # 2e-5 s per byte
    cal.observe_pad(200, 4e-3)
    assert cal.padding_cost(3) == pytest.approx(3 * 100.0 * 2e-5, rel=1e-6)


def test_session_wires_size_probe_and_hint(tmp_path):
    from repro.api import Interval, MLegoSession, QuerySpec
    from repro.configs.lda_default import LDAConfig
    from repro.data.corpus import make_corpus

    cfg = LDAConfig(n_topics=4, vocab_size=60, max_iters=4,
                    e_step_iters=3, gibbs_sweeps=3)
    corpus, _ = make_corpus(60, cfg.vocab_size, cfg.n_topics,
                            mean_doc_len=15, seed=2)
    sess = MLegoSession(corpus, cfg, cost="calibrated")
    assert sess.cost.part_bytes_hint == cfg.n_topics * cfg.vocab_size * 4
    m = sess.train_range(0.0, 40.0)
    assert sess.cost.size_probe(m.model_id) == m.nbytes()
    assert sess.cost.size_probe(999_999) is None


def test_format1_sidecar_cold_starts(tmp_path):
    """Pre-per-byte sidecars carry part counts, not bytes — loading
    them would mis-scale prices by ~KV·4, so they must cold-start."""
    stale = tmp_path / "v1.json"
    stale.write_text('{"format": 1, "train_obs": {}, "host_obs": [], '
                     '"device_obs": [[1, 2, 0.003]], "pad_obs": []}')
    assert Calibration.load(str(stale)) is None


def test_session_save_calibration_requires_a_path_and_provider():
    from repro.api import MLegoSession
    from repro.configs.lda_default import LDAConfig
    from repro.data.corpus import make_corpus

    cfg = LDAConfig(n_topics=4, vocab_size=60, max_iters=4,
                    e_step_iters=3, gibbs_sweeps=3)
    corpus, _ = make_corpus(40, cfg.vocab_size, cfg.n_topics,
                            mean_doc_len=10, seed=2)
    sess = MLegoSession(corpus, cfg, cost="calibrated")
    with pytest.raises(ValueError, match="calibration path"):
        sess.save_calibration()
    analytic = MLegoSession(corpus, cfg)
    with pytest.raises(ValueError, match="not calibrated"):
        analytic.save_calibration("/tmp/never-written.json")
