"""Model-merging properties (paper Alg. 1/2): order independence,
associativity, and equivalence of the host / kernel / collective forms."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (see ci.yml)
from hypothesis import given, settings, strategies as st

from repro.configs.lda_default import LDAConfig
from repro.core.lda import MaterializedModel
from repro.core.merge import merge_gs, merge_models, merge_vb, merged_theta
from repro.core.plans import Interval

CFG = LDAConfig(n_topics=4, vocab_size=32, eta=0.05)


def _models(arrays, kind):
    out = []
    for i, a in enumerate(arrays):
        theta = {"lam": a} if kind == "vb" else {"delta_nkv": a}
        out.append(MaterializedModel(i, Interval(float(i), float(i) + 1.0),
                                     10, 100, kind, theta))
    return out


ARRS = st.lists(
    st.integers(0, 2 ** 31 - 1).map(
        lambda s: np.random.default_rng(s).gamma(
            1.0, 1.0, (CFG.n_topics, CFG.vocab_size)).astype(np.float32)),
    min_size=1, max_size=5)


@settings(max_examples=25, deadline=None)
@given(ARRS, st.randoms(use_true_random=False))
def test_merge_vb_order_independent(arrays, rnd):
    ms = _models(arrays, "vb")
    a = merge_vb(ms, CFG)
    shuffled = list(ms)
    rnd.shuffle(shuffled)
    b = merge_vb(shuffled, CFG)
    np.testing.assert_allclose(a, b, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(ARRS, st.randoms(use_true_random=False))
def test_merge_gs_order_independent(arrays, rnd):
    ms = _models(arrays, "gs")
    a = merge_gs(ms, CFG)
    shuffled = list(ms)
    rnd.shuffle(shuffled)
    b = merge_gs(shuffled, CFG)
    np.testing.assert_allclose(a, b, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(ARRS)
def test_merge_vb_associative(arrays):
    """merge(A ∪ B) == merge(merge(A) ∪ B) in Θ-space (Eq. 6)."""
    ms = _models(arrays, "vb")
    if len(ms) < 2:
        return
    direct = merge_vb(ms, CFG)
    left_theta, kind = merged_theta(ms[:2], CFG)
    left = MaterializedModel(99, Interval(0, 2), 20, 200, kind, left_theta)
    nested = merge_vb([left] + ms[2:], CFG)
    np.testing.assert_allclose(direct, nested, rtol=1e-5)


def test_merge_gs_decay_staleness():
    a = np.ones((4, 32), np.float32)
    ms = _models([a, a], "gs")
    out = merge_gs(ms, CFG, staleness=[0, 2], decay=0.5)
    np.testing.assert_allclose(out, a * (1.0 + 0.25), rtol=1e-6)


def test_merge_rejects_mixed_kinds():
    a = np.ones((4, 32), np.float32)
    mixed = _models([a], "vb") + _models([a], "gs")
    with pytest.raises(ValueError):
        merge_models(mixed, CFG)


def test_kernel_matches_host_merge():
    """kernels/merge_topics == core/merge on the same inputs."""
    import jax.numpy as jnp
    from repro.kernels.merge_topics.ops import merge_vb_stats

    rng = np.random.default_rng(3)
    lams = rng.gamma(1.0, 1.0, (4, CFG.n_topics, CFG.vocab_size)).astype(
        np.float32)
    ms = _models(list(lams), "vb")
    host = merge_vb(ms, CFG)
    kern = np.asarray(merge_vb_stats(jnp.asarray(lams),
                                     jnp.ones((4,), jnp.float32),
                                     CFG.eta, interpret=True))
    np.testing.assert_allclose(host, kern, rtol=1e-5, atol=1e-5)


def test_delta_merge_lm_params():
    """Eq. 6 analogue for LM trees: order-independent, exact for one
    model, and equal to the weighted average of deltas."""
    import jax
    from repro.core.delta_merge import merge_param_deltas

    rng = np.random.default_rng(0)
    base = {"w": rng.normal(size=(4, 4)).astype(np.float32),
            "b": rng.normal(size=(4,)).astype(np.float32)}
    t1 = jax.tree.map(lambda x: x + 1.0, base)
    t2 = jax.tree.map(lambda x: x - 3.0, base)
    # single model, weight 1 -> exact recovery
    out1 = merge_param_deltas(base, [t1], [1.0])
    np.testing.assert_allclose(out1["w"], t1["w"], rtol=1e-6)
    # order independence
    a = merge_param_deltas(base, [t1, t2], [0.25, 0.75])
    b = merge_param_deltas(base, [t2, t1], [0.75, 0.25])
    np.testing.assert_allclose(a["w"], b["w"], rtol=1e-6)
    # weighted delta arithmetic: base + 0.25*1 + 0.75*(-3)
    np.testing.assert_allclose(a["b"], base["b"] + 0.25 - 2.25, rtol=1e-5)
